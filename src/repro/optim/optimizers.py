"""Optimizers: AdamW, Adafactor (factored), SGD-momentum. Mixed precision.

Parameters may live in bf16; every optimizer keeps an fp32 *master* copy in
its state (unless params are already fp32) and casts down after the update.
State sharding (ZeRO-1) is applied externally via the sharding rules in
``parallel/sharding.py`` — the update math here is purely elementwise /
per-tensor, which is what makes GSPMD's sharded-optimizer transform exact.

Adafactor [arXiv:1804.04235] stores a factored second moment for >=2-D
tensors (row/col means) — the only optimizer whose state fits kimi-k2-1t on
512 x 16 GB chips (see EXPERIMENTS §Dry-run).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig

__all__ = ["Optimizer", "make_optimizer", "global_norm", "clip_by_global_norm"]


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: callable        # values -> opt_state
    update: callable      # (grads, opt_state, values, step) -> (new_values, new_state)
    state_axes: callable  # values_axes_tree -> state_axes_tree (same treedef as init's)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _master(values):
    # Force a copy: fp32 params would otherwise alias their master weights,
    # which breaks buffer donation in the jitted train step.
    return jax.tree.map(lambda v: jnp.array(v, dtype=jnp.float32, copy=True), values)


def _lr(step, cfg: RunConfig, warmup=200, total=10_000):
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = cfg.learning_rate * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, jnp.maximum(cos, cfg.learning_rate * 0.1))


def make_optimizer(cfg: RunConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return _adamw(cfg)
    if cfg.optimizer == "adafactor":
        return _adafactor(cfg)
    if cfg.optimizer == "sgdm":
        return _sgdm(cfg)
    raise ValueError(cfg.optimizer)


# ------------------------------------------------------------------- AdamW
def _adamw(cfg: RunConfig, b1=0.9, b2=0.95, eps=1e-8):
    def init(values):
        zeros = jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32), values)
        st = {"m": zeros, "v": jax.tree.map(jnp.copy, zeros)}
        if cfg.master_fp32:
            st["master"] = _master(values)
        return st

    def update(grads, state, values, step):
        lr = _lr(step, cfg)
        t = (step + 1).astype(jnp.float32)
        c1 = 1 - b1**t
        c2 = 1 - b2**t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + cfg.weight_decay * p
            return m, v, p - lr * u

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        # Without a stored master, cast per-tensor INSIDE the update so XLA
        # fuses bf16->f32->update->bf16 elementwise (no 2x fp32 param copy).
        flat_p = treedef.flatten_up_to(
            state["master"] if cfg.master_fp32 else values
        )
        out = [
            upd(g, m, v, p.astype(jnp.float32))
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)
        ]
        new_m = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        new_master = treedef.unflatten([o[2] for o in out])
        new_values = jax.tree.map(
            lambda mp, v: mp.astype(v.dtype), new_master, values
        )
        st = {"m": new_m, "v": new_v}
        if cfg.master_fp32:
            st["master"] = new_master
        return new_values, st

    def state_axes(values_axes):
        st = {"m": values_axes, "v": values_axes}
        if cfg.master_fp32:
            st["master"] = values_axes
        return st

    return Optimizer(init, update, state_axes)


# --------------------------------------------------------------- Adafactor
def _adafactor(cfg: RunConfig, decay=0.8, eps=1e-30, clip_thresh=1.0):
    def init(values):
        def vstate(v):
            if v.ndim >= 2:
                return {
                    "vr": jnp.zeros(v.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(v.shape[:-2] + v.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(v.shape, jnp.float32)}

        st = {"v": jax.tree.map(vstate, values)}
        if cfg.master_fp32:
            st["master"] = _master(values)
        return st

    def update(grads, state, values, step):
        lr = _lr(step, cfg)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, vs, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if g.ndim >= 2:
                vr = beta * vs["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vs["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g / jnp.sqrt(vhat + eps)
                nvs = {"vr": vr, "vc": vc}
            else:
                v = beta * vs["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                nvs = {"v": v}
            # RMS update clipping (Adafactor eq. 7)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            if p.ndim >= 2:
                u = u + cfg.weight_decay * p
            return nvs, p - lr * u

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(
            state["master"] if cfg.master_fp32 else values
        )
        out = [upd(g, vs, p.astype(jnp.float32)) for g, vs, p in zip(flat_g, flat_v, flat_p)]
        new_v = treedef.unflatten([o[0] for o in out])
        new_master = treedef.unflatten([o[1] for o in out])
        new_values = jax.tree.map(lambda mp, v: mp.astype(v.dtype), new_master, values)
        st = {"v": new_v}
        if cfg.master_fp32:
            st["master"] = new_master
        return new_values, st

    def state_axes(values_axes):
        def vaxes(a):
            if len(a) >= 2:
                return {"vr": a[:-1], "vc": a[:-2] + a[-1:]}
            return {"v": a}

        st = {"v": jax.tree.map(vaxes, values_axes, is_leaf=_is_axes)}
        if cfg.master_fp32:
            st["master"] = values_axes
        return st

    return Optimizer(init, update, state_axes)


# -------------------------------------------------------------------- SGDM
def _sgdm(cfg: RunConfig, momentum=0.9):
    def init(values):
        return {
            "mom": jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32), values),
            "master": _master(values),
        }

    def update(grads, state, values, step):
        lr = _lr(step, cfg)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return m, p - lr * m

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["mom"])
        flat_p = treedef.flatten_up_to(state["master"])
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        new_m = treedef.unflatten([o[0] for o in out])
        new_master = treedef.unflatten([o[1] for o in out])
        new_values = jax.tree.map(lambda mp, v: mp.astype(v.dtype), new_master, values)
        return new_values, {"mom": new_m, "master": new_master}

    def state_axes(values_axes):
        return {"mom": values_axes, "master": values_axes}

    return Optimizer(init, update, state_axes)
