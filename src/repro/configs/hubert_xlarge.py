"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer.

Assignment: the conv waveform frontend is a STUB — ``input_specs`` supplies
precomputed frame embeddings (dim 512). Training is masked-unit prediction
over the 504-unit codebook (the HuBERT objective); decode shapes are
skipped (no autoregressive step). vocab 504 does not divide the tensor
axis -> the (tiny) output head is replicated.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,
    frontend="frame",
    frontend_dim=512,
    source="arXiv:2106.07447; unverified",
)
