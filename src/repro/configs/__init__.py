"""Config registry: ``--arch <id>`` resolves through here."""

from __future__ import annotations

import dataclasses

from .base import ModelConfig, RunConfig, ShapeConfig
from .shapes import SHAPES, cell_status, get_shape

from . import (  # noqa: E402
    deepseek_7b,
    deepseek_moe_16b,
    hubert_xlarge,
    kimi_k2_1t_a32b,
    llava_next_34b,
    phi3_medium_14b,
    starcoder2_15b,
    tinyllama_1_1b,
    xlstm_350m,
    zamba2_1_2b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        starcoder2_15b,
        deepseek_7b,
        phi3_medium_14b,
        tinyllama_1_1b,
        zamba2_1_2b,
        deepseek_moe_16b,
        kimi_k2_1t_a32b,
        llava_next_34b,
        hubert_xlarge,
        xlstm_350m,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family variant for CPU smoke tests (assignment: reduced
    configs exercise real compute; full configs only via the dry-run)."""
    kw: dict = dict(
        num_layers=4 if cfg.family in ("hybrid", "ssm") else 2,
        d_model=128,
        num_heads=4,
        num_kv_heads=cfg.num_kv_heads if cfg.num_kv_heads == cfg.num_heads else 2,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
        attn_chunk=64,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.num_kv_heads == cfg.num_heads:
        kw["num_kv_heads"] = 4
    if cfg.moe_num_experts:
        kw.update(
            moe_num_experts=8,
            moe_top_k=2,
            moe_num_shared=min(cfg.moe_num_shared, 1),
            moe_first_dense=min(cfg.moe_first_dense, 1),
            moe_dense_ff=320 if cfg.moe_dense_ff else 0,
        )
    if cfg.family == "hybrid":
        kw.update(ssm_state=16, ssm_head_dim=32, attn_every=2)
    if cfg.family == "ssm":
        kw.update(slstm_every=2)
    if cfg.window:
        kw["window"] = 64
    if cfg.frontend != "none":
        kw.update(frontend_dim=32, frontend_len=8)
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "ARCHS",
    "ModelConfig",
    "RunConfig",
    "SHAPES",
    "ShapeConfig",
    "cell_status",
    "get_config",
    "get_shape",
    "list_archs",
    "reduced",
]
