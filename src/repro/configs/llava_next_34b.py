"""LLaVA-NeXT-34B [hf:llava-hf; unverified] — VLM backbone, anyres tiling.

Assignment: the modality frontend is a STUB — ``input_specs`` supplies
precomputed patch embeddings (anyres 4-tile + base ≈ 2304 patches of dim
1024) that a linear connector projects into the 7168-wide decoder. 56 heads
do not divide the 16-way tensor axis -> sequence-sharded attention.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    attn_shard="seq",
    frontend="patch",
    frontend_dim=1024,
    frontend_len=2304,
    source="hf:llava-hf/llava-v1.6; unverified",
)
