"""Phi-3-medium-14B [arXiv:2404.14219] — dense, RoPE, SwiGLU, GQA kv=10.

40 heads do not divide the 16-way tensor axis -> sequence-sharded attention
fallback (``attn_shard="seq"``; see models/attention.py).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    head_dim=128,
    attn_shard="seq",
    source="arXiv:2404.14219; unverified",
)
