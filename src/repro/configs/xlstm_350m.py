"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks (d_ff=0: the
up/down projections live inside the blocks). Every 8th block is sLSTM
(≈7:1 mLSTM:sLSTM, the paper's ratio)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    mlstm_proj_factor=2.0,
    source="arXiv:2405.04517; unverified",
)
