"""Model + run configuration dataclasses (the framework's config system).

Every assigned architecture is one ``ModelConfig`` in ``configs/<id>.py``;
shapes (train_4k / prefill_32k / decode_32k / long_500k) live in
``configs/shapes.py``. ``--arch``/``--shape`` flags on the launchers select
them by name through :func:`repro.configs.registry`.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeConfig", "RunConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0      # shared (always-on) experts
    moe_first_dense: int = 0     # leading dense layers in a MoE stack
    moe_dense_ff: int = 0        # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "gspmd"      # "gspmd" (pjit dispatch) | "a2a" (shard_map
                                 # all-to-all; needs a mesh with a model axis)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256         # SSD chunk length (perf knob, §Perf)
    attn_every: int = 0          # hybrid: shared attn block after every k blocks

    # --- xLSTM ---
    slstm_every: int = 0         # every k-th block is sLSTM (rest mLSTM)
    mlstm_proj_factor: float = 2.0

    # --- attention details ---
    causal: bool = True
    rope_theta: float = 10_000.0
    window: int = 0              # sliding-window size (0 = full attention)
    attn_shard: str = "heads"    # "heads" | "seq" (fallback when heads % tp != 0)
    attn_chunk: int = 1024       # online-softmax block size for long sequences
    attn_dense_threshold: int = 2048  # use chunked attention above this seq_len
    kv_cache_dtype: str = ""     # "" = compute dtype; "int8" = quantized cache
                                 # (per-token/head scales; halves decode HBM traffic)
    logit_softcap: float = 0.0

    # --- frontends (assignment: modality frontends are stubs) ---
    frontend: str = "none"       # none | patch (vlm) | frame (audio)
    frontend_dim: int = 0        # embedding dim of precomputed patch/frame inputs
    frontend_len: int = 0        # number of patch/frame positions per sample

    # --- numerics ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- notes for DESIGN/EXPERIMENTS ---
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count of the built model (validated by tests)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim_
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        total += d  # final norm
        if self.frontend != "none":
            total += self.frontend_dim * d
        for kind in self.block_layout():
            total += self._block_params(kind, d, hd)
        return total

    def _attn_params(self, d, hd):
        return d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d

    def _block_params(self, kind, d, hd):
        if kind == "attn_mlp":
            return self._attn_params(d, hd) + 3 * d * self.d_ff + 2 * d
        if kind == "attn_dense_moe":  # leading dense layer inside a MoE model
            return self._attn_params(d, hd) + 3 * d * (self.moe_dense_ff or self.d_ff) + 2 * d
        if kind == "attn_moe":
            experts = self.moe_num_experts * 3 * d * self.d_ff
            shared = self.moe_num_shared * 3 * d * self.d_ff
            router = d * self.moe_num_experts
            return self._attn_params(d, hd) + experts + shared + router + 2 * d
        if kind == "mamba2":
            di, n = self.d_inner, self.ssm_state
            heads = di // self.ssm_head_dim
            in_proj = d * (2 * di + 2 * n + heads)
            conv = (di + 2 * n) * self.ssm_conv
            extras = heads * 2 + di  # A_log, dt_bias, skip D
            out = di * d
            return in_proj + conv + extras + out + d
        if kind == "shared_attn":
            # one shared parameter set, counted once (returned by caller once)
            return self._attn_params(d, hd) + 3 * d * self.d_ff + 2 * d
        if kind == "mlstm":
            di = int(self.mlstm_proj_factor * d)
            qkv = 3 * di * di + 2 * di  # qkv + i,f gate biases folded in proj
            gates = 2 * di * 2  # per-channel i/f projections (low-rank-ish)
            return d * 2 * di + qkv + gates + di + di * d + d
        if kind == "slstm":
            h = d
            return 4 * (h * h + h * h + h) + d  # W, R (block-diag counted dense), b
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k of routed)."""
        if not self.moe_num_experts:
            return self.param_count()
        total = self.param_count()
        d = self.d_model
        routed = (self.num_layers - self.moe_first_dense) * (
            self.moe_num_experts * 3 * d * self.d_ff
        )
        active_routed = routed * self.moe_top_k / self.moe_num_experts
        return int(total - routed + active_routed)

    # ------------------------------------------------------------- layout
    def block_layout(self) -> list[str]:
        """Per-layer block kinds, in order. 'shared_attn' appears at each
        application site but its params are shared (counted once)."""
        L = self.num_layers
        if self.family in ("dense", "encoder", "vlm"):
            return ["attn_mlp"] * L
        if self.family == "moe":
            lead = ["attn_dense_moe"] * self.moe_first_dense
            return lead + ["attn_moe"] * (L - self.moe_first_dense)
        if self.family == "hybrid":
            out = []
            for i in range(L):
                out.append("mamba2")
                if self.attn_every and (i + 1) % self.attn_every == 0:
                    out.append("shared_attn")
            return out
        if self.family == "ssm":
            out = []
            for i in range(L):
                if self.slstm_every and (i + 1) % self.slstm_every == 0:
                    out.append("slstm")
                else:
                    out.append("mlstm")
            return out
        raise ValueError(self.family)

    def segments(self) -> list[tuple[str, int]]:
        """Run-length encoding of block_layout -> scan segments."""
        out: list[tuple[str, int]] = []
        for kind in self.block_layout():
            if out and out[-1][0] == kind:
                out[-1] = (kind, out[-1][1] + 1)
            else:
                out.append((kind, 1))
        return out

    def supports_decode(self) -> bool:
        return self.family != "encoder"

    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists (assignment: run long_500k only then)."""
        return self.family in ("hybrid", "ssm")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run knobs independent of the architecture."""

    optimizer: str = "adamw"        # adamw | adafactor | sgdm
    parallelism: str = "tp"         # "tp" (model axis = tensor parallel) |
                                    # "dp_only" (model axis = extra data parallel;
                                    # right-sizes small models on the fixed mesh)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: str = "dots"             # none | dots | full
    zero1: bool = True              # shard optimizer state over the data axis
    fsdp: bool = False              # shard params over the data axis too
    grad_allreduce_dtype: str = ""  # "" = native; "bfloat16" halves collective bytes
    microbatch: int = 0             # 0 = no gradient accumulation
    seq_parallel: bool = False      # Megatron-SP: shard residual stream on seq dim
    master_fp32: bool = True        # keep fp32 master weights in optimizer state
                                    # (False: update bf16 params directly — required
                                    # to fit kimi-k2-1t in 512 x 16 GB HBM)
