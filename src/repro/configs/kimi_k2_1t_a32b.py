"""Kimi-K2 1T-A32B [arXiv:2501.kimi2 paper-table] — trillion-param MoE.

384 routed experts top-8 + 1 shared, fine-grained d_ff=2048, first layer
dense (d_ff=18432). head_dim = 7168/64 = 112 per the assignment table (MXU
pads 112->128; noted in the roofline). Requires FSDP + factored optimizer to
fit 16 GB/chip HBM at 512 chips (see RunConfig overrides in launch/dryrun).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,
    moe_num_experts=384,
    moe_top_k=8,
    moe_num_shared=1,
    moe_first_dense=1,
    moe_dense_ff=18432,
    capacity_factor=1.0,
    source="arXiv:2501.kimi2; unverified (paper-table)",
)
