"""The assigned input-shape set (same four cells for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``; ``prefill_*`` lowers the full-sequence
forward that builds the cache. Skips (assignment-mandated):
* long_500k  -> only archs with a sub-quadratic path (hybrid/ssm families);
* decode_*   -> not for encoder-only archs.
"""

from __future__ import annotations

from .base import ModelConfig, ShapeConfig

__all__ = ["SHAPES", "get_shape", "cell_status"]

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a skip reason for the (arch x shape) dry-run cell."""
    if shape.kind == "decode" and not cfg.supports_decode():
        return "skip: encoder-only arch has no autoregressive step"
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return "skip: pure full-attention arch (assignment: sub-quadratic only)"
    return "run"
