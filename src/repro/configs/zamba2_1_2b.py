"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention.

38 Mamba2 blocks; one *shared* attention+MLP block (single parameter set)
applied after every 6th Mamba2 block (Zamba's parameter-sharing design).
``window=4096`` bounds the shared block's KV at 500k-context decode (the
sub-quadratic requirement of the long_500k cell; DESIGN.md §7 note — the
released model uses full attention at 4k context, where window=4096 is
equivalent).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    window=4096,
    source="arXiv:2411.15242; hf",
)
