"""Shared argparse builders for the launch CLIs.

``train.py`` and ``data_service.py`` grew the same data-plane knobs with
drifting spellings; these builders define each shared flag ONCE —
identical option string, type, choices, default, and help — and each
launcher composes the groups it needs. ``tests/test_transport.py`` pins
the two parsers to identical spellings for every shared flag.
"""

from __future__ import annotations

import argparse
from pathlib import Path

from ..core.storage.codec import CODECS
from ..core.storage.store import BACKENDS

__all__ = [
    "DEVICE_PATHS",
    "RESUME_AUTO",
    "add_autotune_args",
    "add_data_plane_args",
    "add_device_args",
    "add_elastic_args",
    "add_obs_args",
    "add_storage_args",
    "resolve_resume_dir",
]

#: ``--device-path`` spellings (DESIGN.md §12): ``naive`` is the per-step
#: ``jnp.asarray`` copy, ``stage`` double-buffers host grids onto the
#: device through a DeviceStager, ``gather`` additionally assembles the
#: batch on-device via the Pallas chunk_gather_train pass.
DEVICE_PATHS = ("naive", "stage", "gather")

#: Sentinel for a bare ``--resume-data`` (no directory): the launcher
#: resolves it to its own default location (train: ``workdir/ckpt/data``);
#: launchers with no natural default reject it with a usage error.
RESUME_AUTO = "__auto__"


def add_data_plane_args(
    ap: argparse.ArgumentParser,
    *,
    batch: int = 8,
    seq_len: int = 128,
    num_docs: int = 1024,
) -> None:
    """The session-shaping knobs every data-plane launcher shares.

    Per-launcher defaults differ only where the historical CLIs did
    (batch/seq-len/num-docs); spelling, type and semantics are identical.
    """
    g = ap.add_argument_group("data plane")
    g.add_argument("--batch", type=int, default=batch,
                   help="global batch size (records per training step)")
    g.add_argument("--seq-len", type=int, default=seq_len)
    g.add_argument("--num-docs", type=int, default=num_docs,
                   help="synthetic dataset size when building a fresh store")
    g.add_argument("--vocab-size", type=int, default=None,
                   help="synthetic vocab (default: launcher-specific)")
    g.add_argument("--seed", type=int, default=0,
                   help="base seed; protocol/sampler/dataset seeds derive "
                        "from it identically in every launcher")
    g.add_argument("--policy", choices=["max_fill", "random"],
                   default="max_fill", help="redirection refill policy")
    g.add_argument("--engine", choices=["replay", "step", "per_access"],
                   default="replay", help="epoch execution engine")
    add_storage_args(ap)


def add_storage_args(ap: argparse.ArgumentParser) -> None:
    """The chunk-store byte-representation knobs (DESIGN.md §15), shared
    verbatim: how chunks are read (``--backend``), how a *fresh* store is
    written (``--codec``/``--bands`` — an existing store's ``store.json``
    wins), and how much of a progressive store to read (``--fidelity``).
    """
    g = ap.add_argument_group("storage")
    g.add_argument("--backend", choices=sorted(BACKENDS), default=None,
                   help="storage backend (default: the store's default)")
    g.add_argument("--codec", choices=sorted(CODECS), default=None,
                   help="per-chunk compression codec when building a fresh "
                        "store (existing stores keep their store.json spec)")
    g.add_argument("--bands", type=int, default=None, metavar="N",
                   help="progressive fidelity bands per record when building "
                        "a fresh store (1: flat records)")
    g.add_argument("--fidelity", type=int, default=None, metavar="K",
                   help="read only the first K fidelity bands of a "
                        "progressive store (default: the autotuner's §6 "
                        "choice under --autotune, else full fidelity)")


def add_device_args(ap: argparse.ArgumentParser) -> None:
    """The host→device staging knobs (DESIGN.md §12), shared verbatim."""
    g = ap.add_argument_group("device data path")
    g.add_argument("--device-path", choices=DEVICE_PATHS, default="naive",
                   help="how batches reach the accelerator: naive per-step "
                        "copies, double-buffered staging, or staged + "
                        "on-device Pallas gather assembly")
    g.add_argument("--stage-depth", type=int, default=2, metavar="N",
                   help="staged device batches kept in flight "
                        "(stage/gather paths)")


def add_elastic_args(ap: argparse.ArgumentParser) -> None:
    """Suspend/resume flags (DESIGN.md §10), shared verbatim."""
    g = ap.add_argument_group("elastic data plane")
    g.add_argument("--resume-data", type=str, nargs="?", const=RESUME_AUTO,
                   default=None, metavar="DIR",
                   help="data-plane suspend/resume directory: resumed from "
                        "if it holds suspend files, written by "
                        "--suspend-after; bare --resume-data uses the "
                        "launcher's default location (if it has one)")
    g.add_argument("--suspend-after", type=int, default=None, metavar="N",
                   help="suspend the data plane to --resume-data after N "
                        "steps and exit (restart with the same flags to "
                        "continue byte-identically)")


def add_autotune_args(ap: argparse.ArgumentParser) -> None:
    """Model-fitted autotuning flags (DESIGN.md §14), shared verbatim."""
    g = ap.add_argument_group("autotuning")
    g.add_argument("--autotune", action="store_true",
                   help="calibrate the chunk store (repro.autotune) and "
                        "auto-select storage backend, readahead depth, and "
                        "cache byte cap from the fitted §6 time model; an "
                        "explicit --backend (or --cache-mb where it exists) "
                        "overrides the corresponding choice")
    g.add_argument("--autotune-memory-mb", type=float, default=None,
                   metavar="MB", help="ceiling for the autotuned cache cap")
    g.add_argument("--compute-per-step", type=float, default=0.0,
                   metavar="SECONDS",
                   help="per-step compute time fed to the autotuner's epoch "
                        "prediction and the service's admission control "
                        "(0: treat the run as I/O bound)")


def add_obs_args(ap: argparse.ArgumentParser) -> None:
    """Observability flags (DESIGN.md §13), shared verbatim."""
    g = ap.add_argument_group("observability")
    g.add_argument("--trace", type=str, default=None, metavar="FILE",
                   help="record a span trace of the run and write Chrome-"
                        "trace JSON to FILE (open in Perfetto UI or "
                        "chrome://tracing); prints the per-stage epoch-time "
                        "attribution report on exit")
    g.add_argument("--trace-capacity", type=int, default=262144, metavar="N",
                   help="trace ring capacity in events (oldest dropped)")
    g.add_argument("--metrics", action="store_true",
                   help="print the Prometheus-style metrics exposition "
                        "(counters/stats snapshot) on exit")


def resolve_resume_dir(
    ap: argparse.ArgumentParser, value, default: "Path | None"
) -> "Path | None":
    """Resolve ``--resume-data``'s value: None passes through, a directory
    is taken literally, and the bare-flag sentinel becomes ``default`` —
    or a usage error for launchers that have no default location."""
    if value is None:
        return None
    if value != RESUME_AUTO:
        return Path(value)
    if default is None:
        ap.error("--resume-data requires a directory with this launcher")
    return default
