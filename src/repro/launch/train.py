"""Training launcher: ``--arch <id>`` selects any assigned architecture.

On this CPU container the model runs at the reduced (same-family) size by
default (``--full`` uses the full config — only sensible on real hardware);
data always flows through the real Redox chunk store + redirection
protocol. Checkpoints/restart and the async loader are on by default.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --steps 50
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs import RunConfig, get_config, list_archs, reduced
from ..core import Cluster, EpochSampler, RedoxLoader
from ..data import SyntheticTokenDataset
from ..models import build_model
from ..optim.optimizers import make_optimizer
from ..train.train_step import build_train_step, init_train_state


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--num-docs", type=int, default=1024)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--full", action="store_true", help="full-size config (real HW)")
    ap.add_argument("--resume-data", action="store_true",
                    help="checkpoint/restore the DATA PLANE alongside model "
                         "state: each model checkpoint also writes a mid-epoch "
                         "loader snapshot (ckpt/data), and a restart resumes "
                         "the batch stream byte-identically mid-epoch")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    model = build_model(cfg)
    run = RunConfig(optimizer=args.optimizer, remat=args.remat)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    step_fn = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
    print(f"arch={args.arch} family={cfg.family} params={cfg.param_count():,d}")

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix=f"redox_{args.arch}_"))
    ds = SyntheticTokenDataset(args.num_docs, cfg.vocab_size,
                               mean_len=args.seq_len // 2, seed=5)
    store = ds.build_store(workdir / "chunks", chunk_size=16,
                           memory_bytes=int(ds.sizes_bytes.sum() // 4), seed=1)
    data_ck = workdir / "ckpt" / "data"
    if args.resume_data and (data_ck / "loader_manifest.json").exists():
        loader = RedoxLoader.resume(data_ck, store)
        print(f"data plane resumed at epoch {loader.resume_point[0]} "
              f"step {loader.resume_point[1]}")
    else:
        cluster = Cluster(store.plan, args.nodes, store=store, seed=2,
                          remote_memory_limit_bytes=1_000_000)
        sampler = EpochSampler(args.num_docs, args.nodes, seed=3)
        loader = RedoxLoader(cluster, sampler,
                             batch_per_node=max(args.batch // args.nodes, 1),
                             seq_len=args.seq_len)
    ckpt = AsyncCheckpointer(workdir / "ckpt")
    start = latest_step(workdir / "ckpt")
    if start:
        state = restore_checkpoint(workdir / "ckpt", start, state)
        print(f"resumed from step {start}")

    if cfg.frontend != "none":
        print("note: stub-frontend arch — launcher trains on token records "
              "projected through the frontend stub (see launch/specs.py)")

    step = int(start or 0)
    epoch, t0 = (loader.resume_point or (0, 0))[0], time.time()
    while step < args.steps:
        for batch in loader.epoch_async(epoch):
            if step >= args.steps:
                break
            feed = {
                "tokens": jnp.asarray(batch["tokens"]),
                "targets": jnp.asarray(batch["targets"]),
                "loss_mask": jnp.asarray(batch["loss_mask"]),
            }
            if cfg.frontend == "frame":
                # stub frontend: embed tokens as one-hot-ish frames
                b, s = feed["tokens"].shape
                feed["frames"] = jax.nn.one_hot(
                    feed["tokens"] % cfg.frontend_dim, cfg.frontend_dim,
                    dtype=jnp.dtype(cfg.compute_dtype),
                )
                del feed["tokens"]
            elif cfg.frontend == "patch":
                b = feed["tokens"].shape[0]
                p = cfg.frontend_len
                feed["patch_embeds"] = jnp.zeros(
                    (b, p, cfg.frontend_dim), jnp.dtype(cfg.compute_dtype)
                )
                feed["targets"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.int32), feed["targets"]], axis=1
                )
                feed["loss_mask"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.float32), feed["loss_mask"]], axis=1
                )
            state, metrics = step_fn(state, feed)
            step += 1
            if step % 10 == 0 or step == 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/step:.2f}s/step)")
            if step % args.ckpt_every == 0:
                ckpt.save(step, state)
                if args.resume_data:
                    # Replay-engine suspend is derived (shadow simulation),
                    # so the stream keeps flowing while this writes.
                    loader.suspend(data_ck)
        epoch += 1
    ckpt.wait()
    print(f"done: {step} steps in {time.time()-t0:.0f}s; workdir={workdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
