"""Training launcher: ``--arch <id>`` selects any assigned architecture.

On this CPU container the model runs at the reduced (same-family) size by
default (``--full`` uses the full config — only sensible on real hardware);
data always flows through the real Redox chunk store + redirection
protocol. Checkpoints/restart and the async loader are on by default.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --steps 50

With ``--data-server SOCKET`` the trainer owns no data plane at all: it
opens a session on a running ``repro.launch.data_service --serve`` process
and consumes batches from the shared-memory ring (DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs import RunConfig, get_config, list_archs, reduced
from ..core import ChunkStore, RedoxLoader, SessionSpec
from ..data import SyntheticTokenDataset
from ..core.stats import PipelineTimeModel, StepIO
from ..models import build_model
from ..obs import (
    MetricsRegistry,
    attribution,
    format_report,
    model_columns,
    trace,
)
from ..optim.optimizers import make_optimizer
from ..service.transport import RedoxClient
from ..train.train_step import build_train_step, init_train_state
from .cli import (
    add_autotune_args,
    add_data_plane_args,
    add_device_args,
    add_elastic_args,
    add_obs_args,
    resolve_resume_dir,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--full", action="store_true", help="full-size config (real HW)")
    add_data_plane_args(ap, batch=8, seq_len=128, num_docs=1024)
    add_device_args(ap)
    add_elastic_args(ap)
    add_autotune_args(ap)
    add_obs_args(ap)
    ap.add_argument("--data-server", metavar="SOCKET", default=None,
                    help="consume batches from a repro.launch.data_service "
                         "--serve process at this unix socket instead of "
                         "building a local data plane")
    ap.add_argument("--job-id", default="train0",
                    help="session id on the data server (--data-server only)")
    return ap


#: Nominal NAS storage/network profile for the DESIGN §6 model columns
#: printed next to the measured attribution under ``--trace`` (same shape
#: as the benchmarks/calibration.py entries; this box's synthetic store is
#: page-cached, so the model shows what the run's I/O demand would cost on
#: the paper's target storage, not what it cost here).
TRACE_TIME_MODEL = PipelineTimeModel(
    disk_bw=200e6, file_overhead=8e-3, chunk_overhead=8e-3,
    net_bw=1e9, net_latency=2e-4,
)


def _local_metrics(loader, store, stager) -> MetricsRegistry:
    """Registry over a local data plane's live stats objects."""
    reg = MetricsRegistry()
    if store is not None:
        reg.register_stats("backend", lambda: store.backend_stats)
    if stager is not None:
        reg.register_stats("device", lambda: stager.stats)
    cluster = getattr(loader, "cluster", None)
    if cluster is not None:
        for r, node in enumerate(cluster.nodes):
            reg.register_stats(
                "node", lambda n=node: n.stats, labels={"node": str(r)}
            )
    last_plan = getattr(loader, "last_plan", None)
    if last_plan is not None:
        reg.register_stats("planner", lambda: last_plan.stats)
    return reg


def main() -> int:
    ap = build_parser()
    args = ap.parse_args()
    if args.data_server is not None and args.resume_data is not None:
        ap.error("--resume-data belongs to the server with --data-server "
                 "(run data_service --resume-data there)")
    if args.data_server is not None and args.suspend_after is not None:
        ap.error("--suspend-after belongs to the server with --data-server")
    if args.suspend_after is not None and args.resume_data is None:
        ap.error("--suspend-after requires --resume-data")
    if args.data_server is not None and args.device_path == "gather":
        ap.error("--device-path gather requires a local data plane (ring "
                 "frames ship assembled grids); use --device-path stage")

    tracer = trace.enable(args.trace_capacity) if args.trace else None

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    model = build_model(cfg)
    run = RunConfig(optimizer=args.optimizer, remat=args.remat)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    step_fn = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
    print(f"arch={args.arch} family={cfg.family} params={cfg.param_count():,d}")

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix=f"redox_{args.arch}_"))
    # Seeds derive from --seed exactly as in data_service.py: protocol
    # +2, sampler +3, dataset +5 (the historical constants at seed 0).
    spec = SessionSpec(
        policy=args.policy,
        seed=args.seed + 2,
        sampler_seed=args.seed + 3,
        num_nodes=args.nodes,
        batch_per_node=max(args.batch // args.nodes, 1),
        seq_len=args.seq_len,
        engine=args.engine,
        remote_memory_limit_bytes=1_000_000,
        fidelity=args.fidelity,
    )
    data_dir = resolve_resume_dir(ap, args.resume_data, workdir / "ckpt" / "data")
    store = None
    if args.data_server is not None:
        loader = RedoxClient(args.data_server, spec, job_id=args.job_id)
        print(f"data plane: {args.data_server} (job {args.job_id})")
    else:
        ds = SyntheticTokenDataset(args.num_docs, args.vocab_size or cfg.vocab_size,
                                   mean_len=args.seq_len // 2, seed=args.seed + 5)
        store = ds.build_store(workdir / "chunks", chunk_size=16,
                               memory_bytes=int(ds.sizes_bytes.sum() // 4),
                               seed=args.seed + 1,
                               codec=args.codec, bands=args.bands)
        if args.backend is not None:
            store.close()
            store = ChunkStore.open(workdir / "chunks", backend=args.backend)
        elif args.autotune:
            # Calibrate the freshly built store and reopen it with the
            # model-selected backend + readahead (DESIGN.md §14). An
            # explicit --backend wins over the autotuner (branch above).
            from .. import autotune
            from ..core.storage import make_backend

            steps_hint = max(args.num_docs // max(args.batch, 1), 1)
            _, choice = autotune.tune_store(
                workdir / "chunks",
                compute_per_step_s=args.compute_per_step,
                num_steps=steps_hint,
                memory_limit_bytes=(
                    int(args.autotune_memory_mb * 1e6)
                    if args.autotune_memory_mb is not None else None
                ),
            )
            print(f"autotune: {choice.describe()}")
            store.close()
            kwargs = {"readahead": choice.readahead} if choice.readahead else {}
            store = ChunkStore.open(
                workdir / "chunks",
                backend=make_backend(choice.backend, **kwargs),
            )
            # The §6 model's fidelity call on a progressive store — an
            # explicit --fidelity wins (it's already in the spec).
            if args.fidelity is None and choice.fidelity is not None:
                spec = dataclasses.replace(spec, fidelity=choice.fidelity)
        if data_dir is not None and (data_dir / "loader_manifest.json").exists():
            loader = RedoxLoader.resume(data_dir, store)
            print(f"data plane resumed at epoch {loader.resume_point[0]} "
                  f"step {loader.resume_point[1]}")
        else:
            loader = RedoxLoader.from_spec(spec, store)
    stager = None
    if args.device_path != "naive":
        from ..core.device import DeviceStager  # deferred: jax-heavy

        stager = DeviceStager(depth=args.stage_depth,
                              use_kernel=(args.device_path == "gather"))
        mode = f"device path: {args.device_path} (depth {args.stage_depth}"
        if args.device_path == "gather":
            mode += f", {'interpret' if stager.interpret else 'compiled'} gather"
        print(mode + ")")

    def epoch_batches(epoch):
        if args.device_path == "gather":
            return loader.epoch_device(epoch, stager)
        if args.device_path == "stage":
            return stager.stream(loader.epoch_async(epoch))
        return loader.epoch_async(epoch)

    ckpt = AsyncCheckpointer(workdir / "ckpt")
    start = latest_step(workdir / "ckpt")
    if start:
        state = restore_checkpoint(workdir / "ckpt", start, state)
        print(f"resumed from step {start}")

    if cfg.frontend != "none":
        print("note: stub-frontend arch — launcher trains on token records "
              "projected through the frontend stub (see launch/specs.py)")

    step = int(start or 0)
    run_steps = 0
    # Per-node StepIO grid for the §6 model columns (--trace only). NB:
    # a Tracer is sized by its event count — test identity, not truth.
    io_grid = [[] for _ in range(spec.num_nodes)] if tracer is not None else None
    suspended = False
    epoch, t0 = (loader.resume_point or (0, 0))[0], time.time()
    while step < args.steps and not suspended:
        for batch in epoch_batches(epoch):
            if step >= args.steps:
                break
            feed = {
                "tokens": jnp.asarray(batch["tokens"]),
                "targets": jnp.asarray(batch["targets"]),
                "loss_mask": jnp.asarray(batch["loss_mask"]),
            }
            if cfg.frontend == "frame":
                # stub frontend: embed tokens as one-hot-ish frames
                b, s = feed["tokens"].shape
                feed["frames"] = jax.nn.one_hot(
                    feed["tokens"] % cfg.frontend_dim, cfg.frontend_dim,
                    dtype=jnp.dtype(cfg.compute_dtype),
                )
                del feed["tokens"]
            elif cfg.frontend == "patch":
                b = feed["tokens"].shape[0]
                p = cfg.frontend_len
                feed["patch_embeds"] = jnp.zeros(
                    (b, p, cfg.frontend_dim), jnp.dtype(cfg.compute_dtype)
                )
                feed["targets"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.int32), feed["targets"]], axis=1
                )
                feed["loss_mask"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.float32), feed["loss_mask"]], axis=1
                )
            if tracer is None:
                state, metrics = step_fn(state, feed)
            else:
                # Force the step inside the span so "compute" reflects real
                # device time, not dispatch (tracing is opt-in, so the
                # pipeline bubble this sync adds is acceptable).
                with trace.span("train.step", "compute", step=step):
                    state, metrics = step_fn(state, feed)
                    jax.block_until_ready(metrics)
            if io_grid is not None:
                by_node = batch.get("io_by_node") or {}
                for r in range(spec.num_nodes):
                    io_grid[r].append(by_node.get(r, StepIO()))
            step += 1
            run_steps += 1
            if step % 10 == 0 or step == 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/step:.2f}s/step)")
            if step % args.ckpt_every == 0:
                ckpt.save(step, state)
                if data_dir is not None:
                    # Replay-engine suspend is derived (shadow simulation),
                    # so the stream keeps flowing while this writes.
                    loader.suspend(data_dir)
            if args.suspend_after is not None and run_steps >= args.suspend_after:
                ckpt.save(step, state)
                loader.suspend(data_dir)
                suspended = True
                break
        epoch += 1
    ckpt.wait()
    elapsed = time.time() - t0
    if stager is not None:
        stager.close()
        d = stager.stats
        print(f"device path {args.device_path}: staged {d.steps} batches "
              f"({d.bytes_to_device / 1e6:.1f} MB to device), "
              f"overlap fraction {d.overlap_fraction:.2f}")
    if run_steps:
        toks = run_steps * spec.num_nodes * spec.batch_per_node * spec.seq_len
        print(f"throughput: {toks / max(elapsed, 1e-9):,.0f} tokens/sec "
              f"over {run_steps} step(s)")
    if args.metrics:
        if args.data_server is not None:
            print(loader.metrics()["text"], end="")  # server-side registry
        else:
            print(_local_metrics(loader, store, stager).exposition(), end="")
    if tracer is not None:
        out = tracer.dump(args.trace)
        print(f"trace: {len(tracer)} events ({tracer.dropped} dropped) -> "
              f"{out}; open in the Perfetto UI or chrome://tracing")
        att = attribution(tracer.events(), wall_s=elapsed)
        model = None
        if run_steps and any(io_grid):
            model = model_columns(
                io_grid, TRACE_TIME_MODEL,
                att["busy_s"].get("compute", 0.0) / run_steps,
            )
        print(format_report(att, model=model, measured_wall_s=elapsed))
        trace.disable()
    if args.data_server is not None:
        loader.close()
    if store is not None:
        store.close()
    if suspended:
        print(f"suspended after {run_steps} step(s) -> {data_dir}; "
              f"rerun with the same flags to continue")
    else:
        print(f"done: {step} steps in {elapsed:.0f}s; workdir={workdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
