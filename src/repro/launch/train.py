"""Training launcher: ``--arch <id>`` selects any assigned architecture.

On this CPU container the model runs at the reduced (same-family) size by
default (``--full`` uses the full config — only sensible on real hardware);
data always flows through the real Redox chunk store + redirection
protocol. Checkpoints/restart and the async loader are on by default.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --steps 50

With ``--data-server SOCKET`` the trainer owns no data plane at all: it
opens a session on a running ``repro.launch.data_service --serve`` process
and consumes batches from the shared-memory ring (DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs import RunConfig, get_config, list_archs, reduced
from ..core import ChunkStore, RedoxLoader, SessionSpec
from ..data import SyntheticTokenDataset
from ..models import build_model
from ..optim.optimizers import make_optimizer
from ..service.transport import RedoxClient
from ..train.train_step import build_train_step, init_train_state
from .cli import (
    add_data_plane_args,
    add_device_args,
    add_elastic_args,
    resolve_resume_dir,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--full", action="store_true", help="full-size config (real HW)")
    add_data_plane_args(ap, batch=8, seq_len=128, num_docs=1024)
    add_device_args(ap)
    add_elastic_args(ap)
    ap.add_argument("--data-server", metavar="SOCKET", default=None,
                    help="consume batches from a repro.launch.data_service "
                         "--serve process at this unix socket instead of "
                         "building a local data plane")
    ap.add_argument("--job-id", default="train0",
                    help="session id on the data server (--data-server only)")
    return ap


def main() -> int:
    ap = build_parser()
    args = ap.parse_args()
    if args.data_server is not None and args.resume_data is not None:
        ap.error("--resume-data belongs to the server with --data-server "
                 "(run data_service --resume-data there)")
    if args.data_server is not None and args.suspend_after is not None:
        ap.error("--suspend-after belongs to the server with --data-server")
    if args.suspend_after is not None and args.resume_data is None:
        ap.error("--suspend-after requires --resume-data")
    if args.data_server is not None and args.device_path == "gather":
        ap.error("--device-path gather requires a local data plane (ring "
                 "frames ship assembled grids); use --device-path stage")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    model = build_model(cfg)
    run = RunConfig(optimizer=args.optimizer, remat=args.remat)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    step_fn = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
    print(f"arch={args.arch} family={cfg.family} params={cfg.param_count():,d}")

    workdir = Path(args.workdir or tempfile.mkdtemp(prefix=f"redox_{args.arch}_"))
    # Seeds derive from --seed exactly as in data_service.py: protocol
    # +2, sampler +3, dataset +5 (the historical constants at seed 0).
    spec = SessionSpec(
        policy=args.policy,
        seed=args.seed + 2,
        sampler_seed=args.seed + 3,
        num_nodes=args.nodes,
        batch_per_node=max(args.batch // args.nodes, 1),
        seq_len=args.seq_len,
        engine=args.engine,
        remote_memory_limit_bytes=1_000_000,
    )
    data_dir = resolve_resume_dir(ap, args.resume_data, workdir / "ckpt" / "data")
    store = None
    if args.data_server is not None:
        loader = RedoxClient(args.data_server, spec, job_id=args.job_id)
        print(f"data plane: {args.data_server} (job {args.job_id})")
    else:
        ds = SyntheticTokenDataset(args.num_docs, args.vocab_size or cfg.vocab_size,
                                   mean_len=args.seq_len // 2, seed=args.seed + 5)
        store = ds.build_store(workdir / "chunks", chunk_size=16,
                               memory_bytes=int(ds.sizes_bytes.sum() // 4),
                               seed=args.seed + 1)
        if args.backend is not None:
            store.close()
            store = ChunkStore.open(workdir / "chunks", backend=args.backend)
        if data_dir is not None and (data_dir / "loader_manifest.json").exists():
            loader = RedoxLoader.resume(data_dir, store)
            print(f"data plane resumed at epoch {loader.resume_point[0]} "
                  f"step {loader.resume_point[1]}")
        else:
            loader = RedoxLoader.from_spec(spec, store)
    stager = None
    if args.device_path != "naive":
        from ..core.device import DeviceStager  # deferred: jax-heavy

        stager = DeviceStager(depth=args.stage_depth,
                              use_kernel=(args.device_path == "gather"))
        mode = f"device path: {args.device_path} (depth {args.stage_depth}"
        if args.device_path == "gather":
            mode += f", {'interpret' if stager.interpret else 'compiled'} gather"
        print(mode + ")")

    def epoch_batches(epoch):
        if args.device_path == "gather":
            return loader.epoch_device(epoch, stager)
        if args.device_path == "stage":
            return stager.stream(loader.epoch_async(epoch))
        return loader.epoch_async(epoch)

    ckpt = AsyncCheckpointer(workdir / "ckpt")
    start = latest_step(workdir / "ckpt")
    if start:
        state = restore_checkpoint(workdir / "ckpt", start, state)
        print(f"resumed from step {start}")

    if cfg.frontend != "none":
        print("note: stub-frontend arch — launcher trains on token records "
              "projected through the frontend stub (see launch/specs.py)")

    step = int(start or 0)
    run_steps = 0
    suspended = False
    epoch, t0 = (loader.resume_point or (0, 0))[0], time.time()
    while step < args.steps and not suspended:
        for batch in epoch_batches(epoch):
            if step >= args.steps:
                break
            feed = {
                "tokens": jnp.asarray(batch["tokens"]),
                "targets": jnp.asarray(batch["targets"]),
                "loss_mask": jnp.asarray(batch["loss_mask"]),
            }
            if cfg.frontend == "frame":
                # stub frontend: embed tokens as one-hot-ish frames
                b, s = feed["tokens"].shape
                feed["frames"] = jax.nn.one_hot(
                    feed["tokens"] % cfg.frontend_dim, cfg.frontend_dim,
                    dtype=jnp.dtype(cfg.compute_dtype),
                )
                del feed["tokens"]
            elif cfg.frontend == "patch":
                b = feed["tokens"].shape[0]
                p = cfg.frontend_len
                feed["patch_embeds"] = jnp.zeros(
                    (b, p, cfg.frontend_dim), jnp.dtype(cfg.compute_dtype)
                )
                feed["targets"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.int32), feed["targets"]], axis=1
                )
                feed["loss_mask"] = jnp.concatenate(
                    [jnp.zeros((b, p), jnp.float32), feed["loss_mask"]], axis=1
                )
            state, metrics = step_fn(state, feed)
            step += 1
            run_steps += 1
            if step % 10 == 0 or step == 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/step:.2f}s/step)")
            if step % args.ckpt_every == 0:
                ckpt.save(step, state)
                if data_dir is not None:
                    # Replay-engine suspend is derived (shadow simulation),
                    # so the stream keeps flowing while this writes.
                    loader.suspend(data_dir)
            if args.suspend_after is not None and run_steps >= args.suspend_after:
                ckpt.save(step, state)
                loader.suspend(data_dir)
                suspended = True
                break
        epoch += 1
    ckpt.wait()
    elapsed = time.time() - t0
    if stager is not None:
        stager.close()
        d = stager.stats
        print(f"device path {args.device_path}: staged {d.steps} batches "
              f"({d.bytes_to_device / 1e6:.1f} MB to device), "
              f"overlap fraction {d.overlap_fraction:.2f}")
    if run_steps:
        toks = run_steps * spec.num_nodes * spec.batch_per_node * spec.seq_len
        print(f"throughput: {toks / max(elapsed, 1e-9):,.0f} tokens/sec "
              f"over {run_steps} step(s)")
    if args.data_server is not None:
        loader.close()
    if store is not None:
        store.close()
    if suspended:
        print(f"suspended after {run_steps} step(s) -> {data_dir}; "
              f"rerun with the same flags to continue")
    else:
        print(f"done: {step} steps in {elapsed:.0f}s; workdir={workdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
