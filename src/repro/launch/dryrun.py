import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before ANY other import (jax locks the
# device count on first init). Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from ..configs import SHAPES, list_archs  # noqa: E402
from .dryrun_lib import run_cell  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

"""Multi-pod dry-run driver (assignment deliverable (e)).

For every live (arch × shape) cell, lower + compile the appropriate step on
the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, print
memory_analysis / cost_analysis, and append a JSON record per cell to the
artifact file (incremental: already-recorded cells are skipped, so the
sweep is restartable).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only
"""


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--out", default="artifacts/dryrun.jsonl")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument(
        "--optimized", action="store_true",
        help="use the §Perf-optimized per-arch configs instead of the "
             "paper-faithful baseline recipe",
    )
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dryrun requires 512 emulated devices"

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if out_path.exists():
        for line in out_path.read_text().splitlines():
            r = json.loads(line)
            done.add((r["arch"], r["shape"], r["mesh"]))

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(make_production_mesh(multi_pod=False))
    if not args.single_pod_only:
        meshes.append(make_production_mesh(multi_pod=True))

    failures = 0
    with open(out_path, "a") as fh:
        for mesh in meshes:
            for arch in archs:
                for shape in shapes:
                    mesh_name = "x".join(f"{k}{v}" for k, v in mesh.shape.items())
                    key = (arch, shape, mesh_name)
                    if key in done:
                        continue
                    if args.optimized:
                        from .dryrun_lib import optimized_run_cfg

                        rc, cfg_ov = optimized_run_cfg(arch)
                        res = run_cell(arch, shape, mesh, run_cfg=rc, cfg_override=cfg_ov)
                    else:
                        res = run_cell(arch, shape, mesh)
                    rec = res.to_json()
                    fh.write(json.dumps(rec) + "\n")
                    fh.flush()
                    tag = res.status if res.status != "ok" else (
                        f"ok  {res.compile_s:6.1f}s  flops/dev={res.flops_per_device:.3e}"
                        f"  coll/dev={res.collectives['total_bytes']:.3e}B"
                        f"  temp/dev={res.memory['temp_size_in_bytes']/1e9:.2f}GB"
                    )
                    print(f"[{mesh_name}] {arch} × {shape}: {tag}", flush=True)
                    if res.status == "FAILED":
                        failures += 1
                        print("   ", res.error[:500], flush=True)
    print(f"dry-run complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
