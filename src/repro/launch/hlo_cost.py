"""Structural HLO cost model: walk the call graph, multiply loop bodies.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scanned-layer models by ~num_layers and chunked attention by
~num_chunks. This parser recovers exact totals from ``compiled.as_text()``:

* FLOPs        — every ``dot`` op: 2 x |result| x contraction size
                 (matmuls are >99% of model FLOPs; elementwise ignored);
* bytes        — operand + result bytes at fusion boundaries (top-level ops
                 of each computation; fusion internals are on-chip), an
                 HBM-traffic proxy;
* collectives  — result bytes of all-gather / all-reduce / reduce-scatter /
                 all-to-all / collective-permute, per kind;

all scaled through the call graph: ``while`` bodies multiply by their
``known_trip_count`` (emitted by XLA for lax.scan), fusions/calls by 1,
conditionals by max over branches.
"""

from __future__ import annotations

import math
import re

__all__ = ["hlo_costs"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_ARRAY_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|c64|c128|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}\d.]+)+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true_computation|false_computation)=%([\w.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _result_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur, body = None, []
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and ("->" in line):
                cur = m.group(1)
                body = []
        else:
            if line.strip() == "}":
                comps[cur] = body
                cur = None
            else:
                body.append(line)
    return comps


def _parse_op(line: str):
    """Returns (name, result_type, opcode, rest) or None."""
    m = _DEF_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    m2 = _OP_RE.match(rhs)
    if not m2:
        return None
    return name, m2.group(1), m2.group(2), m2.group(3)


def _dot_flops(result_type, rest, shapes) -> float:
    rd = _result_dims(result_type)
    if rd is None:
        return 0.0
    out_elems = math.prod(rd[0]) if rd[0] else 1
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    ops = re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0] + ")")
    k = 1
    if mc and ops:
        lhs_shape = shapes.get(ops[0])
        if lhs_shape:
            for d in (mc.group(1).split(",") if mc.group(1) else []):
                di = int(d)
                if di < len(lhs_shape):
                    k *= lhs_shape[di]
    return 2.0 * out_elems * k


def hlo_costs(text: str) -> dict:
    """Whole-module costs with loop multipliers applied."""
    comps = _split_computations(text)

    # Pass 1: per-computation self costs + child edges.
    info: dict[str, dict] = {}
    for cname, lines in comps.items():
        shapes: dict[str, list[int]] = {}
        flops = 0.0
        bytes_ = 0.0
        bytes_dots = 0.0
        coll: dict[str, float] = {}
        children: list[tuple[str, float]] = []
        is_fusion_body = cname.startswith("fused_") or cname.startswith("wrapped_")
        dtypes: dict[str, str] = {}
        src: dict[str, str] = {}  # convert/copy/bitcast -> first operand

        def _resolved_dtype(op_name: str) -> str:
            # Look through convert/copy/bitcast chains: the HBM read happens
            # at the SOURCE dtype (bf16 weights widened to f32 by XLA:CPU,
            # int8 KV caches dequantised before the dot — both fuse into the
            # operand fetch on TPU).
            seen = 0
            while op_name in src and seen < 8:
                op_name = src[op_name]
                seen += 1
            return dtypes.get(op_name, "f32")

        for line in lines:
            parsed = _parse_op(line)
            if parsed is None:
                continue
            name, rtype, opcode, rest = parsed
            rd = _result_dims(rtype)
            shapes[name] = rd[0] if rd else []
            dtypes[name] = rd[1] if rd else "f32"
            if opcode in ("convert", "copy", "bitcast"):
                ops = re.findall(r"%([\w.\-]+)", rest)
                if ops:
                    src[name] = ops[0]
            if opcode == "dot" or opcode == "convolution":
                flops += _dot_flops(rtype, rest, shapes)
                # dot-anchored HBM traffic: lhs + rhs + out (the TPU-
                # realistic proxy — elementwise chains fuse into epilogues)
                b = _shape_bytes(rtype)
                for op_name in re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0] + ")")[:2]:
                    shp = shapes.get(op_name)
                    if shp is not None:
                        n = 1
                        for dd in shp:
                            n *= dd
                        b += n * _DTYPE_BYTES.get(_resolved_dtype(op_name), 4)
                bytes_dots += b
            base = opcode.split("-start")[0]
            if base in _COLLECTIVES:
                b = _shape_bytes(rtype)
                coll[base] = coll.get(base, 0.0) + b
                bytes_dots += b  # collectives read+write HBM too
            # HBM upper bound: result bytes of top-level ops at CPU-backend
            # fusion granularity (finer than TPU -> overestimates)
            if not is_fusion_body and opcode not in ("parameter", "constant", "tuple",
                                                     "get-tuple-element", "bitcast"):
                bytes_ += _shape_bytes(rtype)
            # call edges
            if opcode == "while":
                trip = 1.0
                mt = _TRIP_RE.search(line)
                if mt:
                    trip = float(mt.group(1))
                mb = re.search(r"body=%([\w.\-]+)", line)
                if mb:
                    children.append((mb.group(1), trip))
                mcond = _COND_RE.search(line)
                if mcond:
                    children.append((mcond.group(1), trip + 1))
            elif opcode == "conditional":
                branches = _BRANCHES_RE.search(line)
                names = []
                if branches:
                    names = re.findall(r"%([\w.\-]+)", branches.group(1))
                names += _TF_RE.findall(line)
                # one branch executes; charge the max later via equal weight 1/n
                for n in names:
                    children.append((n, 1.0 / max(len(names), 1)))
            else:
                for cn in _CALLS_RE.findall(line):
                    children.append((cn, 1.0))
        info[cname] = dict(
            flops=flops, bytes=bytes_, bytes_dots=bytes_dots, coll=coll,
            children=children,
        )

    # Pass 2: bottom-up totals (memoised DFS).
    memo: dict[str, dict] = {}

    def total(cname: str, stack=()) -> dict:
        if cname in memo:
            return memo[cname]
        if cname not in info or cname in stack:
            return {"flops": 0.0, "bytes": 0.0, "bytes_dots": 0.0, "coll": {}}
        node = info[cname]
        f, b, bd = node["flops"], node["bytes"], node["bytes_dots"]
        c = dict(node["coll"])
        for child, mult in node["children"]:
            sub = total(child, stack + (cname,))
            f += sub["flops"] * mult
            b += sub["bytes"] * mult
            bd += sub["bytes_dots"] * mult
            for k, v in sub["coll"].items():
                c[k] = c.get(k, 0.0) + v * mult
        res = {"flops": f, "bytes": b, "bytes_dots": bd, "coll": c}
        memo[cname] = res
        return res

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: the computation not called by anyone
        called = {c for v in info.values() for c, _ in v["children"]}
        candidates = [c for c in info if c not in called]
        entry = candidates[-1] if candidates else next(iter(info))
    out = total(entry)
    out["coll_total"] = float(sum(out["coll"].values()))
    return out
