"""Data-service launcher: K concurrent training jobs over ONE chunk cache.

    PYTHONPATH=src python -m repro.launch.data_service --jobs 3 --epochs 1

Builds a synthetic chunk store (or reuses ``--store-dir``), opens one
session per job on a :class:`repro.service.DataService`, drives the shared
round-robin pump, and reports per-job + aggregate sharing stats: with K
co-scheduled jobs the bytes actually read from storage stay close to 1x the
dataset while the protocol-level demand is ~K x (every duplicate chunk read
is served from the shared residency).
"""

from __future__ import annotations

import argparse
import contextlib
import tempfile
import time
from pathlib import Path

from ..core import ChunkStore
from ..data import SyntheticTokenDataset
from ..service import DataService


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--num-docs", type=int, default=512)
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--engine", choices=["replay", "step", "per_access"],
                    default="replay")
    ap.add_argument("--co-refill", action="store_true",
                    help="steer refill tie-breaks toward shareable chunks")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="shared residency cap in MB (default: unbounded)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-dir", type=Path, default=None,
                    help="reuse/build the chunk store here instead of a tmpdir")
    ap.add_argument("--resume-data", type=Path, default=None, metavar="DIR",
                    help="service suspend/resume directory: an existing "
                         "service_manifest.json there is resumed mid-epoch; "
                         "--suspend-after writes one")
    ap.add_argument("--suspend-after", type=int, default=None, metavar="N",
                    help="suspend all sessions to --resume-data after N pump "
                         "steps and exit (restart with the same flags to "
                         "continue byte-identically)")
    args = ap.parse_args(argv)
    if args.suspend_after is not None and args.resume_data is None:
        ap.error("--suspend-after requires --resume-data DIR")
    if args.resume_data is not None and args.store_dir is None:
        ap.error("--resume-data requires --store-dir (the snapshot references "
                 "the persistent chunk store)")

    with contextlib.ExitStack() as stack:
        if args.store_dir is None:
            tmp = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="redox_svc_")
            )
            root = Path(tmp) / "chunks"
        else:
            root = args.store_dir
        if not (root / "plan.npz").exists():
            ds = SyntheticTokenDataset(
                args.num_docs, vocab_size=32000, mean_len=args.seq_len,
                seed=args.seed + 5,
            )
            ds.build_store(
                root, args.chunk_size,
                num_slots=args.groups * args.chunk_size, seed=args.seed,
            )
        store = ChunkStore.open(root)
        limit = int(args.cache_mb * 1e6) if args.cache_mb else None
        resuming = (
            args.resume_data is not None
            and (args.resume_data / "service_manifest.json").exists()
        )
        if resuming:
            svc = DataService.resume(args.resume_data, store)
            start_epoch = min(
                s.loader.resume_point[0] for s in svc.sessions
                if s.loader.resume_point is not None
            )
            print(f"resumed {len(svc.sessions)} session(s) mid-epoch "
                  f"{start_epoch} from {args.resume_data}")
        else:
            svc = DataService(store, cache_limit_bytes=limit,
                              co_refill=args.co_refill)
            for j in range(args.jobs):
                svc.open_session(
                    f"job{j}", seed=args.seed + 10 * j + 1,
                    batch_per_node=args.batch, seq_len=args.seq_len,
                    engine=args.engine,
                )
            start_epoch = 0
        steps = {s.job_id: 0 for s in svc.sessions}
        demand = 0
        pumped = 0
        suspended = False
        t0 = time.perf_counter()
        for epoch in range(start_epoch, args.epochs):
            pump = svc.co_epoch(epoch)
            for job_id, _ in pump:
                steps[job_id] += 1
                pumped += 1
                if args.suspend_after is not None and pumped >= args.suspend_after:
                    suspended = True
                    break
            if suspended:
                pump.close()
                out = svc.suspend(args.resume_data)
                print(f"suspended after {pumped} pump step(s) -> {out}; "
                      f"rerun with the same flags to continue")
                break
            # NodeStats are per-epoch (reset at the next begin_epoch), so
            # fold each epoch's protocol-level demand in as it completes.
            demand += sum(
                n.stats.disk_bytes for s in svc.sessions for n in s.cluster.nodes
            )
        wall = time.perf_counter() - t0

        rep = svc.stats_report()
        agg = rep["aggregate"]
        print(f"{args.jobs} jobs x {args.epochs} epoch(s), engine={args.engine}, "
              f"co_refill={args.co_refill}: {sum(steps.values())} steps "
              f"in {wall:.2f}s")
        for job_id in sorted(rep["per_job"]):
            st = rep["per_job"][job_id]
            print(f"  {job_id}: steps={steps[job_id]} "
                  f"physical={st['physical_bytes']/1e6:.1f}MB "
                  f"shared={st['shared_bytes']/1e6:.1f}MB "
                  f"(hits={st['shared_hits']}, co_refill={st['co_refill_hits']})")
        saved = agg["shared_bytes"]
        print(f"aggregate: demand={demand/1e6:.1f}MB "
              f"physical={agg['physical_bytes']/1e6:.1f}MB "
              f"dup_loads_avoided={agg['dup_loads_avoided']} "
              f"saved={saved/1e6:.1f}MB "
              f"peak_cache={agg['peak_cache_bytes']/1e6:.1f}MB "
              f"evictions={agg['evictions']}")
        svc.close()
        store.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
