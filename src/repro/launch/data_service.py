"""Data-service launcher: K concurrent training jobs over ONE chunk cache.

    PYTHONPATH=src python -m repro.launch.data_service --jobs 3 --epochs 1

Builds a synthetic chunk store (or reuses ``--store-dir``), opens one
session per job on a :class:`repro.service.DataService`, drives the shared
round-robin pump, and reports per-job + aggregate sharing stats: with K
co-scheduled jobs the bytes actually read from storage stay close to 1x the
dataset while the protocol-level demand is ~K x (every duplicate chunk read
is served from the shared residency).

With ``--serve SOCKET`` it instead exposes the service out-of-process:
trainers in other OS processes open sessions over the unix socket
(``repro.launch.train --data-server SOCKET``) and batches flow through
per-session shared-memory rings (DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import contextlib
import tempfile
import time
from pathlib import Path

from ..core import ChunkStore, SessionSpec
from ..core.storage import make_backend
from ..data import SyntheticTokenDataset
from ..obs import attribution, format_report, trace
from ..service import AdmissionControl, DataService
from ..service.transport import DataServiceServer
from ..service.transport.server import service_metrics
from .cli import (
    add_autotune_args,
    add_data_plane_args,
    add_elastic_args,
    add_obs_args,
    resolve_resume_dir,
)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--groups", type=int, default=8)
    add_data_plane_args(ap, batch=16, seq_len=64, num_docs=512)
    ap.add_argument("--co-refill", action="store_true",
                    help="steer refill tie-breaks toward shareable chunks")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="shared residency cap in MB (default: unbounded)")
    ap.add_argument("--eviction", choices=["belady", "lru"], default="belady",
                    help="cache eviction under --cache-mb: clairvoyant "
                         "Belady/MIN over the merged claim schedule "
                         "(default) or plain least-recently-claimed")
    add_autotune_args(ap)
    ap.add_argument("--admission-mb-s", type=float, default=None,
                    metavar="MB/S",
                    help="storage bandwidth budget for open_session "
                         "admission control (default with --autotune: the "
                         "calibrated bandwidth of the chosen backend)")
    ap.add_argument("--admission-mode", choices=["reject", "queue"],
                    default=None,
                    help="what an over-budget open_session gets: an "
                         "immediate AdmissionRejected, or queueing until "
                         "capacity frees (enables admission control)")
    ap.add_argument("--store-dir", type=Path, default=None,
                    help="reuse/build the chunk store here instead of a tmpdir")
    add_elastic_args(ap)
    add_obs_args(ap)
    ap.add_argument("--serve", metavar="SOCKET", default=None,
                    help="serve sessions out-of-process on this unix socket "
                         "instead of pumping local jobs (trainers connect "
                         "with repro.launch.train --data-server SOCKET)")
    return ap


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    resume_dir = resolve_resume_dir(ap, args.resume_data, None)
    if args.suspend_after is not None and resume_dir is None:
        ap.error("--suspend-after requires --resume-data DIR")
    if resume_dir is not None and args.store_dir is None:
        ap.error("--resume-data requires --store-dir (the snapshot references "
                 "the persistent chunk store)")
    if args.serve is not None and args.suspend_after is not None:
        ap.error("--suspend-after is driven over the socket when serving "
                 "(RedoxClient.suspend)")
    tracer = trace.enable(args.trace_capacity) if args.trace else None

    with contextlib.ExitStack() as stack:
        if args.store_dir is None:
            tmp = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="redox_svc_")
            )
            root = Path(tmp) / "chunks"
        else:
            root = args.store_dir
        if not (root / "plan.npz").exists():
            ds = SyntheticTokenDataset(
                args.num_docs, vocab_size=args.vocab_size or 32000,
                mean_len=args.seq_len, seed=args.seed + 5,
            )
            ds.build_store(
                root, args.chunk_size,
                num_slots=args.groups * args.chunk_size, seed=args.seed,
                codec=args.codec, bands=args.bands,
            ).close()
        limit = int(args.cache_mb * 1e6) if args.cache_mb else None
        tuned_bw = None
        fidelity = args.fidelity
        if args.autotune:
            from .. import autotune

            _, choice = autotune.tune_store(
                root,
                compute_per_step_s=args.compute_per_step,
                num_steps=max(args.num_docs // max(args.batch, 1), 1),
                memory_limit_bytes=(
                    int(args.autotune_memory_mb * 1e6)
                    if args.autotune_memory_mb is not None else None
                ),
            )
            print(f"autotune: {choice.describe()}")
            tuned_bw = choice.model.disk_bw
            if args.backend is None:
                kw = {"readahead": choice.readahead} if choice.readahead else {}
                store = ChunkStore.open(
                    root, backend=make_backend(choice.backend, **kw)
                )
            else:
                store = ChunkStore.open(root, backend=args.backend)
            if limit is None:
                limit = choice.cache_limit_bytes
            if fidelity is None:
                fidelity = choice.fidelity
        else:
            store = ChunkStore.open(root, backend=args.backend or "vfs")
        admission = None
        if args.admission_mb_s is not None or args.admission_mode is not None:
            bw = (
                args.admission_mb_s * 1e6
                if args.admission_mb_s is not None else tuned_bw
            )
            if bw is None:
                ap.error("--admission-mode needs --admission-mb-s or "
                         "--autotune (to measure the bandwidth budget)")
            if args.compute_per_step <= 0:
                ap.error("admission control needs --compute-per-step > 0 "
                         "(predicted read rate is bytes per compute-second)")
            admission = AdmissionControl(
                bandwidth_bytes_per_s=bw,
                compute_per_step_s=args.compute_per_step,
                mode=args.admission_mode or "reject",
            )
            print(f"admission: {admission.mode} over "
                  f"{bw / 1e6:.1f} MB/s budget")
        resuming = (
            resume_dir is not None
            and (resume_dir / "service_manifest.json").exists()
        )
        if resuming:
            svc = DataService.resume(resume_dir, store)
            start_epoch = min(
                s.loader.resume_point[0] for s in svc.sessions
                if s.loader.resume_point is not None
            )
            print(f"resumed {len(svc.sessions)} session(s) mid-epoch "
                  f"{start_epoch} from {resume_dir}")
        else:
            svc = DataService(store, cache_limit_bytes=limit,
                              co_refill=args.co_refill,
                              eviction=args.eviction,
                              admission=admission)
            start_epoch = 0

        if args.serve is not None:
            # Serve mode: sessions come from the clients (or the resumed
            # snapshot), not from --jobs.
            with DataServiceServer(svc, args.serve) as server:
                print(f"serving on {args.serve} "
                      f"({len(svc.sessions)} resumed session(s), "
                      f"ctrl-c to stop; scrape with the metrics/trace_dump "
                      f"RPCs)", flush=True)
                with contextlib.suppress(KeyboardInterrupt):
                    server.serve_forever()
                if args.metrics:
                    print(server.metrics.exposition(), end="")
            if tracer is not None:
                out = tracer.dump(args.trace)
                print(f"trace: {len(tracer)} events -> {out}")
            store.close()
            return 0

        if not resuming:
            for j in range(args.jobs):
                svc.open_session(f"job{j}", SessionSpec(
                    policy=args.policy, seed=args.seed + 10 * j + 1,
                    batch_per_node=args.batch, seq_len=args.seq_len,
                    engine=args.engine, fidelity=fidelity,
                ))
        steps = {s.job_id: 0 for s in svc.sessions}
        demand = 0
        pumped = 0
        suspended = False
        t0 = time.perf_counter()
        for epoch in range(start_epoch, args.epochs):
            pump = svc.co_epoch(epoch)
            for job_id, _ in pump:
                steps[job_id] += 1
                pumped += 1
                if args.suspend_after is not None and pumped >= args.suspend_after:
                    suspended = True
                    break
            if suspended:
                pump.close()
                out = svc.suspend(resume_dir)
                print(f"suspended after {pumped} pump step(s) -> {out}; "
                      f"rerun with the same flags to continue")
                break
            # NodeStats are per-epoch (reset at the next begin_epoch), so
            # fold each epoch's protocol-level demand in as it completes.
            demand += sum(
                n.stats.disk_bytes for s in svc.sessions for n in s.cluster.nodes
            )
        wall = time.perf_counter() - t0

        rep = svc.stats_report()
        agg = rep["aggregate"]
        print(f"{args.jobs} jobs x {args.epochs} epoch(s), engine={args.engine}, "
              f"co_refill={args.co_refill}: {sum(steps.values())} steps "
              f"in {wall:.2f}s")
        for job_id in sorted(rep["per_job"]):
            st = rep["per_job"][job_id]
            print(f"  {job_id}: steps={steps[job_id]} "
                  f"physical={st['physical_bytes']/1e6:.1f}MB "
                  f"shared={st['shared_bytes']/1e6:.1f}MB "
                  f"(hits={st['shared_hits']}, co_refill={st['co_refill_hits']})")
        saved = agg["shared_bytes"]
        svc_rec = rep["service"]
        print(f"aggregate: demand={demand/1e6:.1f}MB "
              f"physical={agg['physical_bytes']/1e6:.1f}MB "
              f"dup_loads_avoided={agg['dup_loads_avoided']} "
              f"saved={saved/1e6:.1f}MB "
              f"peak_cache={svc_rec['peak_cache_bytes']/1e6:.1f}MB "
              f"evictions={svc_rec['evictions']} "
              f"({svc_rec['eviction']}, bypass={svc_rec['cache_bypass']})")
        if args.metrics:
            reg = service_metrics(svc)
            for j, st in svc.residency.per_job_stats.items():
                reg.register_stats(
                    "service", lambda st=st: st, labels={"job": str(j)}
                )
            print(reg.exposition(), end="")
        if tracer is not None:
            out = tracer.dump(args.trace)
            print(f"trace: {len(tracer)} events ({tracer.dropped} dropped) "
                  f"-> {out}; open in the Perfetto UI or chrome://tracing")
            print(format_report(
                attribution(tracer.events(), wall_s=wall),
                measured_wall_s=wall,
            ))
            trace.disable()
        svc.close()
        store.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
