"""Serving launcher: batched prefill + decode for any decode-capable arch.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --new-tokens 16

``--list-archs`` prints every registered arch with its serving capability
and exits 0 (the sanctioned way to probe for encoder-only archs from
scripts); asking to *serve* an encoder-only arch remains exit code 1.
``--seed`` makes the random prompts and parameter init reproducible.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs, reduced
from ..models import build_model, split_params
from ..train.train_step import build_decode_step, build_prefill_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(),
                    help="arch to serve (required unless --list-archs)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for prompts and parameter init")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--list-archs", action="store_true",
                    help="list archs and their serving capability, exit 0")
    args = ap.parse_args()

    if args.list_archs:
        # Explicit listing: encoder-only archs are information, not misuse.
        for arch in list_archs():
            kind = "decode" if get_config(arch).supports_decode() else "encoder-only"
            print(f"{arch}: {kind}")
        return 0
    if args.arch is None:
        ap.error("--arch is required unless --list-archs is given")

    cfg = get_config(args.arch)
    if not cfg.supports_decode():
        print(f"{args.arch} is encoder-only: no autoregressive serving path")
        return 1
    if not args.full:
        cfg = reduced(cfg)
    model = build_model(cfg)
    values, _ = split_params(model.init(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.new_tokens
    prefill = jax.jit(build_prefill_step(model, max_len=max_len))
    decode = jax.jit(build_decode_step(model), donate_argnums=1)

    inputs = {"tokens": prompts}
    if cfg.frontend == "patch":
        inputs["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_len, cfg.frontend_dim),
            jnp.dtype(cfg.compute_dtype),
        )
    t0 = time.time()
    logits, cache = prefill(values, inputs)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    print(f"prefill {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")
    pos0 = args.prompt_len + (cfg.frontend_len if cfg.frontend == "patch" else 0)
    out = [tok]
    t0 = time.time()
    for t in range(args.new_tokens - 1):
        logits, cache = decode(values, cache, tok, jnp.int32(pos0 + t))
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    print(f"decoded {args.new_tokens} tok/seq in {dt:.2f}s "
          f"({args.new_tokens*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("first sequence:", jnp.concatenate(out, 1)[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
