"""Roofline analysis from the dry-run artifacts (assignment deliverable (g)).

For each (arch × shape × mesh) cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]
                      (global collective bytes / (chips·link_bw) — equal,
                       since per-device bytes are uniform under SPMD)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE; fwd-only shapes use
2·N·D), the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term,
and the projected roofline fraction
``(MODEL_FLOPS_time) / max(terms)`` — the score §Perf hillclimbs.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--inp artifacts/dryrun.jsonl]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from ..configs import get_config, get_shape

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9, "hbm_bytes": 16e9}

__all__ = ["analyze", "load_rows", "main", "HW"]


def load_rows(path: str | Path) -> list[dict]:
    return [json.loads(l) for l in Path(path).read_text().splitlines()]


def _chips(mesh_name: str) -> int:
    n = 1
    for part in mesh_name.split("x"):
        n *= int("".join(c for c in part if c.isdigit()))
    return n


def model_flops(arch: str, shape_name: str) -> float:
    """Global useful FLOPs per step: 6·N_active·D train, 2·N_active·D serve."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1  # decode: one new token
    return 2.0 * n * tokens


def _advice(dom: str, row: dict, ratio: float) -> str:
    arch, shape = row["arch"], row["shape"]
    if dom == "collective":
        if "moe" in get_config(arch).family:
            return "shard_map all-to-all dispatch / wider EP to cut gather-based dispatch collectives"
        return "reduce TP degree for this model size (use model axis as DP) or overlap grads (bf16 all-reduce)"
    if dom == "memory":
        if row["step_kind"] == "serve_decode":
            return "decode is KV-bandwidth-bound: quantize KV cache (int8) or batch more requests"
        return "increase arithmetic intensity: larger per-device batch or fuse elementwise chains"
    if ratio < 0.5:
        return "compute-bound but >2x padded/remat waste: relax remat policy or fix causal over-compute (Pallas flash kernel)"
    return "compute-bound near useful peak: scale batch or accept"


def analyze(rows: list[dict]) -> list[dict]:
    out = []
    for r in rows:
        if r["status"] != "ok":
            out.append(
                dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"], status=r["status"])
            )
            continue
        chips = _chips(r["mesh"])
        t_comp = r["flops_per_device"] / HW["peak_flops"]
        if r["step_kind"] == "serve_decode":
            # Decode streams its whole working set (weights + KV cache =
            # the argument bytes) once per token; the dot-anchored proxy
            # over-counts dequant-fused operands across fusion boundaries.
            t_mem = r["memory"]["argument_size_in_bytes"] / HW["hbm_bw"]
        else:
            t_mem = r["bytes_per_device"] / HW["hbm_bw"]
        t_coll = r["collectives"]["total_bytes"] / HW["ici_bw"]
        dom = max(
            ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
            key=lambda kv: kv[1],
        )[0]
        mf = model_flops(r["arch"], r["shape"])
        mf_dev = mf / chips
        ratio = mf_dev / r["flops_per_device"] if r["flops_per_device"] else 0.0
        t_useful = mf_dev / HW["peak_flops"]
        frac = t_useful / max(t_comp, t_mem, t_coll, 1e-30)
        out.append(
            dict(
                arch=r["arch"],
                shape=r["shape"],
                mesh=r["mesh"],
                status="ok",
                step_kind=r["step_kind"],
                compute_s=t_comp,
                memory_s=t_mem,
                collective_s=t_coll,
                dominant=dom,
                model_flops_global=mf,
                useful_ratio=ratio,
                roofline_fraction=frac,
                temp_gb=r["memory"]["temp_tpu_adjusted"] / 1e9,
                args_gb=r["memory"]["argument_size_in_bytes"] / 1e9,
                fits_hbm=(
                    r["memory"]["temp_tpu_adjusted"]
                    + r["memory"]["argument_size_in_bytes"]
                )
                <= HW["hbm_bytes"],
                advice=_advice(dom, r, ratio),
            )
        )
    return out


def to_markdown(an: list[dict], mesh_filter: str | None = None) -> str:
    lines = [
        "| arch | shape | mesh | comp s | mem s | coll s | dominant | 6ND/HLO | roofline frac | fits 16GB | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in an:
        if mesh_filter and a.get("mesh") != mesh_filter:
            continue
        if a["status"] != "ok":
            lines.append(
                f"| {a['arch']} | {a['shape']} | {a.get('mesh','-')} | — | — | — | {a['status']} | — | — | — | — |"
            )
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['compute_s']:.3g} | {a['memory_s']:.3g} | {a['collective_s']:.3g} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} | {a['roofline_fraction']:.3f} "
            f"| {'yes' if a['fits_hbm'] else 'NO'} | {a['advice']} |"
        )
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inp", default="artifacts/dryrun.jsonl")
    ap.add_argument("--out", default="artifacts/roofline.json")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load_rows(args.inp)
    an = analyze(rows)
    Path(args.out).write_text(json.dumps(an, indent=1))
    print(to_markdown(an, args.mesh))
    return 0


if __name__ == "__main__":
    sys.exit(main())
