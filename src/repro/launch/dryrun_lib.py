"""Dry-run machinery: lower + compile every (arch × shape × mesh) cell.

No parameters are ever materialised: ``jax.eval_shape`` traces
``Model.init`` (Param is a registered pytree) so even kimi-k2-1t costs only
metadata. Each cell produces:

* ``compiled.memory_analysis()``  — proves the per-device footprint fits;
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline;
* collective bytes parsed from the post-SPMD HLO text (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute operand
  sizes) — cost_analysis does not expose these.

Import this module only AFTER device count is configured (launch/dryrun.py
sets XLA_FLAGS before any jax import; tests use small emulated meshes).
"""

from __future__ import annotations

import dataclasses
import re
import time

import jax
import jax.numpy as jnp

from ..configs import RunConfig, cell_status, get_config, get_shape
from ..models import build_model, split_params
from ..models.transformer import Model
from ..optim.optimizers import make_optimizer
from ..parallel import sharding as shd
from ..parallel.axes import ShardingRules, sharding_ctx
from ..train.train_step import build_train_step, build_decode_step
from .specs import decode_input_specs, train_input_specs

__all__ = ["run_cell", "default_run_cfg", "CellResult", "HW"]

# TPU v5e constants (assignment §ROOFLINE):
HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
    "hbm_bytes": 16e9,      # per chip
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")

_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
    "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}


def _tuple_or_operand_bytes(line: str) -> int:
    """Sum array byte-sizes of the *result* of a collective op line."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective result bytes by op kind, from post-SPMD HLO."""
    out: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        b = _tuple_or_operand_bytes(line)
        out[kind] = out.get(kind, 0) + b
        count += 1
    out["total_bytes"] = float(sum(v for k, v in out.items() if k != "num_ops"))
    out["num_ops"] = count
    return out


_CONVERT_RE = re.compile(
    r"=\s*f32\[([\d,]+)\][^=]*(?:fusion|convert)\(%param(?:\.\d+)?\b"
)


def cpu_convert_overhead(hlo_text: str) -> float:
    """Bytes of hoisted bf16->f32 weight converts (CPU-backend artifact).

    XLA:CPU has no native bf16 matmul, so it converts weight parameters to
    f32 and hoists the converts out of the layer scan — inflating temp by
    ~2x params/device. TPU executes bf16 dots natively, so the dry-run
    reports ``temp_tpu_adjusted = temp - this``.
    """
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry and line.strip() == "}":
            break
        if not in_entry:
            continue
        m = _CONVERT_RE.search(line)
        if m:
            n = 1
            for d in m.group(1).split(","):
                n *= int(d)
            total += 4.0 * n
    return total


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str
    step_kind: str = ""
    compile_s: float = 0.0
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collectives: dict | None = None
    memory: dict | None = None
    param_count: float = 0.0
    error: str = ""
    raw_cost_analysis: dict | None = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def default_run_cfg(arch: str) -> RunConfig:
    """Per-arch RunConfig overrides needed to fit / balance (DESIGN.md §5).

    These are the *baseline* (paper-faithful recipe) settings whose roofline
    is recorded for every cell; the §Perf hillclimb changes them per cell.
    """
    if arch == "kimi-k2-1t-a32b":
        # 1T params on 512 x 16 GB: bf16 params + factored opt WITHOUT an
        # fp32 master (4 TB > global HBM), FSDP everywhere, full remat,
        # sequence-parallel residuals (activations / 16).
        return RunConfig(
            optimizer="adafactor",
            fsdp=True,
            remat="full",
            master_fp32=False,
            seq_parallel=True,
            microbatch=4,
        )
    if arch in ("starcoder2-15b", "llava-next-34b", "phi3-medium-14b", "deepseek-7b"):
        return RunConfig(optimizer="adamw", zero1=True, remat="full", microbatch=8,
                         seq_parallel=True)
    if arch == "deepseek-moe-16b":
        return RunConfig(optimizer="adamw", zero1=True, remat="full", microbatch=8)
    return RunConfig(optimizer="adamw", zero1=True, remat="full", microbatch=4)


def optimized_run_cfg(arch: str) -> tuple[RunConfig, object]:
    """§Perf-optimized (beyond-paper) per-arch configs: (RunConfig, cfg_override).

    Derived from the hillclimb log (EXPERIMENTS §Perf / artifacts/
    perf_iters.jsonl): sub-2B models go pure-DP; 7-34B dense go ZeRO-3+DP;
    MoEs keep EP (kimi via shard_map a2a); zamba additionally tunes the SSD
    chunk. Regenerate the optimized table with
    ``python -m repro.launch.dryrun --optimized``.
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if arch in ("tinyllama-1.1b", "xlstm-350m", "hubert-xlarge"):
        return RunConfig(zero1=True, remat="dots", parallelism="dp_only"), None
    if arch == "zamba2-1.2b":
        return (
            RunConfig(zero1=True, remat="dots", parallelism="dp_only"),
            _dc.replace(cfg, ssm_chunk=64),
        )
    if arch in ("deepseek-7b", "phi3-medium-14b", "starcoder2-15b", "llava-next-34b"):
        return RunConfig(zero1=True, fsdp=True, remat="full", parallelism="dp_only"), None
    if arch == "deepseek-moe-16b":
        return RunConfig(zero1=True, fsdp=True, remat="full", parallelism="dp_only"), None
    if arch == "kimi-k2-1t-a32b":
        return (
            RunConfig(optimizer="adafactor", fsdp=True, remat="full",
                      master_fp32=False, seq_parallel=True, microbatch=4),
            _dc.replace(cfg, moe_impl="a2a"),
        )
    return default_run_cfg(arch), None


def _abstract_state(model: Model, optimizer):
    params_sds = jax.eval_shape(lambda: model.init(0))
    values_sds, axes = split_params(params_sds)
    opt_sds = jax.eval_shape(optimizer.init, values_sds)
    state_sds = {
        "values": values_sds,
        "opt": opt_sds,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return state_sds, axes


def _state_shardings(mesh, run_cfg, state_sds, axes, optimizer):
    values_sh = shd.param_shardings(mesh, run_cfg, state_sds["values"], axes)
    opt_sh = shd.opt_state_shardings(
        mesh, run_cfg, state_sds["opt"], optimizer.state_axes(axes)
    )
    return {"values": values_sh, "opt": opt_sh, "step": shd.replicated(mesh)}


def _mesh_name(mesh) -> str:
    return "x".join(f"{k}{v}" for k, v in mesh.shape.items())


def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    run_cfg: RunConfig | None = None,
    cfg_override=None,
    want_hlo: bool = False,
) -> CellResult | tuple[CellResult, str]:
    """Lower + compile one cell; returns roofline raw terms.

    ``cfg_override`` lets §Perf iterations vary ModelConfig knobs
    (ssm_chunk, attn_chunk, ...) without touching the registry.
    """
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = _mesh_name(mesh)
    status = cell_status(cfg, shape)
    if status != "run":
        return CellResult(arch, shape_name, mesh_name, status)

    run_cfg = run_cfg or default_run_cfg(arch)
    model = build_model(cfg)
    optimizer = make_optimizer(run_cfg)
    rules = ShardingRules(mesh, shd.activation_rules(mesh, run_cfg))
    t0 = time.time()
    try:
        if shape.kind == "train":
            step = build_train_step(model, run_cfg, optimizer)
            state_sds, axes = _abstract_state(model, optimizer)
            state_sh = _state_shardings(mesh, run_cfg, state_sds, axes, optimizer)
            batch_sds = train_input_specs(cfg, shape)
            batch_sh = shd.batch_shardings(mesh, batch_sds, run_cfg)
            with mesh, sharding_ctx(rules):
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, shd.replicated(mesh)),
                    donate_argnums=0,
                ).lower(state_sds, batch_sds)
                compiled = lowered.compile()
            step_kind = "train_step"
        elif shape.kind == "prefill":
            state_sds, axes = _abstract_state(model, optimizer)
            values_sds = state_sds["values"]
            values_sh = shd.param_shardings(mesh, run_cfg, values_sds, axes)
            batch_sds = train_input_specs(cfg, shape)
            batch_sh = shd.batch_shardings(mesh, batch_sds, run_cfg)

            def prefill_logits(values, inputs):
                logits, _, _ = model.forward(values, inputs)
                return logits[:, -1:]

            with mesh, sharding_ctx(rules):
                lowered = jax.jit(
                    prefill_logits,
                    in_shardings=(values_sh, batch_sh),
                    out_shardings=shd.replicated(mesh),
                ).lower(values_sds, batch_sds)
                compiled = lowered.compile()
            step_kind = "serve_prefill"
        else:  # decode
            state_sds, axes = _abstract_state(model, optimizer)
            values_sds = state_sds["values"]
            values_sh = shd.param_shardings(mesh, run_cfg, values_sds, axes)
            b = shape.global_batch
            cache_sds = model.cache_specs(b, shape.seq_len)
            cache_rules = ShardingRules(mesh, shd.activation_rules(mesh, run_cfg))
            cache_sh = jax.tree.map(
                lambda sds, ax: cache_rules.sharding_for(ax, sds.shape),
                cache_sds,
                model.cache_axes(b, shape.seq_len, tp=mesh.shape.get("model")),
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            dec_sds = decode_input_specs(cfg, shape)
            dec_sh = shd.batch_shardings(mesh, dec_sds, run_cfg)
            decode = build_decode_step(model)
            with mesh, sharding_ctx(rules):
                lowered = jax.jit(
                    decode,
                    in_shardings=(values_sh, cache_sh, dec_sh["tokens"], dec_sh["cache_pos"]),
                    out_shardings=(shd.replicated(mesh), cache_sh),
                    donate_argnums=1,
                ).lower(
                    values_sds, cache_sds, dec_sds["tokens"], dec_sds["cache_pos"]
                )
                compiled = lowered.compile()
            step_kind = "serve_decode"
    except Exception as e:  # a failing cell is a bug; record it loudly
        return CellResult(
            arch, shape_name, mesh_name, "FAILED", error=f"{type(e).__name__}: {e}"
        )

    compile_s = time.time() - t0
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    memory = {
        k: float(getattr(mem, k, 0.0))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    hlo = compiled.as_text()
    memory["cpu_convert_overhead"] = cpu_convert_overhead(hlo)
    memory["temp_tpu_adjusted"] = max(
        memory["temp_size_in_bytes"] - memory["cpu_convert_overhead"], 0.0
    )
    # Structural costs: cost_analysis() counts while bodies once; hlo_costs
    # multiplies by known_trip_count (exact for scanned layers/chunks).
    from .hlo_cost import hlo_costs

    structural = hlo_costs(hlo)
    coll = {k: float(v) for k, v in structural["coll"].items()}
    coll["total_bytes"] = structural["coll_total"]
    coll["raw_single_body"] = parse_collectives(hlo)["total_bytes"]
    result = CellResult(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        status="ok",
        step_kind=step_kind,
        compile_s=compile_s,
        flops_per_device=float(structural["flops"]),
        # headline: dot-anchored traffic (TPU fusion granularity);
        # upper bound (CPU fusion granularity) kept in memory dict
        bytes_per_device=float(structural["bytes_dots"]),
        collectives=coll,
        memory=memory,
        param_count=float(cfg.param_count()),
    )
    result.memory["bytes_upper_bound"] = float(structural["bytes"])
    result.raw_cost_analysis = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    if want_hlo:
        return result, hlo
    return result
