"""Input specs (ShapeDtypeStruct stand-ins) and dummy inputs per (arch, shape).

The dry-run lowers against these; smoke tests materialise the dummy
variants. For ``vlm`` the sequence is [patch positions | text]; for
``frame`` (audio) every position is a frame embedding and targets are the
masked-unit labels (HuBERT objective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

__all__ = ["train_input_specs", "decode_input_specs", "dummy_train_inputs", "dummy_tokens"]

_F32 = jnp.float32
_I32 = jnp.int32


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Specs for train/prefill (full-sequence) steps."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "patch":
        p = cfg.frontend_len
        assert p < s, (p, s)
        return {
            "tokens": sds((b, s - p), _I32),
            "patch_embeds": sds((b, p, cfg.frontend_dim), jnp.bfloat16),
            "targets": sds((b, s), _I32),
            "loss_mask": sds((b, s), _F32),
        }
    if cfg.frontend == "frame":
        return {
            "frames": sds((b, s, cfg.frontend_dim), jnp.bfloat16),
            "targets": sds((b, s), _I32),
            "loss_mask": sds((b, s), _F32),
        }
    return {
        "tokens": sds((b, s), _I32),
        "targets": sds((b, s), _I32),
        "loss_mask": sds((b, s), _F32),
    }


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), _I32),
        "cache_pos": jax.ShapeDtypeStruct((), _I32),
    }


def dummy_tokens(rng: np.random.Generator, b: int, s: int, vocab: int) -> np.ndarray:
    return rng.integers(0, vocab, (b, s)).astype(np.int32)


def dummy_train_inputs(cfg: ModelConfig, b: int, s: int, seed: int = 0) -> dict:
    """Materialised random inputs matching train_input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    if cfg.frontend == "patch":
        p = cfg.frontend_len
        return {
            "tokens": jnp.asarray(dummy_tokens(rng, b, s - p, cfg.vocab_size)),
            "patch_embeds": jnp.asarray(
                rng.normal(size=(b, p, cfg.frontend_dim)), cfg.compute_dtype
            ),
            "targets": jnp.asarray(dummy_tokens(rng, b, s, cfg.vocab_size)),
            "loss_mask": jnp.asarray(
                np.concatenate(
                    [np.zeros((b, p), np.float32), np.ones((b, s - p), np.float32)], 1
                )
            ),
        }
    if cfg.frontend == "frame":
        mask = (rng.random((b, s)) < 0.08).astype(np.float32)  # HuBERT-style 8%
        return {
            "frames": jnp.asarray(
                rng.normal(size=(b, s, cfg.frontend_dim)), cfg.compute_dtype
            ),
            "targets": jnp.asarray(dummy_tokens(rng, b, s, cfg.vocab_size)),
            "loss_mask": jnp.asarray(mask),
        }
    toks = dummy_tokens(rng, b, s + 1, cfg.vocab_size)
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }
