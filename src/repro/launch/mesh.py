"""Production mesh construction (assignment MULTI-POD DRY-RUN §1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary meshes for tests (e.g. (2, 4) on 8 emulated devices)."""
    return jax.make_mesh(shape, axes)
