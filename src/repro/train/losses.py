"""Training losses: causal LM CE (+ z-loss) and MoE auxiliary loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lm_loss"]


def lm_loss(logits, targets, loss_mask, *, aux=0.0, aux_weight=0.0, z_weight=1e-4):
    """Masked token-level cross entropy in fp32.

    logits: (B, S, V); targets: (B, S) int32; loss_mask: (B, S) float.
    Works for causal LM (mask = valid next-token positions) and for the
    encoder masked-prediction objective (mask = masked positions).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    denom = jnp.maximum(loss_mask.sum(), 1.0)
    ce = (nll * loss_mask).sum() / denom
    zl = ((logz * logz) * loss_mask).sum() / denom
    total = ce + z_weight * zl + aux_weight * aux
    return total, {"ce": ce, "z_loss": zl, "aux": jnp.asarray(aux, jnp.float32)}
