"""train_step / serve_step builders (the jitted top-level programs).

``build_train_step`` returns the function lowered by both the real training
loop (examples/train_lm.py) and the multi-pod dry-run. Structure:

    loss(values) -> grads -> [cast for all-reduce] -> clip -> optimizer

Microbatching (gradient accumulation) wraps the loss/grad in a lax.scan
over microbatch slices — the standard way to trade HBM for steps at large
global batch. The gradient all-reduce over the data axis is implicit in
GSPMD (params replicated over "data" unless FSDP); casting grads to
``grad_allreduce_dtype`` before they cross the data axis halves collective
bytes when set to bfloat16 (§Perf lever).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig
from ..models.transformer import Model
from ..optim.optimizers import Optimizer, clip_by_global_norm
from .losses import lm_loss

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step", "init_train_state"]


def init_train_state(model: Model, optimizer: Optimizer, seed: int = 0):
    from ..models.common import split_params

    values, _ = split_params(model.init(seed))
    return {"values": values, "opt": optimizer.init(values), "step": jnp.zeros((), jnp.int32)}


def build_train_step(model: Model, run_cfg: RunConfig, optimizer: Optimizer):
    cfg = model.cfg

    def loss_fn(values, batch):
        logits, aux, _ = model.forward(values, batch, remat=run_cfg.remat)
        loss, metrics = lm_loss(
            logits,
            batch["targets"],
            batch["loss_mask"],
            aux=aux,
            aux_weight=cfg.router_aux_weight if cfg.moe_num_experts else 0.0,
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(values, batch):
        if run_cfg.microbatch and run_cfg.microbatch > 1:
            k = run_cfg.microbatch
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            acc_dt = (
                jnp.dtype(run_cfg.grad_allreduce_dtype)
                if run_cfg.grad_allreduce_dtype
                else None
            )

            def acc(carry, mb):
                (l_acc, g_acc) = carry
                (l, m), g = grad_fn(values, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (l_acc + l, g), m

            # Accumulate in the param dtype (bf16 for big models) unless a
            # grad dtype is forced — an fp32 accumulator alone is 16 GB/dev
            # for kimi-k2-1t.
            zeros = jax.tree.map(
                lambda v: jnp.zeros(v.shape, acc_dt or v.dtype), values
            )
            (loss, grads), metrics = jax.lax.scan(acc, (jnp.zeros(()), zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(values, batch)
        return loss, grads, metrics

    def train_step(state, batch):
        loss, grads, metrics = compute_grads(state["values"], batch)
        if run_cfg.grad_allreduce_dtype:
            dt = jnp.dtype(run_cfg.grad_allreduce_dtype)
            grads = jax.tree.map(lambda g: g.astype(dt), grads)
        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        new_values, new_opt = optimizer.update(
            grads, state["opt"], state["values"], state["step"]
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return (
            {"values": new_values, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


# ------------------------------------------------------------------ serving
def build_prefill_step(model: Model, max_len: int):
    """Full-prompt pass that builds the decode cache (sized to max_len)."""
    cfg = model.cfg

    def prefill(values, inputs):
        logits, _, caches = model.forward(values, inputs, want_cache=True)
        sized = []
        for (kind, count), cache in zip(cfg.segments(), caches):
            if kind in ("attn_mlp", "attn_dense_moe", "attn_moe", "shared_attn"):
                if kind == "shared_attn":
                    cache = jax.tree.map(lambda t: t[None], cache)
                k, v = cache["k"], cache["v"]  # (n, B, S, KVH, D)
                s = k.shape[2]
                s_c = min(max_len, cfg.window) if cfg.window else max_len
                tgt = lambda t: jnp.zeros(
                    t.shape[:2] + (s_c,) + t.shape[3:], t.dtype
                )
                if s_c >= s:
                    k_c = jax.lax.dynamic_update_slice_in_dim(tgt(k), k, 0, axis=2)
                    v_c = jax.lax.dynamic_update_slice_in_dim(tgt(v), v, 0, axis=2)
                else:
                    # rotating window layout: slot = position % window
                    pos = jnp.arange(s - s_c, s)
                    slots = jnp.mod(pos, s_c)
                    k_c = tgt(k).at[:, :, slots].set(k[:, :, pos])
                    v_c = tgt(v).at[:, :, slots].set(v[:, :, pos])
                if cfg.kv_cache_dtype == "int8":
                    from ..models.attention import quantize_kv

                    kq, ks = quantize_kv(k_c)
                    vq, vs = quantize_kv(v_c)
                    sized.append({"k": kq, "k_scale": ks, "v": vq, "v_scale": vs})
                else:
                    sized.append({"k": k_c, "v": v_c})
            else:
                sized.append(cache)  # recurrent state is already the cache
        return logits[:, -1:], sized

    return prefill


def build_decode_step(model: Model):
    def decode(values, caches, tokens, cache_pos):
        return model.decode_step(values, caches, tokens, cache_pos)

    return decode
