"""Jit'd wrapper for chunk_gather."""

from __future__ import annotations

import functools

import jax

from .chunk_gather import chunk_gather as _kernel_call

__all__ = ["chunk_gather"]


@functools.partial(jax.jit, static_argnames=("pad_id", "interpret"))
def chunk_gather(chunk_tokens, record_lens, indices, *, pad_id=0, interpret=True):
    return _kernel_call(
        chunk_tokens, record_lens, indices, pad_id=pad_id, interpret=interpret
    )
