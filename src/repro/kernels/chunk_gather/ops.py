"""Jit'd wrappers for chunk_gather / chunk_gather_train.

``interpret=None`` (the default) auto-detects the backend: the kernel is
compiled on TPU and interpreted elsewhere (``kernels.common``).
"""

from __future__ import annotations

import functools

import jax

from .chunk_gather import chunk_gather as _kernel_call
from .chunk_gather import chunk_gather_train as _train_call

__all__ = ["chunk_gather", "chunk_gather_train"]


@functools.partial(jax.jit, static_argnames=("pad_id", "interpret"))
def chunk_gather(chunk_tokens, record_lens, indices, *, pad_id=0, interpret=None):
    return _kernel_call(
        chunk_tokens, record_lens, indices, pad_id=pad_id, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("seq_len", "pad_id", "interpret"))
def chunk_gather_train(
    chunk_tokens, record_lens, indices, *, seq_len, pad_id=0, interpret=None
):
    return _train_call(
        chunk_tokens, record_lens, indices,
        seq_len=seq_len, pad_id=pad_id, interpret=interpret,
    )
