"""chunk_gather: device-side redirected batch assembly (the paper's
technique as a Pallas kernel; DESIGN.md §2 "Where a Pallas kernel is
warranted").

Redox's host protocol batches whole chunks into memory and *redirects* each
framework request to whatever record currently occupies the target slot.
On TPU the analogous hot loop is assembling the device batch: a chunk
buffer lands in HBM as one contiguous DMA (the batched read), and the
per-step redirection table picks `B` variable-length records to form the
padded (B, L) token grid + loss mask.

The kernel streams one output row per grid step: the redirection index is
a scalar-prefetch operand (known before the body runs), so the BlockSpec
index_map selects which chunk-slot row to DMA into VMEM — the gather
happens in the *data movement*, not in compute. Lengths produce the mask.

Layout notes for real TPUs: records are padded to the (8,128)-tile lane
width by the host packer; the slot row arrives VMEM-resident; the scalar
table lives in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["chunk_gather"]


def _kernel(idx_ref, len_ref, chunk_ref, tok_ref, mask_ref, *, pad_id):
    # chunk_ref block == the slot row selected by the index_map via the
    # scalar-prefetched redirection table; body only pads + masks.
    row = chunk_ref[0]  # (L,)
    i = pl.program_id(0)
    n = len_ref[idx_ref[i]]
    pos = jax.lax.broadcasted_iota(jnp.int32, row.shape, 0)
    valid = pos < n
    tok_ref[0] = jnp.where(valid, row, pad_id)
    mask_ref[0] = valid.astype(mask_ref.dtype)


def chunk_gather(
    chunk_tokens: jax.Array,  # (num_slots, L) int32, slot-padded records
    record_lens: jax.Array,   # (num_slots,) int32
    indices: jax.Array,       # (B,) int32 — the redirection table
    *,
    pad_id: int = 0,
    interpret: bool = True,
):
    """Returns (tokens (B, L) int32, mask (B, L) float32)."""
    num_slots, l = chunk_tokens.shape
    b = indices.shape[0]
    kernel = functools.partial(_kernel, pad_id=pad_id)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # indices, record_lens
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l), lambda i, idx, lens: (idx[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l), lambda i, idx, lens: (i, 0)),
            pl.BlockSpec((1, l), lambda i, idx, lens: (i, 0)),
        ],
    )
    tokens, mask = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, l), jnp.int32),
            jax.ShapeDtypeStruct((b, l), jnp.float32),
        ],
        interpret=interpret,
    )(indices, record_lens, chunk_tokens)
    return tokens, mask
