"""chunk_gather: device-side redirected batch assembly (the paper's
technique as a Pallas kernel; DESIGN.md §2 "Where a Pallas kernel is
warranted", §12 "Device-resident data path").

Redox's host protocol batches whole chunks into memory and *redirects* each
framework request to whatever record currently occupies the target slot.
On TPU the analogous hot loop is assembling the device batch: a chunk
buffer lands in HBM as one contiguous DMA (the batched read), and the
per-step redirection table picks `B` variable-length records to form the
padded (B, L) token grid + loss mask.

The kernel streams one output row per grid step: the redirection index is
a scalar-prefetch operand (known before the body runs), so the BlockSpec
index_map selects which chunk-slot row to DMA into VMEM — the gather
happens in the *data movement*, not in compute. Lengths produce the mask.

Two entry points:

* :func:`chunk_gather` — the raw gather: (tokens, mask) grids, the unit
  the parity suite sweeps.
* :func:`chunk_gather_train` — the fused training-batch assembly used by
  the :class:`~repro.core.device.DeviceStager`: one slot-row DMA yields
  the shifted ``tokens``/``targets`` pair *and* the target-aligned loss
  mask in a single pass, so the host ships one int32 slot buffer instead
  of three pre-assembled grids (~1/3 of the H2D bytes) and the grid
  assembly runs on-device, overlapped with the previous train step.

Layout notes for real TPUs: slot rows are padded to the (8,128)-tile lane
width by the host packer (``row_pad``); the slot row arrives VMEM-resident;
the scalar redirection/length tables live in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import resolve_interpret

__all__ = ["chunk_gather", "chunk_gather_train"]


def _kernel(idx_ref, len_ref, chunk_ref, tok_ref, mask_ref, *, pad_id):
    # chunk_ref block == the slot row selected by the index_map via the
    # scalar-prefetched redirection table; body only pads + masks.
    row = chunk_ref[0]  # (L,)
    i = pl.program_id(0)
    n = len_ref[idx_ref[i]]
    pos = jax.lax.broadcasted_iota(jnp.int32, row.shape, 0)
    valid = pos < n
    tok_ref[0] = jnp.where(valid, row, pad_id)
    mask_ref[0] = valid.astype(mask_ref.dtype)


def chunk_gather(
    chunk_tokens: jax.Array,  # (num_slots, L) int32, slot-padded records
    record_lens: jax.Array,   # (num_slots,) int32
    indices: jax.Array,       # (B,) int32 — the redirection table
    *,
    pad_id: int = 0,
    interpret: "bool | None" = None,
):
    """Returns (tokens (B, L) int32, mask (B, L) float32)."""
    num_slots, l = chunk_tokens.shape
    b = indices.shape[0]
    kernel = functools.partial(_kernel, pad_id=pad_id)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # indices, record_lens
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, l), lambda i, idx, lens: (idx[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l), lambda i, idx, lens: (i, 0)),
            pl.BlockSpec((1, l), lambda i, idx, lens: (i, 0)),
        ],
    )
    tokens, mask = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, l), jnp.int32),
            jax.ShapeDtypeStruct((b, l), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(indices, record_lens, chunk_tokens)
    return tokens, mask


def _train_kernel(
    idx_ref, len_ref, chunk_ref, tok_ref, tgt_ref, mask_ref, *, seq_len, pad_id
):
    # One slot-row DMA per grid step (index_map gather, as above); the body
    # fuses the next-token shift with the length mask: tokens = row[:S],
    # targets = row[1:S+1], loss over targets where the *target* position is
    # still inside the record.
    row = chunk_ref[0]  # (Lp,) — lane-padded slot row, Lp >= seq_len + 1
    i = pl.program_id(0)
    n = len_ref[idx_ref[i]]
    pos = jax.lax.broadcasted_iota(jnp.int32, (seq_len,), 0)
    tok = jax.lax.slice(row, (0,), (seq_len,))
    tgt = jax.lax.slice(row, (1,), (seq_len + 1,))
    tok_ref[0] = jnp.where(pos < n, tok, pad_id)
    tgt_ref[0] = jnp.where(pos + 1 < n, tgt, pad_id)
    mask_ref[0] = (pos + 1 < n).astype(mask_ref.dtype)


def chunk_gather_train(
    chunk_tokens: jax.Array,  # (num_slots, Lp) int32, slot-padded records
    record_lens: jax.Array,   # (num_slots,) int32, clipped to seq_len + 1
    indices: jax.Array,       # (B,) int32 — the redirection table
    *,
    seq_len: int,
    pad_id: int = 0,
    interpret: "bool | None" = None,
):
    """Fused redirected-gather + shift + mask: the (B, S) training triple.

    Returns ``(tokens (B, S) int32, targets (B, S) int32,
    loss_mask (B, S) float32)`` — exactly what ``RedoxLoader._assemble``
    builds on the host, produced on-device from one slot buffer.
    """
    num_slots, lp = chunk_tokens.shape
    assert lp >= seq_len + 1, (lp, seq_len)
    b = indices.shape[0]
    kernel = functools.partial(_train_kernel, seq_len=seq_len, pad_id=pad_id)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # indices, record_lens
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, lp), lambda i, idx, lens: (idx[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, seq_len), lambda i, idx, lens: (i, 0)),
            pl.BlockSpec((1, seq_len), lambda i, idx, lens: (i, 0)),
            pl.BlockSpec((1, seq_len), lambda i, idx, lens: (i, 0)),
        ],
    )
    tokens, targets, mask = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, seq_len), jnp.int32),
            jax.ShapeDtypeStruct((b, seq_len), jnp.int32),
            jax.ShapeDtypeStruct((b, seq_len), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(indices, record_lens, chunk_tokens)
    return tokens, targets, mask
