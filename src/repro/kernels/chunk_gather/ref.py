"""Pure-jnp oracles for chunk_gather / chunk_gather_train."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["chunk_gather_ref", "chunk_gather_train_ref"]


def chunk_gather_ref(chunk_tokens, record_lens, indices, *, pad_id=0):
    rows = chunk_tokens[indices]                   # (B, L)
    lens = record_lens[indices]                    # (B,)
    pos = jnp.arange(chunk_tokens.shape[1])[None, :]
    valid = pos < lens[:, None]
    return jnp.where(valid, rows, pad_id), valid.astype(jnp.float32)


def chunk_gather_train_ref(chunk_tokens, record_lens, indices, *, seq_len, pad_id=0):
    rows = chunk_tokens[indices]                   # (B, Lp)
    lens = record_lens[indices][:, None]           # (B, 1)
    pos = jnp.arange(seq_len)[None, :]
    tokens = jnp.where(pos < lens, rows[:, :seq_len], pad_id)
    targets = jnp.where(pos + 1 < lens, rows[:, 1 : seq_len + 1], pad_id)
    mask = (pos + 1 < lens).astype(jnp.float32)
    return tokens, targets, mask
