"""Pure-jnp oracle for chunk_gather."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["chunk_gather_ref"]


def chunk_gather_ref(chunk_tokens, record_lens, indices, *, pad_id=0):
    rows = chunk_tokens[indices]                   # (B, L)
    lens = record_lens[indices]                    # (B,)
    pos = jnp.arange(chunk_tokens.shape[1])[None, :]
    valid = pos < lens[:, None]
    return jnp.where(valid, rows, pad_id), valid.astype(jnp.float32)
