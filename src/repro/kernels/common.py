"""Shared kernel-dispatch conventions.

Every kernel package exposes ``interpret=None`` on its public ``ops``
wrapper: ``None`` means *auto* — compile the Pallas kernel when the
runtime actually is a TPU, fall back to the interpreter everywhere else
(CPU CI, local dev). Passing an explicit bool always wins, so tests can
pin interpret mode and real deployments can force compilation.
"""

from __future__ import annotations

import jax

__all__ = ["resolve_interpret", "round_up"]


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``n``."""
    return -(-n // multiple) * multiple


def resolve_interpret(interpret: "bool | None" = None) -> bool:
    """Resolve the tri-state ``interpret`` flag to a concrete bool.

    ``None``  -> auto: compiled on TPU backends, interpreter elsewhere.
    ``bool``  -> taken literally.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
