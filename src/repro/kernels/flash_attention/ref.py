"""Pure-jnp oracle for flash_attention (naive full-matrix attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, window=0):
    """q/k/v: (BH, S, D). fp32 softmax, output in q.dtype."""
    bh, s, d = q.shape
    scale = d**-0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (window) produce uniform probs; zero them like the kernel
    any_valid = mask.any(axis=1)[None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
