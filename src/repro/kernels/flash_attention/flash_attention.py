"""Flash attention forward, Pallas/TPU (FlashAttention [arXiv:2205.14135],
adapted to the TPU grid model).

TPU adaptation (DESIGN.md §2): instead of CUDA thread-block tiling, the
kernel exploits the *sequential minor-most grid dimension* on TPU — the
(batch·head, q_block, kv_block) grid runs kv_blocks in order, so the online
-softmax running state (m, l, acc) lives in VMEM scratch that persists
across kv steps; the output block is written once, on the last kv step.
Block shapes are MXU-aligned (q/kv blocks multiples of 128 on real shapes;
tests sweep smaller shapes in interpret mode).

GQA is handled OUTSIDE the kernel (k/v are pre-expanded per q-head group by
ops.py — on real TPUs one would instead loop q-head groups per kv head to
avoid the HBM expansion; noted as a further optimization).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import resolve_interpret

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal, window, sm_scale, kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    bq, d = q.shape
    bk = k.shape[0]

    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * sm_scale  # (bq, bk)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)  # guard fully-masked rows (window)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v
    ).astype(jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention_fwd(
    q: jax.Array,  # (BH, S, D)
    k: jax.Array,  # (BH, S, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: "bool | None" = None,
) -> jax.Array:
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, sm_scale=sm_scale, kv_blocks=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m: running max
            pltpu.VMEM((block_q,), jnp.float32),      # l: running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # acc: running numerator
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
