"""Jit'd public wrapper for the flash-attention kernel (GQA layout).

``interpret=None`` (default) auto-detects the backend: compiled on TPU,
interpreted elsewhere (``kernels.common``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd

__all__ = ["flash_attention", "flash_attention_gqa"]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
                    interpret=None):
    """(BH, S, D) attention via the Pallas kernel."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


def flash_attention_gqa(q, k, v, *, causal=True, window=0, **kw):
    """(B, S, H, D) x (B, S, KVH, D) GQA convenience wrapper."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    k = jnp.repeat(k, groups, axis=2)
    v = jnp.repeat(v, groups, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = flash_attention(fold(q), fold(k), fold(v), causal=causal, window=window, **kw)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
