"""Ref-vs-kernel parity and throughput harness for every Pallas kernel.

The xformers idiom (see PAPERS.md and the ``test_mem_eff_attention`` /
``triton/softmax`` exemplars): each kernel declares a *shape grid* and a
*per-dtype tolerance table*, a case generator materialises deterministic
inputs for every (shape, dtype) cell, and one checker compares the kernel
against its pure-jnp oracle under a scale-normalised max-error metric.
``tests/test_kernel_parity.py`` sweeps the full grid as the correctness
gate; ``benchmarks/device_path.py`` reuses the same cases for the
throughput tables, so the benchmarked shapes are exactly the verified
ones.

All entry points accept ``interpret=None`` (auto: compiled on TPU,
interpreted elsewhere — ``kernels.common``), so the same sweep runs
compiled on real hardware and interpreted in CPU CI.
"""

from __future__ import annotations

import dataclasses
import time
import zlib

import jax.numpy as jnp
import numpy as np

from .chunk_gather.ops import chunk_gather, chunk_gather_train
from .chunk_gather.ref import chunk_gather_ref, chunk_gather_train_ref
from .common import round_up
from .decode_attention.ops import decode_attention
from .decode_attention.ref import decode_attention_ref
from .flash_attention.ops import flash_attention
from .flash_attention.ref import attention_ref
from .ssd_scan.ops import ssd_scan
from .ssd_scan.ref import ssd_scan_ref

__all__ = [
    "KERNELS",
    "KernelCase",
    "check_case",
    "iter_cases",
    "measure_case",
    "round_up",
]


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One cell of a kernel's parity grid."""

    kernel: str     # registry key
    shape: tuple    # kernel-specific shape tuple (see KERNELS[...]["shapes"])
    dtype: str      # jnp dtype name

    @property
    def name(self) -> str:
        dims = "x".join(str(d) for d in self.shape)
        return f"{self.kernel}[{dims}]{self.dtype}"


# Per-kernel shape grids + per-dtype tolerances (scale-normalised max
# error, see _max_err). The integer gathers are exact by construction.
KERNELS: dict[str, dict] = {
    "flash_attention": {
        # (bh, s, d, causal)
        "shapes": [
            (2, 128, 32, True), (2, 128, 32, False),
            (4, 256, 64, True), (4, 256, 64, False),
            (3, 192, 64, True),
            (1, 512, 128, True),
        ],
        "quick_shapes": [(2, 128, 32, True)],
        "tols": {"float32": 2e-5, "bfloat16": 2e-2},
    },
    "decode_attention": {
        # (b, h, kvh, s, d)
        "shapes": [
            (2, 8, 2, 512, 64),
            (1, 4, 4, 256, 32),
            (3, 16, 4, 1024, 128),
        ],
        "quick_shapes": [(1, 4, 4, 256, 32)],
        "tols": {"float32": 2e-5, "bfloat16": 2e-2},
    },
    "ssd_scan": {
        # (bh, s, p, n, chunk)
        "shapes": [
            (4, 256, 64, 16, 64),
            (2, 128, 32, 32, 32),
            (1, 512, 64, 64, 128),
        ],
        "quick_shapes": [(2, 128, 32, 32, 32)],
        "tols": {"float32": 2e-4, "bfloat16": 5e-2},
    },
    "chunk_gather": {
        # (num_slots, L, B)
        "shapes": [(64, 128, 16), (32, 256, 8), (16, 64, 32), (128, 512, 4)],
        "quick_shapes": [(64, 128, 16)],
        "tols": {"int32": 0.0},
    },
    "chunk_gather_train": {
        # (num_slots, seq_len, B) — slot rows lane-padded like the packer
        "shapes": [(64, 128, 16), (32, 100, 8), (16, 64, 32)],
        "quick_shapes": [(64, 128, 16)],
        "tols": {"int32": 0.0},
    },
}


def iter_cases(kernels=None, *, quick: bool = False) -> list[KernelCase]:
    out = []
    for kernel, spec in KERNELS.items():
        if kernels is not None and kernel not in kernels:
            continue
        shapes = spec["quick_shapes" if quick else "shapes"]
        for shape in shapes:
            for dtype in spec["tols"]:
                out.append(KernelCase(kernel, shape, dtype))
    return out


# ---------------------------------------------------------------- inputs
def make_inputs(case: KernelCase, seed: int = 0) -> tuple:
    # zlib.crc32, not hash(): stable across processes (PYTHONHASHSEED).
    rng = np.random.default_rng((seed, zlib.crc32(case.kernel.encode()), *case.shape))
    dt = jnp.dtype(case.dtype)
    k = case.kernel
    if k == "flash_attention":
        bh, s, d, _ = case.shape
        return tuple(jnp.asarray(rng.normal(size=(bh, s, d)), dt) for _ in range(3))
    if k == "decode_attention":
        b, h, kvh, s, d = case.shape
        q = jnp.asarray(rng.normal(size=(b, h, d)), dt)
        ck = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dt)
        cv = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dt)
        mask = jnp.asarray(rng.random((b, s)) < 0.75)
        return q, ck, cv, mask
    if k == "ssd_scan":
        bh, s, p, n, _ = case.shape
        x = jnp.asarray(rng.normal(size=(bh, s, p)), dt)
        dts = jnp.asarray(rng.random((bh, s)) * 0.5 + 0.01, jnp.float32)
        a = jnp.asarray(-rng.random((bh, 1)) * 2 - 0.1, jnp.float32)
        b_ = jnp.asarray(rng.normal(size=(bh, s, n)), dt)
        c = jnp.asarray(rng.normal(size=(bh, s, n)), dt)
        return x, dts, a, b_, c
    if k == "chunk_gather":
        slots, length, batch = case.shape
        ct = jnp.asarray(rng.integers(1, 1000, (slots, length)), jnp.int32)
        lens = jnp.asarray(rng.integers(1, length + 1, (slots,)), jnp.int32)
        idx = jnp.asarray(rng.integers(0, slots, (batch,)), jnp.int32)
        return ct, lens, idx
    if k == "chunk_gather_train":
        slots, seq_len, batch = case.shape
        lp = round_up(seq_len + 1, 128)
        lens = rng.integers(1, seq_len + 2, (slots,))
        ct = np.zeros((slots, lp), np.int32)
        for i, n in enumerate(lens):
            ct[i, :n] = rng.integers(1, 1000, n)
        idx = jnp.asarray(rng.integers(0, slots, (batch,)), jnp.int32)
        return jnp.asarray(ct), jnp.asarray(lens, jnp.int32), idx
    raise ValueError(f"unknown kernel {k!r}")


# ------------------------------------------------------------------- run
def run_kernel(case: KernelCase, inputs: tuple, *, interpret=None):
    k = case.kernel
    if k == "flash_attention":
        causal = case.shape[3]
        s = case.shape[1]
        bq = min(64, s)
        return flash_attention(
            *inputs, causal=causal, block_q=bq, block_k=bq, interpret=interpret
        )
    if k == "decode_attention":
        return decode_attention(*inputs, block_k=128, interpret=interpret)
    if k == "ssd_scan":
        chunk = case.shape[4]
        return ssd_scan(*inputs, chunk=chunk, interpret=interpret)
    if k == "chunk_gather":
        return chunk_gather(*inputs, interpret=interpret)
    if k == "chunk_gather_train":
        seq_len = case.shape[1]
        return chunk_gather_train(*inputs, seq_len=seq_len, interpret=interpret)
    raise ValueError(f"unknown kernel {k!r}")


def run_ref(case: KernelCase, inputs: tuple):
    k = case.kernel
    if k == "flash_attention":
        return attention_ref(*inputs, causal=case.shape[3])
    if k == "decode_attention":
        q, ck, cv, mask = inputs
        b, h, d = q.shape
        s, kvh = ck.shape[1], ck.shape[2]
        g = h // kvh
        qg = q.reshape(b * kvh, g, d)
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
        m = jnp.repeat(mask[:, None, :], kvh, 1).reshape(b * kvh, s)
        return decode_attention_ref(qg, fold(ck), fold(cv), m).reshape(b, h, d)
    if k == "ssd_scan":
        return ssd_scan_ref(*inputs)
    if k == "chunk_gather":
        return chunk_gather_ref(*inputs)
    if k == "chunk_gather_train":
        return chunk_gather_train_ref(*inputs, seq_len=case.shape[1])
    raise ValueError(f"unknown kernel {k!r}")


# ----------------------------------------------------------------- check
def _leaves(out):
    return list(out) if isinstance(out, (tuple, list)) else [out]


def _max_err(out, ref) -> float:
    """Scale-normalised max abs error, maxed over output leaves."""
    worst = 0.0
    for o, r in zip(_leaves(out), _leaves(ref)):
        o32 = np.asarray(o, np.float32)
        r32 = np.asarray(r, np.float32)
        scale = float(np.max(np.abs(r32))) + 1e-6
        worst = max(worst, float(np.max(np.abs(o32 - r32))) / scale)
    return worst


def check_case(case: KernelCase, *, interpret=None, seed: int = 0) -> dict:
    """Run one grid cell; returns {case, max_err, tol, ok}."""
    inputs = make_inputs(case, seed)
    out = run_kernel(case, inputs, interpret=interpret)
    ref = run_ref(case, inputs)
    err = _max_err(out, ref)
    tol = KERNELS[case.kernel]["tols"][case.dtype]
    return dict(case=case.name, max_err=err, tol=tol, ok=err <= tol)


# ------------------------------------------------------------- throughput
def _block(out) -> None:
    for leaf in _leaves(out):
        leaf.block_until_ready()


def measure_case(
    case: KernelCase, *, iters: int = 5, interpret=None, seed: int = 0
) -> dict:
    """Best-of-``iters`` wall time for kernel and oracle (post-warmup).

    ``out_mb`` sizes the assembled output, so ``mb_per_s`` reads as
    delivered bandwidth for the gather kernels and stays an honest
    relative number for the compute kernels. Interpret-mode timings only
    rank shapes against each other; absolute numbers are meaningful on a
    compiled backend.
    """
    inputs = make_inputs(case, seed)
    out = run_kernel(case, inputs, interpret=interpret)  # warmup/compile
    _block(out)
    ref = run_ref(case, inputs)
    _block(ref)
    out_bytes = sum(leaf.size * leaf.dtype.itemsize for leaf in _leaves(out))

    def best(fn) -> float:
        t = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            _block(fn())
            t = min(t, time.perf_counter() - t0)
        return t

    kernel_s = best(lambda: run_kernel(case, inputs, interpret=interpret))
    ref_s = best(lambda: run_ref(case, inputs))
    return dict(
        case=case.name,
        kernel_us=kernel_s * 1e6,
        ref_us=ref_s * 1e6,
        out_mb=out_bytes / 1e6,
        mb_per_s=out_bytes / 1e6 / kernel_s if kernel_s else 0.0,
    )
