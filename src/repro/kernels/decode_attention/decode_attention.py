"""GQA decode attention, Pallas/TPU — flash-decoding-style split-K
[FlashDecoding, arXiv:2311.01282-adjacent], TPU grid adaptation.

One query token attends to a long KV cache. The grid is
(batch · kv_head, kv_block); the kv_block axis is minor-most, hence
sequential on TPU, so the online-softmax state for the q-head *group* of
this kv head persists in VMEM scratch across kv blocks (the TPU analogue of
CUDA split-K + cross-SM reduction). Cache validity (rotating-window buffers
included) arrives as a precomputed boolean mask, so ring layouts need no
special-casing in-kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import resolve_interpret

__all__ = ["decode_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref, *, sm_scale, kv_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]          # (G, D) — the q-head group of this kv head
    k = k_ref[0]          # (bk, D)
    v = v_ref[0]
    valid = mask_ref[0]   # (bk,)

    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * sm_scale  # (G, bk)
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(p.astype(v.dtype), v).astype(
        jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def decode_attention_fwd(
    q: jax.Array,      # (B*KVH, G, D) one token's queries, grouped by kv head
    k: jax.Array,      # (B*KVH, S, D) cache keys
    v: jax.Array,      # (B*KVH, S, D)
    mask: jax.Array,   # (B*KVH, S) bool — slot validity (handles ring buffers)
    *,
    block_k: int = 512,
    interpret: "bool | None" = None,
) -> jax.Array:
    bkv, g, d = q.shape
    s = k.shape[1]
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    nk = s // block_k
    kernel = functools.partial(_kernel, sm_scale=1.0 / math.sqrt(d), kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(bkv, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v, mask)
