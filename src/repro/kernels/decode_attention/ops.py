"""Jit'd wrapper: model-layout (B,1,H,D) decode -> kernel layout and back.

``interpret=None`` (default) auto-detects the backend: compiled on TPU,
interpreted elsewhere (``kernels.common``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_fwd

__all__ = ["decode_attention"]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, cache_k, cache_v, mask, *, block_k=512, interpret=None):
    """q: (B, H, D); cache_k/v: (B, S, KVH, D); mask: (B, S) bool.

    Returns (B, H, D).
    """
    b, h, d = q.shape
    s, kvh = cache_k.shape[1], cache_k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d).reshape(b * kvh, g, d)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    m = jnp.repeat(mask[:, None, :], kvh, axis=1).reshape(b * kvh, s)
    out = decode_attention_fwd(
        qg, fold(cache_k), fold(cache_v), m, block_k=block_k, interpret=interpret
    )
    return out.reshape(b, kvh, g, d).reshape(b, h, d)
