"""Pure-jnp oracle for decode_attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]


def decode_attention_ref(q, k, v, mask):
    """q: (BKV, G, D); k/v: (BKV, S, D); mask: (BKV, S) bool."""
    d = q.shape[-1]
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d**-0.5)
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, :], p, 0.0)
    return jnp.einsum("bgs,bsd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
