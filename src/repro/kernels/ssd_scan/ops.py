"""Jit'd wrapper for the SSD scan kernel.

``interpret=None`` (default) auto-detects the backend: compiled on TPU,
interpreted elsewhere (``kernels.common``).
"""

from __future__ import annotations

import functools

import jax

from .ssd_scan import ssd_scan_fwd

__all__ = ["ssd_scan"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk=128, interpret=None):
    return ssd_scan_fwd(x, dt, a, b, c, chunk=chunk, interpret=interpret)
