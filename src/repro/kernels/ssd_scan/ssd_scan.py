"""Mamba-2 SSD chunked scan, Pallas/TPU [arXiv:2405.21060 §6].

The chunk axis is the minor-most grid dimension — sequential on TPU — so
the inter-chunk SSM state (head_dim x state) persists in VMEM scratch
across chunks while each grid step computes the quadratic intra-chunk term
on the MXU. This mirrors the CUDA SSD kernel's block decomposition, but
where the GPU version parallelises chunks across thread blocks and stitches
states with a separate scan kernel, the TPU version exploits grid
sequentiality to fuse the state recurrence into the same kernel — one pass,
no inter-kernel HBM round-trip for states.

Grid: (batch*heads, num_chunks). One (b,h) pair per major step keeps B/C
shared loads small; tests sweep shapes/dtypes vs the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import resolve_interpret

__all__ = ["ssd_scan_fwd"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, nc):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)    # (chunk, p)
    dt = dt_ref[0].astype(jnp.float32)  # (chunk,)
    a = a_ref[0, 0]                     # scalar decay rate (negative)
    bb = b_ref[0].astype(jnp.float32)   # (chunk, n)
    cc = c_ref[0].astype(jnp.float32)   # (chunk, n)
    chunk = x.shape[0]

    la = dt * a                          # per-step log decay (negative)
    seg = jnp.cumsum(la)                 # inclusive
    total = seg[-1]
    li = seg[:, None]
    lj = seg[None, :]
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = kpos <= qpos
    decay = jnp.where(mask, jnp.exp(li - lj), 0.0)
    cb = jnp.dot(cc, bb.T)               # (chunk, chunk)
    att = cb * decay * dt[None, :]
    y = jnp.dot(att, x)                  # intra-chunk
    # inter-chunk: y += C_i exp(seg_i) . state_in
    state = state_ref[...]               # (p, n)
    y = y + jnp.exp(seg)[:, None] * jnp.dot(cc, state.T)
    # state update
    wdec = jnp.exp(total - seg) * dt     # (chunk,)
    state_ref[...] = state * jnp.exp(total) + jnp.dot(
        (wdec[:, None] * x).T, bb
    )
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(
    x: jax.Array,   # (BH, S, P) head inputs
    dt: jax.Array,  # (BH, S) positive step sizes
    a: jax.Array,   # (BH, 1) negative per-head decay rate
    b: jax.Array,   # (BH, S, N)
    c: jax.Array,   # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: "bool | None" = None,
) -> jax.Array:
    bh, s, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, dt, a, b, c)
