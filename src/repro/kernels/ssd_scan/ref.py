"""Pure-jnp oracle for ssd_scan: the naive sequential SSM recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(x, dt, a, b, c):
    """x: (BH, S, P); dt: (BH, S); a: (BH, 1); b/c: (BH, S, N).

    h_t = exp(a*dt_t) h_{t-1} + dt_t * x_t b_t^T ;  y_t = h_t c_t
    """
    def per_bh(xb, dtb, ab, bb, cb):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = h * jnp.exp(ab[0] * dtt) + dtt * jnp.outer(xt, bt)
            return h, h @ ct

        p, n = xb.shape[-1], bb.shape[-1]
        h0 = jnp.zeros((p, n), jnp.float32)
        _, ys = jax.lax.scan(
            step, h0,
            (xb.astype(jnp.float32), dtb.astype(jnp.float32),
             bb.astype(jnp.float32), cb.astype(jnp.float32)),
        )
        return ys

    return jax.vmap(per_bh)(x, dt, a, b, c).astype(x.dtype)
