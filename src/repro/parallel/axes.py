"""Logical-axis sharding: annotation helpers usable from model code.

Model code names array dimensions with *logical* axes ("batch", "embed",
"heads", ...). A :class:`ShardingRules` context maps logical axes to mesh
axes, with two safety rails:

* divisibility — JAX rejects uneven shards, so a rule is applied to a dim
  only if the mesh-axis size divides it (otherwise that dim is replicated);
* no-mesh no-op — without an active context, ``shard()`` is the identity,
  so single-device smoke tests run the exact same model code.
"""

from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "sharding_ctx", "shard", "logical_spec", "current_ctx"]

_LOCAL = threading.local()


class ShardingRules:
    """logical axis name -> mesh axis (str), tuple of mesh axes, or None."""

    def __init__(self, mesh: jax.sharding.Mesh, rules: dict[str, object]):
        self.mesh = mesh
        self.rules = dict(rules)

    def _mesh_size(self, target) -> int:
        if target is None:
            return 1
        if isinstance(target, tuple):
            return math.prod(self.mesh.shape[t] for t in target)
        return self.mesh.shape[target]

    def spec_for(self, axes: tuple[str | None, ...], shape: tuple[int, ...]) -> P:
        assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
        parts, used = [], set()
        for name, dim in zip(axes, shape):
            target = self.rules.get(name) if name is not None else None
            if target is None:
                parts.append(None)
                continue
            flat = target if isinstance(target, tuple) else (target,)
            if any(t in used for t in flat):
                parts.append(None)  # a mesh axis may appear only once per spec
                continue
            if dim % self._mesh_size(target) != 0:
                parts.append(None)  # divisibility rail (replicate instead)
                continue
            used.update(flat)
            parts.append(target)
        return P(*parts)

    def sharding_for(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes, shape))


def current_ctx() -> ShardingRules | None:
    return getattr(_LOCAL, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(rules: ShardingRules):
    prev = current_ctx()
    _LOCAL.ctx = rules
    try:
        yield rules
    finally:
        _LOCAL.ctx = prev


def logical_spec(axes, shape) -> P:
    ctx = current_ctx()
    return P() if ctx is None else ctx.spec_for(axes, shape)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op without ctx)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding_for(axes, x.shape))
