from .axes import ShardingRules, current_ctx, logical_spec, shard, sharding_ctx

__all__ = ["ShardingRules", "current_ctx", "logical_spec", "shard", "sharding_ctx"]
