"""Mesh-level sharding policies: DP / TP / EP / FSDP / ZeRO-1.

One place defines how every logical axis maps onto the mesh:

* params   — TP over "model" (heads/kv/mlp/experts/vocab/inner dims);
             optionally FSDP ("embed" -> "data") for models that cannot
             replicate (kimi-k2-1t).
* opt state — ZeRO-1: same as params *plus* "embed" -> "data", so master
             weights and moments shard over the data axis even when params
             replicate (GSPMD then computes the update sharded and
             all-gathers the new params — exactly ZeRO-1 semantics).
* batch    — "batch" -> ("pod", "data") (the pod axis is plain extra DP).
* activations — annotated inline in model code via parallel.axes.shard.

The divisibility rail in ShardingRules silently replicates any dim a rule
cannot split evenly (e.g. hubert's 504-way vocab head, long_500k's batch=1).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import RunConfig
from .axes import ShardingRules

__all__ = [
    "param_rules",
    "activation_rules",
    "make_rules",
    "param_shardings",
    "opt_state_shardings",
    "batch_shardings",
    "replicated",
]


def _dp_axes(mesh, run_cfg: RunConfig | None = None) -> tuple:
    axes = ["pod", "data"]
    if run_cfg is not None and run_cfg.parallelism == "dp_only":
        axes.append("model")  # model axis joins the batch shards
    return tuple(a for a in axes if a in mesh.shape)


def param_rules(mesh, run_cfg: RunConfig) -> dict:
    if run_cfg.parallelism == "dp_only":
        # replicate params everywhere; the whole mesh is data-parallel
        rules = {k: None for k in (
            "vocab", "heads_flat", "kv_flat", "mlp", "experts", "inner_flat",
            "inner_heads", "embed2", "layers",
        )}
        rules["embed"] = _dp_axes(mesh) if run_cfg.fsdp else None
        return rules
    rules = {
        "vocab": "model",
        "heads_flat": "model",
        "kv_flat": "model",
        "mlp": "model",
        "experts": "model",
        "inner_flat": "model",
        "inner_heads": "model",
        "embed2": "model",
        "layers": None,
        "embed": _dp_axes(mesh) if run_cfg.fsdp else None,
    }
    return rules


def zero1_rules(mesh, run_cfg: RunConfig) -> dict:
    rules = dict(param_rules(mesh, run_cfg))
    rules["embed"] = _dp_axes(mesh, run_cfg)  # shard opt state over data even w/o fsdp
    return rules


def activation_rules(mesh, run_cfg: RunConfig) -> dict:
    if run_cfg.parallelism == "dp_only":
        dp = _dp_axes(mesh, run_cfg)
        rules = {k: None for k in (
            "embed_act", "seq_act", "heads", "heads_r", "seq_tp", "mlp",
            "experts", "vocab", "inner_heads", "kv_heads", "kv_seq",
            "inner_flat", "embed_state", "layers", "embed",
        )}
        rules["batch"] = dp
        return rules
    return {
        "batch": _dp_axes(mesh),
        "embed_act": None,
        "seq_act": "model" if run_cfg.seq_parallel else None,
        "heads": "model",
        "heads_r": None,
        "seq_tp": "model",
        "mlp": "model",
        "experts": "model",
        "vocab": "model",
        "inner_heads": "model",
        # cache/state axes (decode):
        "kv_heads": "model",
        "kv_seq": "model",
        "inner_flat": "model",
        "embed_state": "model",
        "layers": None,
        # param axes can appear in constraints too (e.g. logits):
        "embed": None,
    }


def make_rules(mesh, run_cfg: RunConfig) -> ShardingRules:
    """Rules for *activations* (installed as the sharding_ctx)."""
    return ShardingRules(mesh, activation_rules(mesh, run_cfg))


# ---------------------------------------------------------------- shardings
def param_shardings(mesh, run_cfg: RunConfig, values, axes_tree):
    rules = ShardingRules(mesh, param_rules(mesh, run_cfg))
    return jax.tree.map(
        lambda v, a: rules.sharding_for(a, v.shape),
        values,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def opt_state_shardings(mesh, run_cfg: RunConfig, opt_state, state_axes_tree):
    """ZeRO-1 shardings for the optimizer state pytree.

    ``state_axes_tree`` comes from ``Optimizer.state_axes`` (each optimizer
    declares the logical axes of its own state, incl. adafactor's factored
    vr/vc entries), so this is a plain leaf-wise rule application.
    """
    rules = ShardingRules(
        mesh, zero1_rules(mesh, run_cfg) if run_cfg.zero1 else param_rules(mesh, run_cfg)
    )
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    return jax.tree.map(
        lambda leaf, ax: rules.sharding_for(ax, leaf.shape),
        opt_state,
        state_axes_tree,
        is_leaf=lambda x: is_axes(x),
    )


def batch_shardings(mesh, batch_tree, run_cfg: RunConfig | None = None):
    dp = _dp_axes(mesh, run_cfg)

    def spec(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        import math

        dp_size = math.prod(mesh.shape[a] for a in dp)
        if shape[0] % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, batch_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
