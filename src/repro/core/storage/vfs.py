"""VFS backend: plain POSIX reads, like the paper's implementation.

Synchronous ``open``/``pread`` with a bounded file-descriptor cache, so
repeated ranged reads against the same chunk file (the per-file baseline
pattern, and :meth:`ChunkStore.read_file`) do not pay an ``open()`` per
call. ``os.pread`` keeps reads positionless, so one cached descriptor is
safe under concurrent use from the parallel backend's worker threads.

``latency_s`` optionally emulates per-operation storage head time (the
NAS access overhead of ``benchmarks/calibration.py``): local benchmark
files sit in the page cache, where every read is a microsecond memcpy, so
without it no storage stall exists to overlap. The sleep blocks exactly
like a real storage op (GIL released), which is what lets the parallel
backend's readahead demonstrate its overlap honestly on a local FS.
"""

from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from pathlib import Path

from .base import StorageBackend

__all__ = ["VFSBackend"]


class VFSBackend(StorageBackend):
    """Baseline backend: one syscall per read, descriptors cached (LRU)."""

    name = "vfs"

    def __init__(self, max_handles: int = 128, latency_s: float = 0.0):
        super().__init__()
        self.max_handles = int(max_handles)
        self.latency_s = float(latency_s)
        self._fds: "OrderedDict[Path, int]" = OrderedDict()
        # fd -> in-flight reads; an evicted/closed backend never closes a
        # descriptor out from under a concurrent reader (that would raise
        # EBADF — or silently read the wrong file if the fd number were
        # reused by a new open). Eviction defers the close until release.
        self._refs: dict[int, int] = {}
        self._defunct: set[int] = set()
        self._lock = threading.Lock()

    def _acquire(self, path: Path) -> int:
        with self._lock:
            fd = self._fds.get(path)
            if fd is not None:
                self._fds.move_to_end(path)
            else:
                fd = os.open(path, os.O_RDONLY)
                self.stats.file_opens += 1
                self._fds[path] = fd
                while len(self._fds) > self.max_handles:
                    _, old = self._fds.popitem(last=False)
                    if self._refs.get(old, 0) == 0:
                        os.close(old)
                    else:
                        self._defunct.add(old)
            self._refs[fd] = self._refs.get(fd, 0) + 1
            return fd

    def _release(self, fd: int) -> None:
        with self._lock:
            n = self._refs.get(fd, 0) - 1
            if n > 0:
                self._refs[fd] = n
                return
            self._refs.pop(fd, None)
            if fd in self._defunct:
                self._defunct.discard(fd)
                os.close(fd)

    def read(self, path: Path) -> bytes:
        fd = self._acquire(path)
        try:
            size = os.fstat(fd).st_size
            t0 = time.perf_counter()
            if self.latency_s:
                time.sleep(self.latency_s)
            blob = os.pread(fd, size, 0)
        finally:
            self._release(fd)
        elapsed = time.perf_counter() - t0
        payload, nraw, decode_s, decoded = self._run_decoder(blob)
        with self._lock:
            self.stats.wait_seconds += elapsed
            self.stats.chunk_reads += 1
            self.stats.bytes_read += nraw
            self.stats.decode_seconds += decode_s
            self.stats.decoded_bytes += decoded
        return payload

    def read_range(self, path: Path, offset: int, length: int) -> bytes:
        fd = self._acquire(path)
        try:
            t0 = time.perf_counter()
            if self.latency_s:
                time.sleep(self.latency_s)
            blob = os.pread(fd, length, offset)
        finally:
            self._release(fd)
        with self._lock:
            self.stats.wait_seconds += time.perf_counter() - t0
            self.stats.ranged_reads += 1
            self.stats.bytes_read += len(blob)
        return blob

    def close(self) -> None:
        with self._lock:
            for fd in self._fds.values():
                if self._refs.get(fd, 0) == 0:
                    os.close(fd)
                else:
                    self._defunct.add(fd)  # closed by the last reader
            self._fds.clear()
