"""Storage backend interface: how chunk bytes reach the protocol.

The paper's claim is storage-agnostic ("it does not depend on any specific
storage"); this ABC makes that concrete. A backend maps *paths* to buffers —
it knows nothing about chunks, plans, or the protocol. :class:`ChunkStore`
owns the chunk-id -> path translation and the offset index.

Three access patterns, mirroring how training actually touches storage:

* :meth:`StorageBackend.read` — one whole-file batched read (the Redox
  chunk-load path);
* :meth:`StorageBackend.read_range` — a ranged read of one record (the
  per-file baseline path);
* :meth:`StorageBackend.prefetch` — a non-binding hint that the given paths
  will likely be read soon. Synchronous backends ignore it; the parallel
  backend turns it into bounded readahead so chunk loads overlap with
  protocol work and batch assembly.

Every backend keeps a :class:`BackendStats` so benchmarks can report
observed chunk-read throughput (bytes delivered per second the *caller*
spent blocked) per backend.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from pathlib import Path

from repro.core.stats import StatsDict

__all__ = ["BackendStats", "StorageBackend"]


@dataclasses.dataclass
class BackendStats(StatsDict):
    """Counters shared by all backends (times in seconds)."""

    chunk_reads: int = 0       # whole-file read() calls served
    ranged_reads: int = 0      # read_range() calls served
    bytes_read: int = 0        # payload bytes handed to callers
    file_opens: int = 0        # OS-level open()/mmap() operations
    # Caller-blocked time inside read(), split by cause. For synchronous
    # backends every read is inline, so all of it lands in wait_seconds;
    # async backends put future waits (readahead that wasn't finished in
    # time) in wait_seconds and cold-miss inline reads (nothing was ever
    # submitted for the path) in miss_read_seconds — the §6 model treats
    # them differently: misses cost full storage latency, waits shrink
    # toward zero as readahead depth grows.
    wait_seconds: float = 0.0       # blocked on a submitted read finishing
    miss_read_seconds: float = 0.0  # blocked on an inline cold-miss read
    cold_misses: int = 0       # read() calls served by neither readahead source
    prefetch_issued: int = 0   # heuristic readahead reads actually submitted
    prefetch_hits: int = 0     # read() calls served by a heuristic prefetch
    scheduled_issued: int = 0  # readahead reads submitted from an exact schedule
    scheduled_hits: int = 0    # read() calls served by the exact schedule
    peak_inflight: int = 0     # max concurrent background reads observed
    # Codec layer (DESIGN.md §15): when a decoder is installed, bytes_read
    # keeps counting *physical* (on-disk, possibly compressed) bytes, and
    # the decode cost lands here — on a worker thread for the parallel
    # backend (overlapped with disk I/O), inline for synchronous backends.
    decode_seconds: float = 0.0  # time spent inside the installed decoder
    decoded_bytes: int = 0       # logical bytes produced by eager decodes

    @property
    def blocked_seconds(self) -> float:
        """Total caller time blocked inside read(), whatever the cause."""
        return self.wait_seconds + self.miss_read_seconds

    def throughput(self) -> float:
        """Observed blocking-read throughput (bytes/s of caller wait time)."""
        blocked = self.blocked_seconds
        return self.bytes_read / blocked if blocked > 0 else 0.0


class StorageBackend(abc.ABC):
    """One way of turning a path into bytes. Stateless w.r.t. the protocol."""

    name: str = "abstract"
    #: True when prefetch() actually consumes hints — lets callers skip
    #: computing hint lists for synchronous backends entirely.
    wants_prefetch: bool = False

    def __init__(self) -> None:
        self.stats = BackendStats()
        self._decoder = None

    # --------------------------------------------------------------- decode
    def set_decoder(self, fn) -> None:
        """Install a post-read transform applied to every whole-file read.

        ``fn(raw) -> payload`` runs wherever the physical read ran — on a
        worker thread for async backends, so decompression overlaps disk
        I/O; inline for synchronous ones. :meth:`read_range` is never
        decoded (a ranged slice of a compressed frame is meaningless —
        ``ChunkStore.read_file`` routes framed stores through a cached
        whole-chunk decode instead).
        """
        self._decoder = fn

    def _run_decoder(self, raw):
        """``(payload, physical_nbytes, decode_s, decoded_nbytes)``.

        Stats are returned, not applied — the caller folds them in under
        its own stats lock.
        """
        nraw = memoryview(raw).nbytes
        if self._decoder is None:
            return raw, nraw, 0.0, 0
        t0 = time.perf_counter()
        payload = self._decoder(raw)
        elapsed = time.perf_counter() - t0
        measure = getattr(payload, "decoded_nbytes", None)
        return payload, nraw, elapsed, measure() if measure else 0

    # ------------------------------------------------------------- required
    @abc.abstractmethod
    def read(self, path: Path) -> "bytes | memoryview":
        """Read the whole file at ``path`` (one batched request)."""

    @abc.abstractmethod
    def read_range(self, path: Path, offset: int, length: int) -> "bytes | memoryview":
        """Read ``length`` bytes at ``offset`` of ``path``."""

    # ------------------------------------------------------------- optional
    def prefetch(self, paths: "list[Path]") -> None:
        """Hint that ``paths`` will be read soon. Default: no-op."""

    def schedule_reads(self, paths: "list[Path]") -> None:
        """Install the *exact* upcoming read order (clairvoyant planner).

        Unlike :meth:`prefetch` hints — which are non-binding guesses that
        may be dropped — a schedule is the ground-truth sequence of future
        :meth:`read` calls, duplicates included. Async backends keep their
        readahead window filled from its head; synchronous backends ignore
        it (the default no-op), and the hint heuristic stays as the
        fallback when no schedule is active.
        """

    @property
    def scheduled_active(self) -> bool:
        """True while an exact read schedule is installed and unexhausted."""
        return False

    def close(self) -> None:
        """Release cached handles/maps/threads. Safe to call twice."""

    def __enter__(self) -> "StorageBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
