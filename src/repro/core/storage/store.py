"""Chunk store: the on-disk batched layout (paper §3.2 "Data Chunk Generation").

A chunk is one file on disk: the concatenation of its member records, plus a
sidecar offset index. This is the paper's one-time dataset re-organisation
("the pre-organized data chunks can be re-used to train different models").
Reads happen at two granularities:

* ``read_chunk``  — one sequential read of the whole chunk (Redox path);
* ``read_file``   — a ranged read of one record (baseline path — models
  PyTorch's per-file access against the same bytes).

*How* bytes are read is delegated to a :class:`StorageBackend`
(``backend="vfs" | "mmap" | "parallel"``, or an instance) — see
``base.py``. The layout itself stays storage-agnostic, like the paper's
implementation: "it does not depend on any specific storage".
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.obs import tracer as trace

from ..chunking import ChunkingPlan
from .base import BackendStats, StorageBackend
from .mapped import MmapBackend
from .parallel import ParallelBackend
from .vfs import VFSBackend

__all__ = [
    "ChunkStore",
    "BACKENDS",
    "make_backend",
    "merge_read_schedules",
    "first_read_order",
]


def merge_read_schedules(per_session_steps: "list[list[list[int]]]") -> "list[int]":
    """Merge per-session, per-step chunk-read schedules into one global order.

    ``per_session_steps[j][s]`` is the list of chunk ids session ``j`` reads
    during its step ``s``. The merge interleaves by step — for each step,
    every session's loads in session order — which is exactly the claim
    order produced by a round-robin serving pump driving the sessions in
    lockstep (``repro.service.DataService.co_epoch``). Duplicates are kept:
    this is the *claim* schedule; :func:`first_read_order` derives the
    physical read schedule a shared refcounted cache actually issues.
    """
    merged: "list[int]" = []
    depth = max((len(steps) for steps in per_session_steps), default=0)
    for s in range(depth):
        for steps in per_session_steps:
            if s < len(steps):
                merged.extend(steps[s])
    return merged


def first_read_order(claims: "list[int]") -> "list[int]":
    """Physical read order of a claim schedule under a refcounted cache.

    With release-on-last-claim refcounts (``repro.service.SharedResidency``)
    a chunk stays cache-resident from its first claim until its last, so
    only each chunk's *first* occurrence reaches storage — later claims,
    including a job's own repeat loads, are shared hits. The result is what
    the service hands to ``ChunkStore.schedule_reads`` as the backend's
    exact readahead schedule.
    """
    seen: "set[int]" = set()
    order: "list[int]" = []
    for k in claims:
        if k not in seen:
            seen.add(k)
            order.append(k)
    return order

BACKENDS = {
    "vfs": VFSBackend,
    "mmap": MmapBackend,
    "parallel": ParallelBackend,
}


def make_backend(spec: "str | StorageBackend", **kwargs) -> StorageBackend:
    """Factory: a backend name (``BACKENDS`` key) or a ready instance."""
    if isinstance(spec, StorageBackend):
        return spec
    try:
        cls = BACKENDS[spec]
    except KeyError:
        raise ValueError(
            f"unknown storage backend {spec!r}; expected one of {sorted(BACKENDS)}"
        ) from None
    return cls(**kwargs)


class ChunkStore:
    """Directory of chunk files + offset indexes for one dataset."""

    def __init__(
        self,
        root: str | Path,
        plan: ChunkingPlan,
        *,
        backend: "str | StorageBackend" = "vfs",
    ):
        self.root = Path(root)
        self.plan = plan
        self._offsets: dict[int, np.ndarray] | None = None
        self._backend = make_backend(backend)

    # ------------------------------------------------------------- backend
    @property
    def backend(self) -> StorageBackend:
        return self._backend

    @property
    def backend_stats(self) -> BackendStats:
        return self._backend.stats

    def chunk_path(self, chunk: int) -> Path:
        return self.root / f"chunk_{chunk:08d}.bin"

    @property
    def wants_prefetch(self) -> bool:
        """Whether computing prefetch hints for this store is worthwhile."""
        return self._backend.wants_prefetch

    def prefetch_chunks(self, chunks: "list[int]") -> None:
        """Hint upcoming chunk loads to the backend (bounded readahead)."""
        if chunks and self._backend.wants_prefetch:
            self._backend.prefetch([self.chunk_path(k) for k in chunks])

    def schedule_reads(self, chunks: "list[int]") -> None:
        """Hand the planner's exact chunk-read schedule to the backend."""
        if chunks:
            trace.instant(
                "store.schedule_reads", "read",
                backend=self._backend.name, chunks=len(chunks),
            )
            self._backend.schedule_reads([self.chunk_path(k) for k in chunks])

    @property
    def has_schedule(self) -> bool:
        """True while the backend is driven by an exact read schedule."""
        return self._backend.scheduled_active

    def close(self) -> None:
        self._backend.close()

    # -------------------------------------------------------------- writing
    @staticmethod
    def build(
        root: str | Path,
        plan: ChunkingPlan,
        records,
        *,
        backend: "str | StorageBackend" = "vfs",
    ) -> "ChunkStore":
        """One-time chunk-file generation (paper Fig. 2a).

        ``records`` is anything indexable by file id returning the record
        bytes (a list, or a provider like ``SyntheticTokenDataset``).
        """
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        offsets = {}
        for k in range(plan.num_chunks):
            files = plan.files_in_chunk(k)
            blobs = [records[int(f)] for f in files]
            sizes = np.array([len(b) for b in blobs], dtype=np.int64)
            if not np.array_equal(sizes, plan.file_sizes[files]):
                raise ValueError(f"record sizes disagree with plan for chunk {k}")
            offs = np.zeros(len(blobs) + 1, dtype=np.int64)
            np.cumsum(sizes, out=offs[1:])
            with open(root / f"chunk_{k:08d}.bin", "wb") as fh:
                for b in blobs:
                    fh.write(b)
            offsets[k] = offs
        index = {
            str(k): [int(x) for x in offs] for k, offs in offsets.items()
        }
        (root / "index.json").write_text(json.dumps(index))
        plan.save(root / "plan.npz")
        store = ChunkStore(root, plan, backend=backend)
        store._offsets = {int(k): np.asarray(v) for k, v in index.items()}
        return store

    # -------------------------------------------------------------- reading
    def _index(self) -> dict[int, np.ndarray]:
        if self._offsets is None:
            raw = json.loads((self.root / "index.json").read_text())
            self._offsets = {int(k): np.asarray(v, dtype=np.int64) for k, v in raw.items()}
        return self._offsets

    def read_chunk(self, chunk: int) -> "list[tuple[int, bytes | memoryview]]":
        """One batched read -> [(file_id, record_bytes), ...] in slot order."""
        offs = self._index()[chunk]
        files = self.plan.files_in_chunk(chunk)
        with trace.span(
            "store.read_chunk", "read",
            chunk=chunk, backend=self._backend.name,
        ):
            blob = self._backend.read(self.chunk_path(chunk))
        return [
            (int(f), blob[offs[j] : offs[j + 1]]) for j, f in enumerate(files)
        ]

    def read_file(self, file_id: int) -> "bytes | memoryview":
        """Ranged read of a single record (baseline access pattern).

        Offsets come from the cached index and the backend reuses its open
        handle for the chunk file, so repeated calls cost one ``pread`` —
        not an ``open`` + index parse per record.
        """
        k = int(self.plan.chunk_of[file_id])
        j = int(self.plan.slot_of[file_id])
        offs = self._index()[k]
        return self._backend.read_range(
            self.chunk_path(k), int(offs[j]), int(offs[j + 1] - offs[j])
        )

    @staticmethod
    def open(
        root: str | Path, *, backend: "str | StorageBackend" = "vfs"
    ) -> "ChunkStore":
        root = Path(root)
        plan = ChunkingPlan.load(root / "plan.npz")
        return ChunkStore(root, plan, backend=backend)
