"""Chunk store: the on-disk batched layout (paper §3.2 "Data Chunk Generation").

A chunk is one file on disk: the concatenation of its member records, plus a
sidecar offset index. This is the paper's one-time dataset re-organisation
("the pre-organized data chunks can be re-used to train different models").
Reads happen at two granularities:

* ``read_chunk``  — one sequential read of the whole chunk (Redox path);
* ``read_file``   — a ranged read of one record (baseline path — models
  PyTorch's per-file access against the same bytes).

*How* bytes are read is delegated to a :class:`StorageBackend`
(``backend="vfs" | "mmap" | "parallel"``, or an instance) — see
``base.py``. The layout itself stays storage-agnostic, like the paper's
implementation: "it does not depend on any specific storage".

*What* bytes sit in a chunk file is described by a frozen
:class:`~repro.core.spec.StoreSpec` (DESIGN.md §15): the default spec is
the legacy raw concatenation, while ``codec``/``bands`` select the framed
progressive layout of ``codec.py`` — per-chunk compressed fidelity bands.
``build`` persists the spec as ``store.json`` in the root, so
``ChunkStore.open(root)`` reopens any store with no flags; only the byte
representation changes, never the offsets index, the redirection
protocol, or the exactly-once semantics.
"""

from __future__ import annotations

import json
from collections import OrderedDict
from pathlib import Path

import numpy as np

from repro.obs import tracer as trace

from ..chunking import ChunkingPlan
from ..spec import StoreSpec
from .base import BackendStats, StorageBackend
from .codec import (
    FRAME_PEEK_BYTES,
    ChunkFrame,
    band_cuts,
    encode_frame,
    get_codec,
    parse_frame,
    peek_frame,
)
from .mapped import MmapBackend
from .parallel import ParallelBackend
from .vfs import VFSBackend

__all__ = [
    "ChunkStore",
    "BACKENDS",
    "make_backend",
    "merge_read_schedules",
    "first_read_order",
]


def merge_read_schedules(per_session_steps: "list[list[list[int]]]") -> "list[int]":
    """Merge per-session, per-step chunk-read schedules into one global order.

    ``per_session_steps[j][s]`` is the list of chunk ids session ``j`` reads
    during its step ``s``. The merge interleaves by step — for each step,
    every session's loads in session order — which is exactly the claim
    order produced by a round-robin serving pump driving the sessions in
    lockstep (``repro.service.DataService.co_epoch``). Duplicates are kept:
    this is the *claim* schedule; :func:`first_read_order` derives the
    physical read schedule a shared refcounted cache actually issues.
    """
    merged: "list[int]" = []
    depth = max((len(steps) for steps in per_session_steps), default=0)
    for s in range(depth):
        for steps in per_session_steps:
            if s < len(steps):
                merged.extend(steps[s])
    return merged


def first_read_order(claims: "list[int]") -> "list[int]":
    """Physical read order of a claim schedule under a refcounted cache.

    With release-on-last-claim refcounts (``repro.service.SharedResidency``)
    a chunk stays cache-resident from its first claim until its last, so
    only each chunk's *first* occurrence reaches storage — later claims,
    including a job's own repeat loads, are shared hits. The result is what
    the service hands to ``ChunkStore.schedule_reads`` as the backend's
    exact readahead schedule.
    """
    seen: "set[int]" = set()
    order: "list[int]" = []
    for k in claims:
        if k not in seen:
            seen.add(k)
            order.append(k)
    return order

BACKENDS = {
    "vfs": VFSBackend,
    "mmap": MmapBackend,
    "parallel": ParallelBackend,
}


def make_backend(spec: "str | StorageBackend", **kwargs) -> StorageBackend:
    """Factory: a backend name (``BACKENDS`` key) or a ready instance."""
    if isinstance(spec, StorageBackend):
        return spec
    try:
        cls = BACKENDS[spec]
    except KeyError:
        raise ValueError(
            f"unknown storage backend {spec!r}; expected one of {sorted(BACKENDS)}"
        ) from None
    return cls(**kwargs)


class ChunkStore:
    """Directory of chunk files + offset indexes for one dataset.

    ``spec`` fixes the byte layout (codec/level/bands) and the default
    backend; an explicit ``backend`` argument overrides the spec's
    *backend* only — the layout always comes from the spec (persisted as
    ``store.json`` by :meth:`build`). ``default_fidelity`` is the store's
    standing band count for progressive reads; ``read_chunk(fidelity=...)``
    overrides it per call.
    """

    # read_file() against a framed store decodes whole chunks; a tiny LRU
    # keeps the baseline's sequential-in-chunk accesses from paying one
    # decompression per record.
    _DECODE_CACHE_CAP = 4

    def __init__(
        self,
        root: str | Path,
        plan: ChunkingPlan,
        *,
        backend: "str | StorageBackend | None" = None,
        spec: "StoreSpec | None" = None,
        fidelity: "int | None" = None,
    ):
        self.root = Path(root)
        self.plan = plan
        self._offsets: dict[int, np.ndarray] | None = None
        if spec is None:
            spec = StoreSpec.from_kwargs(backend if backend is not None else "vfs")
        self.spec = spec
        if backend is not None:
            self._backend = make_backend(backend)
        else:
            self._backend = make_backend(spec.backend, **spec.backend_kwargs)
        self.default_fidelity = fidelity
        self._codec = get_codec(spec.codec)
        self._band_offs: "dict[int, list[np.ndarray]]" = {}
        self._decode_cache: "OrderedDict[int, list]" = OrderedDict()
        if spec.framed:
            self._backend.set_decoder(self._decode_payload)

    # ------------------------------------------------------------- backend
    @property
    def backend(self) -> StorageBackend:
        return self._backend

    @property
    def backend_stats(self) -> BackendStats:
        return self._backend.stats

    def chunk_path(self, chunk: int) -> Path:
        return self.root / f"chunk_{chunk:08d}.bin"

    @property
    def wants_prefetch(self) -> bool:
        """Whether computing prefetch hints for this store is worthwhile."""
        return self._backend.wants_prefetch

    def prefetch_chunks(self, chunks: "list[int]") -> None:
        """Hint upcoming chunk loads to the backend (bounded readahead)."""
        if chunks and self._backend.wants_prefetch:
            self._backend.prefetch([self.chunk_path(k) for k in chunks])

    def schedule_reads(self, chunks: "list[int]") -> None:
        """Hand the planner's exact chunk-read schedule to the backend."""
        if chunks:
            trace.instant(
                "store.schedule_reads", "read",
                backend=self._backend.name, chunks=len(chunks),
            )
            self._backend.schedule_reads([self.chunk_path(k) for k in chunks])

    @property
    def has_schedule(self) -> bool:
        """True while the backend is driven by an exact read schedule."""
        return self._backend.scheduled_active

    def close(self) -> None:
        self._decode_cache.clear()
        self._backend.close()

    # -------------------------------------------------------------- writing
    @staticmethod
    def build(
        root: str | Path,
        plan: ChunkingPlan,
        records,
        *,
        backend: "str | StorageBackend | None" = None,
        spec: "StoreSpec | None" = None,
        codec: "str | None" = None,
        level: "int | None" = None,
        bands: "int | None" = None,
    ) -> "ChunkStore":
        """One-time chunk-file generation (paper Fig. 2a).

        ``records`` is anything indexable by file id returning the record
        bytes (a list, or a provider like ``SyntheticTokenDataset``).
        Pass either a full ``spec`` or the individual ``codec``/``level``/
        ``bands`` knobs (legacy ``backend=`` spelling included); the
        resolved spec is persisted as ``store.json`` so ``open(root)``
        needs no flags. The index always stores *logical* offsets — the
        sizes validated here are pre-encode record sizes, whatever the
        codec does to the bytes on disk.
        """
        if spec is not None:
            if codec is not None or level is not None or bands is not None:
                raise ValueError(
                    "pass either spec= or codec/level/bands, not both"
                )
            if backend is not None:
                raise ValueError("with spec=, the backend belongs in the spec")
        else:
            spec = StoreSpec.from_kwargs(
                backend if backend is not None else "vfs",
                codec=codec if codec is not None else "none",
                level=level if level is not None else -1,
                bands=bands if bands is not None else 1,
            )
        codec_obj = get_codec(spec.codec)
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        offsets = {}
        for k in range(plan.num_chunks):
            files = plan.files_in_chunk(k)
            blobs = [records[int(f)] for f in files]
            sizes = np.array([len(b) for b in blobs], dtype=np.int64)
            if not np.array_equal(sizes, plan.file_sizes[files]):
                raise ValueError(f"record sizes disagree with plan for chunk {k}")
            offs = np.zeros(len(blobs) + 1, dtype=np.int64)
            np.cumsum(sizes, out=offs[1:])
            path = root / f"chunk_{k:08d}.bin"
            if spec.framed:
                cuts = [band_cuts(len(b), spec.bands) for b in blobs]
                payloads = [
                    codec_obj.encode(
                        b"".join(
                            blob[c[b] : c[b + 1]] for blob, c in zip(blobs, cuts)
                        ),
                        spec.level,
                    )
                    for b in range(spec.bands)
                ]
                path.write_bytes(encode_frame(spec.codec, payloads))
            else:
                with open(path, "wb") as fh:
                    for b in blobs:
                        fh.write(b)
            offsets[k] = offs
        index = {
            str(k): [int(x) for x in offs] for k, offs in offsets.items()
        }
        (root / "index.json").write_text(json.dumps(index))
        plan.save(root / "plan.npz")
        (root / "store.json").write_text(json.dumps(spec.to_json(), indent=1))
        store = ChunkStore(root, plan, backend=backend, spec=spec)
        store._offsets = {int(k): np.asarray(v) for k, v in index.items()}
        return store

    # -------------------------------------------------------------- reading
    def _index(self) -> dict[int, np.ndarray]:
        if self._offsets is None:
            raw = json.loads((self.root / "index.json").read_text())
            self._offsets = {int(k): np.asarray(v, dtype=np.int64) for k, v in raw.items()}
        return self._offsets

    def _decode_payload(self, raw) -> ChunkFrame:
        """Backend decoder hook: parse + eagerly decompress one chunk frame.

        Runs wherever the physical read ran — the ParallelBackend's worker
        threads for scheduled/prefetched chunks, so decompression overlaps
        disk I/O. The eager decode stops at the store's standing fidelity;
        a later call asking for more bands decodes from the kept
        compressed payloads.
        """
        frame = parse_frame(raw)
        if frame.codec_name != self.spec.codec:
            raise ValueError(
                f"chunk frame codec {frame.codec_name!r} does not match "
                f"store codec {self.spec.codec!r}"
            )
        frame.ensure_decoded(self._effective_fidelity(None))
        return frame

    def _effective_fidelity(self, fidelity: "int | None") -> int:
        f = self.default_fidelity if fidelity is None else fidelity
        return self.spec.bands if f is None else max(1, min(int(f), self.spec.bands))

    def _band_offset_arrays(self, chunk: int) -> "list[np.ndarray]":
        """Per-band record offsets, derived from the logical index (no extra
        on-disk metadata: cut points are a pure function of record sizes)."""
        cached = self._band_offs.get(chunk)
        if cached is None:
            sizes = np.diff(self._index()[chunk])
            cuts = [band_cuts(int(s), self.spec.bands) for s in sizes]
            cached = []
            for b in range(self.spec.bands):
                offs = np.zeros(len(cuts) + 1, dtype=np.int64)
                np.cumsum([c[b + 1] - c[b] for c in cuts], out=offs[1:])
                cached.append(offs)
            self._band_offs[chunk] = cached
        return cached

    def read_chunk(
        self, chunk: int, fidelity: "int | None" = None
    ) -> "list[tuple[int, bytes | memoryview]]":
        """One batched read -> [(file_id, record_bytes), ...] in slot order.

        On a progressive store, ``fidelity=k`` decodes only the first ``k``
        bands: every record comes back as a strict token-prefix of its
        full self (the Progressive Compressed Records move for I/O-bound
        jobs). Full fidelity is byte-identical to the raw layout.
        """
        with trace.span(
            "store.read_chunk", "read",
            chunk=chunk, backend=self._backend.name, codec=self.spec.codec,
        ):
            payload = self._backend.read(self.chunk_path(chunk))
        return self.decode_chunk(chunk, payload, fidelity)

    def read_chunk_raw(self, chunk: int):
        """The chunk's *cacheable* payload: a parsed-but-compressed
        :class:`ChunkFrame` on framed stores, the raw blob otherwise.
        :meth:`decode_chunk` turns it into records; ``payload_nbytes``
        gives its physical footprint. This is the pair ``SharedResidency``
        uses to cache compressed bytes and decode per-claim.
        """
        with trace.span(
            "store.read_chunk", "read",
            chunk=chunk, backend=self._backend.name, codec=self.spec.codec,
        ):
            return self._backend.read(self.chunk_path(chunk))

    @staticmethod
    def payload_nbytes(payload) -> int:
        """Physical bytes of a :meth:`read_chunk_raw` payload."""
        if isinstance(payload, ChunkFrame):
            return payload.physical_bytes
        return memoryview(payload).nbytes

    def decode_chunk(
        self, chunk: int, payload, fidelity: "int | None" = None
    ) -> "list[tuple[int, bytes | memoryview]]":
        """Slice a chunk payload into records (per-claim decode path).

        Never mutates a cached frame beyond consuming its one-shot eager
        decode, so concurrent claims at different fidelities are safe.
        """
        offs = self._index()[chunk]
        files = self.plan.files_in_chunk(chunk)
        if not self.spec.framed:
            return [
                (int(f), payload[offs[j] : offs[j + 1]])
                for j, f in enumerate(files)
            ]
        if not isinstance(payload, ChunkFrame):
            payload = parse_frame(payload)
        eff = self._effective_fidelity(fidelity)
        with trace.span(
            "store.decode_chunk", "decode",
            chunk=chunk, codec=self.spec.codec,
            fidelity=eff, bands=self.spec.bands,
        ):
            data = payload.take_decoded(eff)
            if data is None:
                data = payload.decode_bands(eff)
            boffs = self._band_offset_arrays(chunk)
            if eff == 1:
                b0, o = data[0], boffs[0]
                return [
                    (int(f), b0[o[j] : o[j + 1]]) for j, f in enumerate(files)
                ]
            return [
                (
                    int(f),
                    b"".join(
                        data[b][boffs[b][j] : boffs[b][j + 1]]
                        for b in range(eff)
                    ),
                )
                for j, f in enumerate(files)
            ]

    def read_file(self, file_id: int) -> "bytes | memoryview":
        """Ranged read of a single record (baseline access pattern).

        Offsets come from the cached index and the backend reuses its open
        handle for the chunk file, so repeated calls cost one ``pread`` —
        not an ``open`` + index parse per record. On a framed store a
        ranged ``pread`` of a compressed frame would hand back garbage
        mid-stream bytes, so the record is sliced from a whole-chunk
        decode instead, LRU-cached so in-chunk locality amortises the
        decompression. Always full fidelity: the baseline path models
        exact per-file bytes.
        """
        k = int(self.plan.chunk_of[file_id])
        j = int(self.plan.slot_of[file_id])
        if self.spec.framed:
            records = self._decode_cache.get(k)
            if records is not None:
                self._decode_cache.move_to_end(k)
            else:
                records = self.read_chunk(k, fidelity=self.spec.bands)
                self._decode_cache[k] = records
                while len(self._decode_cache) > self._DECODE_CACHE_CAP:
                    self._decode_cache.popitem(last=False)
            return records[j][1]
        offs = self._index()[k]
        return self._backend.read_range(
            self.chunk_path(k), int(offs[j]), int(offs[j + 1] - offs[j])
        )

    # -------------------------------------------------------------- opening
    def _verify_frames(self) -> None:
        """Reject a mixed-codec store at open(): every chunk file's frame
        header must agree with the spec (a store root assembled from two
        differently-encoded builds would otherwise fail mid-epoch)."""
        for k in range(self.plan.num_chunks):
            path = self.chunk_path(k)
            with open(path, "rb") as fh:
                head = peek_frame(fh.read(FRAME_PEEK_BYTES))
            if head is None:
                raise ValueError(
                    f"{path} is not a {self.spec.codec!r} frame "
                    f"(mixed-codec or legacy-raw chunk in a framed store)"
                )
            codec_name, nbands = head
            if codec_name != self.spec.codec or nbands != self.spec.bands:
                raise ValueError(
                    f"mixed-codec store: {path} is {codec_name!r}/{nbands} "
                    f"bands, store.json says {self.spec.codec!r}/"
                    f"{self.spec.bands}"
                )

    @staticmethod
    def open(
        root: str | Path,
        *,
        backend: "str | StorageBackend | None" = None,
        spec: "StoreSpec | None" = None,
        fidelity: "int | None" = None,
    ) -> "ChunkStore":
        """Reopen a built store. With no arguments the persisted
        ``store.json`` supplies everything; an explicit ``spec`` that
        disagrees with it is refused, and an explicit ``backend`` overrides
        the spec's default read path only (never the layout).
        """
        root = Path(root)
        plan = ChunkingPlan.load(root / "plan.npz")
        sidecar = root / "store.json"
        stored = None
        if sidecar.exists():
            stored = StoreSpec.from_json(json.loads(sidecar.read_text()))
        if spec is not None and stored is not None and spec != stored:
            raise ValueError(
                f"explicit spec conflicts with {sidecar}: "
                f"{spec.to_json()} != {stored.to_json()}"
            )
        resolved = spec if spec is not None else stored
        store = ChunkStore(
            root, plan, backend=backend, spec=resolved, fidelity=fidelity
        )
        if store.spec.framed:
            store._verify_frames()
        return store
