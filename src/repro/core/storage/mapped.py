"""mmap backend: zero-copy chunk reads through the page cache.

Each chunk file is mapped once and kept mapped; reads return
``memoryview`` slices of the map instead of copied ``bytes``. Record
payloads flow into ``np.frombuffer`` (decode) without an intermediate
copy, so a chunk's bytes cross from the page cache straight into batch
assembly — the paper's "batched read" with the kernel doing the batching.

Slicing a memoryview is O(1); the copy happens only when tokens are packed
into the fixed-shape training grid.
"""

from __future__ import annotations

import mmap
import os
import threading
import time
from pathlib import Path

from .base import StorageBackend

__all__ = ["MmapBackend"]


class MmapBackend(StorageBackend):
    """Zero-copy backend: files mapped read-only, reads are views."""

    name = "mmap"

    def __init__(self) -> None:
        super().__init__()
        self._maps: dict[Path, mmap.mmap] = {}
        self._lock = threading.Lock()

    def _map(self, path: Path) -> mmap.mmap:
        with self._lock:
            mm = self._maps.get(path)
            if mm is None:
                fd = os.open(path, os.O_RDONLY)
                try:
                    mm = mmap.mmap(fd, 0, access=mmap.ACCESS_READ)
                finally:
                    os.close(fd)
                self.stats.file_opens += 1
                self._maps[path] = mm
            return mm

    def read(self, path: Path) -> memoryview:
        t0 = time.perf_counter()
        view = memoryview(self._map(path))
        elapsed = time.perf_counter() - t0
        payload, nraw, decode_s, decoded = self._run_decoder(view)
        with self._lock:
            self.stats.wait_seconds += elapsed
            self.stats.chunk_reads += 1
            self.stats.bytes_read += nraw
            self.stats.decode_seconds += decode_s
            self.stats.decoded_bytes += decoded
        return payload

    def read_range(self, path: Path, offset: int, length: int) -> memoryview:
        t0 = time.perf_counter()
        view = memoryview(self._map(path))[offset : offset + length]
        with self._lock:
            self.stats.wait_seconds += time.perf_counter() - t0
            self.stats.ranged_reads += 1
            self.stats.bytes_read += length
        return view

    def close(self) -> None:
        with self._lock:
            for mm in self._maps.values():
                try:
                    mm.close()
                except BufferError:
                    # A consumer still holds a view into this map (e.g. an
                    # undecoded record); the map is reclaimed when they drop it.
                    pass
            self._maps.clear()
