"""Pluggable storage: one chunk layout, three ways to read it.

``ChunkStore`` owns the on-disk chunk layout (paper §3.2); a
:class:`StorageBackend` decides how bytes are fetched:

========  =====================================================  ==========
backend   mechanism                                              returns
========  =====================================================  ==========
vfs       ``open``/``pread`` with a descriptor cache (default)   ``bytes``
mmap      files mapped once; reads are zero-copy views           ``memoryview``
parallel  threadpool reads + bounded readahead over an inner
          backend, driven by protocol prefetch hints             inner's type
========  =====================================================  ==========

Select one with ``ChunkStore.open(root, backend="mmap")`` or pass an
instance for custom tuning (``ParallelBackend(workers=8, readahead=16)``).

Orthogonally to *how* bytes are read, ``codec.py`` decides *what* bytes
sit on disk: per-chunk framed compression (``none``/``zlib``/``lz4``)
with progressive fidelity bands, described by a frozen
:class:`~repro.core.spec.StoreSpec` persisted as ``store.json`` — see
DESIGN.md §15. ``ChunkStore.open(root)`` with no flags reopens any built
store.
"""

from .base import BackendStats, StorageBackend
from .codec import CODECS, ChunkFrame, Codec, band_cuts, get_codec
from .mapped import MmapBackend
from .parallel import ParallelBackend
from .store import (
    BACKENDS,
    ChunkStore,
    first_read_order,
    make_backend,
    merge_read_schedules,
)
from .vfs import VFSBackend

__all__ = [
    "BACKENDS",
    "BackendStats",
    "CODECS",
    "ChunkFrame",
    "ChunkStore",
    "Codec",
    "MmapBackend",
    "ParallelBackend",
    "StorageBackend",
    "VFSBackend",
    "band_cuts",
    "first_read_order",
    "get_codec",
    "make_backend",
    "merge_read_schedules",
]
