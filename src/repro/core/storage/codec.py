"""Chunk codecs + the framed progressive record container (DESIGN.md §15).

The chunk layout in ``store.py`` is byte-oriented: a chunk file is the
concatenation of its member records and the sidecar index holds *logical*
offsets. This module changes only the byte representation on disk — never
the redirection protocol or the exactly-once semantics:

* **Codecs** (:data:`CODECS`) turn a buffer into a smaller buffer and back.
  ``none`` is the identity, ``zlib`` is the stdlib DEFLATE, and ``lz4`` is a
  self-contained LZ4-style LZ77 token format (literal-run/match sequences,
  no entropy coder) so the fast-codec path needs no third-party wheel.
* **Frames** wrap one chunk: a small header naming the codec plus one or
  more independently-compressed *fidelity bands*.
* **Bands** make records progressive (Progressive Compressed Records):
  band ``b`` of a chunk holds, for every record, the slice of its tokens
  between the record's band-``b`` cut points. Decoding bands ``0..k-1``
  and re-concatenating per record yields, for every record, a strict
  token-prefix of the full record — so an I/O-bound job can train on
  truncated records while a compute-bound job decodes everything.

Cut points are derived purely from the logical record sizes already in the
offset index, so bands need no extra per-record metadata on disk.
"""

from __future__ import annotations

import struct
import zlib

__all__ = [
    "CODECS",
    "Codec",
    "ChunkFrame",
    "FRAME_MAGIC",
    "band_cuts",
    "encode_frame",
    "get_codec",
    "is_frame",
    "parse_frame",
    "peek_frame",
]

FRAME_MAGIC = b"RXF1"
_FRAME_VERSION = 1
# Longest prefix peek_frame() ever needs: magic + version + nbands +
# name length + a 255-byte codec name.
FRAME_PEEK_BYTES = 4 + 3 + 255


# ------------------------------------------------------------------ codecs
class Codec:
    """One reversible byte transform. Stateless; instances live in CODECS."""

    name: str = "abstract"

    def encode(self, data: "bytes | memoryview", level: int = -1) -> bytes:
        raise NotImplementedError

    def decode(self, data: "bytes | memoryview") -> bytes:
        raise NotImplementedError


class NoneCodec(Codec):
    """Identity codec: framed (banded) layout without compression."""

    name = "none"

    def encode(self, data, level=-1) -> bytes:
        return bytes(data)

    def decode(self, data) -> bytes:
        return bytes(data)


class ZlibCodec(Codec):
    """Stdlib DEFLATE. ``level`` is the zlib level (-1 = library default)."""

    name = "zlib"

    def encode(self, data, level=-1) -> bytes:
        return zlib.compress(bytes(data), level)

    def decode(self, data) -> bytes:
        return zlib.decompress(data)


class Lz4Codec(Codec):
    """LZ4-style LZ77 block codec, implemented in-repo.

    Sequence format (mirrors the LZ4 block spirit): a token byte packs
    ``(literal_len << 4) | (match_len - 4)`` with 255-chunk extension
    bytes for either nibble at 15, followed by the literals, a 16-bit
    little-endian match offset, and the match-length extensions. The last
    sequence carries literals only (decode stops at end of input).
    ``level`` is accepted for registry uniformity and ignored.
    """

    name = "lz4"
    _MIN_MATCH = 4
    _MAX_OFFSET = 0xFFFF

    def encode(self, data, level=-1) -> bytes:
        data = bytes(data)
        n = len(data)
        out = bytearray()
        table: dict[bytes, int] = {}
        anchor = 0
        pos = 0
        limit = n - self._MIN_MATCH
        while pos <= limit:
            key = data[pos : pos + 4]
            ref = table.get(key)
            table[key] = pos
            if ref is None or pos - ref > self._MAX_OFFSET:
                pos += 1
                continue
            mlen = 4
            while pos + mlen < n and data[ref + mlen] == data[pos + mlen]:
                mlen += 1
            self._emit(out, data, anchor, pos, pos - ref, mlen)
            pos += mlen
            anchor = pos
        self._emit(out, data, anchor, n, 0, 0)  # final literal-only run
        return bytes(out)

    @staticmethod
    def _emit(out: bytearray, data: bytes, lit_start: int, lit_end: int,
              offset: int, mlen: int) -> None:
        lit = lit_end - lit_start
        mtok = 0 if mlen == 0 else mlen - 4
        out.append((min(lit, 15) << 4) | min(mtok, 15))
        if lit >= 15:
            rest = lit - 15
            while rest >= 255:
                out.append(255)
                rest -= 255
            out.append(rest)
        out += data[lit_start:lit_end]
        if mlen == 0:
            return  # final sequence: literals only
        out += struct.pack("<H", offset)
        if mtok >= 15:
            rest = mtok - 15
            while rest >= 255:
                out.append(255)
                rest -= 255
            out.append(rest)

    def decode(self, data) -> bytes:
        data = bytes(data)
        out = bytearray()
        pos, n = 0, len(data)
        while pos < n:
            token = data[pos]
            pos += 1
            lit = token >> 4
            if lit == 15:
                while True:
                    b = data[pos]
                    pos += 1
                    lit += b
                    if b != 255:
                        break
            out += data[pos : pos + lit]
            pos += lit
            if pos >= n:
                break  # final literal-only sequence
            offset = data[pos] | (data[pos + 1] << 8)
            pos += 2
            mlen = (token & 0xF) + 4
            if (token & 0xF) == 15:
                while True:
                    b = data[pos]
                    pos += 1
                    mlen += b
                    if b != 255:
                        break
            start = len(out) - offset
            if offset >= mlen:
                out += out[start : start + mlen]
            else:  # overlapping match = run-length copy
                for i in range(mlen):
                    out.append(out[start + i])
        return bytes(out)


CODECS: "dict[str, Codec]" = {
    c.name: c for c in (NoneCodec(), ZlibCodec(), Lz4Codec())
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; expected one of {sorted(CODECS)}"
        ) from None


# ------------------------------------------------------------------- bands
def band_cuts(nbytes: int, bands: int) -> "list[int]":
    """Byte cut points ``[c_0=0, ..., c_bands=nbytes]`` for one record.

    Cuts land on token (4-byte) boundaries whenever the record is a whole
    number of int32 tokens, so every band prefix stays decodable by
    ``decode_record``; odd-sized blobs fall back to plain byte cuts.
    """
    item = 4 if nbytes % 4 == 0 else 1
    n = nbytes // item
    return [(n * b // bands) * item for b in range(bands)] + [nbytes]


# ------------------------------------------------------------------ frames
class ChunkFrame:
    """A parsed (not yet decompressed) chunk frame.

    ``raw_bands`` holds the compressed band payloads — this is the object
    :class:`~repro.service.SharedResidency` caches, so its footprint is
    the *physical* (compressed) bytes. ``decoded`` is an optional eager
    decode filled on a backend worker thread and consumed exactly once by
    the first claim via :meth:`take_decoded`; per-claim decodes afterwards
    call :meth:`decode_bands`, which never mutates the frame.
    """

    __slots__ = ("codec_name", "raw_bands", "physical_bytes", "decoded")

    def __init__(self, codec_name: str, raw_bands: "tuple", physical_bytes: int):
        self.codec_name = codec_name
        self.raw_bands = raw_bands
        self.physical_bytes = int(physical_bytes)
        self.decoded: "list[bytes] | None" = None

    @property
    def nbands(self) -> int:
        return len(self.raw_bands)

    def decode_bands(self, fidelity: "int | None" = None) -> "list[bytes]":
        """Decompress bands ``0..fidelity-1`` into fresh buffers."""
        f = self.nbands if fidelity is None else max(1, min(fidelity, self.nbands))
        codec = get_codec(self.codec_name)
        return [codec.decode(self.raw_bands[b]) for b in range(f)]

    def ensure_decoded(self, fidelity: "int | None" = None) -> "list[bytes]":
        """Eager decode hook (runs on the ParallelBackend worker thread)."""
        out = self.decode_bands(fidelity)
        self.decoded = out
        return out

    def take_decoded(self, fidelity: int) -> "list[bytes] | None":
        """Claim the eager decode if it covers ``fidelity`` bands; clears it
        so cached frames hold compressed bytes only."""
        out, self.decoded = self.decoded, None
        if out is not None and len(out) >= fidelity:
            return out[:fidelity]
        return None

    def decoded_nbytes(self) -> int:
        return sum(len(b) for b in self.decoded) if self.decoded else 0


def encode_frame(codec_name: str, band_payloads: "list[bytes]") -> bytes:
    """Serialise one chunk: header + per-band lengths + payloads."""
    name = codec_name.encode("ascii")
    if not 1 <= len(name) <= 255:
        raise ValueError(f"codec name {codec_name!r} out of range")
    if not 1 <= len(band_payloads) <= 255:
        raise ValueError(f"band count {len(band_payloads)} out of range")
    head = bytearray(FRAME_MAGIC)
    head.append(_FRAME_VERSION)
    head.append(len(band_payloads))
    head.append(len(name))
    head += name
    for p in band_payloads:
        head += struct.pack("<I", len(p))
    return bytes(head) + b"".join(band_payloads)


def is_frame(buf: "bytes | memoryview") -> bool:
    return bytes(buf[:4]) == FRAME_MAGIC


def peek_frame(prefix: "bytes | memoryview") -> "tuple[str, int] | None":
    """``(codec_name, nbands)`` from a file prefix, or None if not a frame."""
    prefix = bytes(prefix)
    if len(prefix) < 7 or prefix[:4] != FRAME_MAGIC:
        return None
    nbands, nlen = prefix[5], prefix[6]
    if len(prefix) < 7 + nlen:
        return None
    return prefix[7 : 7 + nlen].decode("ascii"), nbands


def parse_frame(buf: "bytes | memoryview") -> ChunkFrame:
    """Split a frame into compressed band views (no decompression)."""
    mv = memoryview(buf)
    total = mv.nbytes
    if total < 7 or bytes(mv[:4]) != FRAME_MAGIC:
        raise ValueError("not a chunk frame (bad magic)")
    version, nbands, nlen = mv[4], mv[5], mv[6]
    if version != _FRAME_VERSION:
        raise ValueError(f"unsupported frame version {version}")
    pos = 7
    codec_name = bytes(mv[pos : pos + nlen]).decode("ascii")
    pos += nlen
    lens = struct.unpack_from(f"<{nbands}I", mv, pos)
    pos += 4 * nbands
    if pos + sum(lens) != total:
        raise ValueError(
            f"frame length mismatch: header says {pos + sum(lens)}, got {total}"
        )
    bands = []
    for ln in lens:
        bands.append(mv[pos : pos + ln])
        pos += ln
    return ChunkFrame(codec_name, tuple(bands), total)
