"""Parallel backend: threadpool chunk reads with bounded readahead.

This is the FanStore/Clairvoyant-prefetch move applied to Redox's chunk
loads: the protocol *hints* which chunks it will likely refill next
(:meth:`prefetch`); a small thread pool reads them in the background while
the consumer decodes records and assembles batches. A later blocking
:meth:`read` of a hinted path just claims the finished (or in-flight)
future, so the caller's stall shrinks from a full disk read to ~zero.

Readahead is bounded: at most ``readahead`` unclaimed reads exist at any
time (in-flight + completed-but-unclaimed), so speculation can never blow
up memory — excess hints are dropped, not queued. Delegated byte access
goes through an inner synchronous backend (VFS by default), which is what
makes this backend composable with any storage medium.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

from .base import StorageBackend
from .vfs import VFSBackend

__all__ = ["ParallelBackend"]


class ParallelBackend(StorageBackend):
    """Concurrent reads over an inner backend, driven by prefetch hints."""

    name = "parallel"
    wants_prefetch = True

    def __init__(
        self,
        inner: StorageBackend | None = None,
        *,
        workers: int = 4,
        readahead: int = 8,
    ):
        super().__init__()
        self.inner = inner if inner is not None else VFSBackend()
        self.readahead = int(readahead)
        self._pool = ThreadPoolExecutor(
            max_workers=int(workers), thread_name_prefix="chunk-read"
        )
        self._futures: "dict[Path, Future]" = {}
        # Hints that arrived while readahead capacity was full; promoted to
        # real background reads as claims free slots. Bounded, insertion-
        # ordered (hints arrive best-first from the protocol).
        self._backlog: "OrderedDict[Path, None]" = OrderedDict()
        self._lock = threading.Lock()
        self._closed = False

    def _submit_locked(self, path: Path) -> None:
        self._futures[path] = self._pool.submit(self.inner.read, path)
        self.stats.prefetch_issued += 1
        self.stats.peak_inflight = max(self.stats.peak_inflight, len(self._futures))

    # ------------------------------------------------------------- readahead
    def prefetch(self, paths: "list[Path]") -> None:
        """Submit background reads for ``paths``, up to the readahead bound.

        Overflow hints are remembered (bounded backlog) and promoted when a
        claim frees capacity, so readahead stays saturated across misses.
        """
        with self._lock:
            if self._closed:
                return
            for path in paths:
                if path in self._futures:
                    continue
                if len(self._futures) < self.readahead:
                    self._backlog.pop(path, None)
                    self._submit_locked(path)
                else:
                    self._backlog[path] = None
                    while len(self._backlog) > 4 * self.readahead:
                        self._backlog.popitem(last=False)

    # ----------------------------------------------------------------- reads
    def read(self, path: Path) -> "bytes | memoryview":
        with self._lock:
            fut = self._futures.pop(path, None)
            if fut is not None:
                self.stats.prefetch_hits += 1
            self._backlog.pop(path, None)  # being read now: hint is stale
            while (
                not self._closed
                and self._backlog
                and len(self._futures) < self.readahead
            ):
                nxt, _ = self._backlog.popitem(last=False)
                if nxt not in self._futures:
                    self._submit_locked(nxt)
        t0 = time.perf_counter()
        if fut is None:
            # Cold miss: read inline — bouncing through the pool would only
            # add a thread round trip to an already-blocking read.
            blob = self.inner.read(path)
        else:
            blob = fut.result()
        with self._lock:
            self.stats.wait_seconds += time.perf_counter() - t0
            self.stats.chunk_reads += 1
            self.stats.bytes_read += len(blob)
        return blob

    def read_range(self, path: Path, offset: int, length: int) -> "bytes | memoryview":
        # Ranged record reads are the baseline path; no speculation to win.
        blob = self.inner.read_range(path, offset, length)
        with self._lock:
            self.stats.ranged_reads += 1
            self.stats.bytes_read += length
        return blob

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pending = list(self._futures.values())
            self._futures.clear()
        for fut in pending:
            fut.cancel()
        self._pool.shutdown(wait=True)
        self.inner.close()
