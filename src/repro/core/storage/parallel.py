"""Parallel backend: threadpool chunk reads with bounded readahead.

This is the FanStore/Clairvoyant-prefetch move applied to Redox's chunk
loads, with two sources of readahead:

* **Exact schedule** (:meth:`schedule_reads`) — the clairvoyant planner's
  global chunk-read order. The readahead window is kept filled from the
  schedule head, so every blocking :meth:`read` claims a finished (or
  in-flight) future: prefetching is exact, not speculative.
* **Heuristic hints** (:meth:`prefetch`) — the protocol's ``_refill_hints``
  guesses, used as the fallback whenever no schedule is installed.

Readahead is bounded either way: at most ``readahead`` unclaimed reads
exist at any time (in-flight + completed-but-unclaimed), so neither source
can blow up memory — excess hints are dropped, and the schedule is drained
lazily. Delegated byte access goes through an inner synchronous backend
(VFS by default), which is what makes this backend composable with any
storage medium.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path

from .base import StorageBackend
from .vfs import VFSBackend

__all__ = ["ParallelBackend"]


class ParallelBackend(StorageBackend):
    """Concurrent reads over an inner backend: exact schedule or hints."""

    name = "parallel"
    wants_prefetch = True

    def __init__(
        self,
        inner: StorageBackend | None = None,
        *,
        workers: int = 4,
        readahead: int = 8,
    ):
        super().__init__()
        self.inner = inner if inner is not None else VFSBackend()
        self.readahead = int(readahead)
        self._pool = ThreadPoolExecutor(
            max_workers=int(workers), thread_name_prefix="chunk-read"
        )
        self._futures: "dict[Path, Future]" = {}
        self._origin: "dict[Path, str]" = {}  # path -> 'sched' | 'hint'
        # Hints that arrived while readahead capacity was full; promoted to
        # real background reads as claims free slots. Bounded, insertion-
        # ordered (hints arrive best-first from the protocol).
        self._backlog: "OrderedDict[Path, None]" = OrderedDict()
        # The exact future read order (duplicates included), drained head-
        # first into the readahead window while capacity allows.
        self._schedule: "deque[Path]" = deque()
        self._lock = threading.Lock()
        self._closed = False

    def _read_job(self, path: Path):
        """Worker-side read + decode: decompression overlaps disk I/O.

        Returns the ``_run_decoder`` tuple so the claiming thread can fold
        physical-byte and decode-time stats in under the backend lock.
        """
        return self._run_decoder(self.inner.read(path))

    def _submit_locked(self, path: Path, origin: str = "hint") -> None:
        self._futures[path] = self._pool.submit(self._read_job, path)
        self._origin[path] = origin
        if origin == "sched":
            self.stats.scheduled_issued += 1
        else:
            self.stats.prefetch_issued += 1
        self.stats.peak_inflight = max(self.stats.peak_inflight, len(self._futures))

    def _top_up_schedule_locked(self) -> None:
        while self._schedule and len(self._futures) < self.readahead:
            if self._schedule[0] in self._futures:
                # A duplicate of an in-flight read: later occurrences are
                # resubmitted after the first is claimed, keeping order.
                break
            self._submit_locked(self._schedule.popleft(), origin="sched")

    # ------------------------------------------------------------- readahead
    def schedule_reads(self, paths: "list[Path]") -> None:
        """Install the planner's exact read order and start filling it.

        *Replaces* any previous schedule: an epoch abandoned mid-replay
        (consumer broke out of the loader) must not leave stale entries or
        stale in-flight submissions pinning the readahead window. All
        unclaimed scheduled reads are discarded with the old schedule —
        after a *completed* epoch there are none, so this only costs
        anything on the abandonment path.
        """
        with self._lock:
            if self._closed:
                return
            stale = [p for p, origin in self._origin.items() if origin == "sched"]
            for p in stale:
                fut = self._futures.pop(p, None)
                if fut is not None:
                    fut.cancel()
                del self._origin[p]
            self._schedule = deque(paths)
            self._backlog.clear()  # exact knowledge supersedes guesses
            self._top_up_schedule_locked()

    @property
    def scheduled_active(self) -> bool:
        return bool(self._schedule)

    def prefetch(self, paths: "list[Path]") -> None:
        """Submit background reads for ``paths``, up to the readahead bound.

        Overflow hints are remembered (bounded backlog) and promoted when a
        claim frees capacity, so readahead stays saturated across misses.
        Ignored while an exact schedule is active — the planner already
        knows the true read order.
        """
        with self._lock:
            if self._closed or self._schedule:
                return
            for path in paths:
                if path in self._futures:
                    continue
                if len(self._futures) < self.readahead:
                    self._backlog.pop(path, None)
                    self._submit_locked(path)
                else:
                    self._backlog[path] = None
                    while len(self._backlog) > 4 * self.readahead:
                        self._backlog.popitem(last=False)

    # ----------------------------------------------------------------- reads
    def read(self, path: Path) -> "bytes | memoryview":
        with self._lock:
            fut = self._futures.pop(path, None)
            if fut is not None:
                if self._origin.pop(path, "hint") == "sched":
                    self.stats.scheduled_hits += 1
                else:
                    self.stats.prefetch_hits += 1
            elif self._schedule and self._schedule[0] == path:
                # Cold read raced ahead of its scheduled submission (window
                # momentarily full): consume the head so order stays exact.
                self._schedule.popleft()
            self._backlog.pop(path, None)  # being read now: hint is stale
            if not self._closed:
                self._top_up_schedule_locked()
            while (
                not self._closed
                and not self._schedule
                and self._backlog
                and len(self._futures) < self.readahead
            ):
                nxt, _ = self._backlog.popitem(last=False)
                if nxt not in self._futures:
                    self._submit_locked(nxt)
        t0 = time.perf_counter()
        if fut is None:
            # Cold miss: read inline — bouncing through the pool would only
            # add a thread round trip to an already-blocking read.
            blob, nraw, decode_s, decoded = self._read_job(path)
        else:
            blob, nraw, decode_s, decoded = fut.result()
        elapsed = time.perf_counter() - t0
        with self._lock:
            # Miss latency and prefetch-wait are different failure modes
            # (no readahead issued vs readahead not finished in time), so
            # they are accounted separately — see BackendStats.
            if fut is None:
                self.stats.miss_read_seconds += elapsed
                self.stats.cold_misses += 1
            else:
                self.stats.wait_seconds += elapsed
            self.stats.chunk_reads += 1
            self.stats.bytes_read += nraw
            self.stats.decode_seconds += decode_s
            self.stats.decoded_bytes += decoded
        return blob

    def read_range(self, path: Path, offset: int, length: int) -> "bytes | memoryview":
        # Ranged record reads are the baseline path; no speculation to win.
        blob = self.inner.read_range(path, offset, length)
        with self._lock:
            self.stats.ranged_reads += 1
            self.stats.bytes_read += length
        return blob

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pending = list(self._futures.values())
            self._futures.clear()
            self._origin.clear()
            self._schedule.clear()
        for fut in pending:
            fut.cancel()
        self._pool.shutdown(wait=True)
        self.inner.close()
