"""Chunk store: the on-disk batched layout (paper §3.2 "Data Chunk Generation").

A chunk is one file on disk: the concatenation of its member records, plus a
sidecar offset index. This is the paper's one-time dataset re-organisation
("the pre-organized data chunks can be re-used to train different models").
Reads happen at two granularities:

* ``read_chunk``  — one sequential read of the whole chunk (Redox path);
* ``read_file``   — a seek + ranged read of one record (baseline path —
  models PyTorch's per-file access against the same bytes).

The store is deliberately VFS-only (plain ``open``/``seek``/``read``), like
the paper's implementation: "it does not depend on any specific storage".
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .chunking import ChunkingPlan

__all__ = ["ChunkStore"]


class ChunkStore:
    """Directory of chunk files + offset indexes for one dataset."""

    def __init__(self, root: str | Path, plan: ChunkingPlan):
        self.root = Path(root)
        self.plan = plan
        self._offsets: dict[int, np.ndarray] | None = None

    # -------------------------------------------------------------- writing
    @staticmethod
    def build(
        root: str | Path,
        plan: ChunkingPlan,
        records: "list[bytes] | RecordProvider",
    ) -> "ChunkStore":
        """One-time chunk-file generation (paper Fig. 2a)."""
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        offsets = {}
        for k in range(plan.num_chunks):
            files = plan.files_in_chunk(k)
            blobs = [records[int(f)] for f in files]
            sizes = np.array([len(b) for b in blobs], dtype=np.int64)
            if not np.array_equal(sizes, plan.file_sizes[files]):
                raise ValueError(f"record sizes disagree with plan for chunk {k}")
            offs = np.zeros(len(blobs) + 1, dtype=np.int64)
            np.cumsum(sizes, out=offs[1:])
            with open(root / f"chunk_{k:08d}.bin", "wb") as fh:
                for b in blobs:
                    fh.write(b)
            offsets[k] = offs
        index = {
            str(k): [int(x) for x in offs] for k, offs in offsets.items()
        }
        (root / "index.json").write_text(json.dumps(index))
        plan.save(root / "plan.npz")
        store = ChunkStore(root, plan)
        store._offsets = {int(k): np.asarray(v) for k, v in index.items()}
        return store

    # -------------------------------------------------------------- reading
    def _index(self) -> dict[int, np.ndarray]:
        if self._offsets is None:
            raw = json.loads((self.root / "index.json").read_text())
            self._offsets = {int(k): np.asarray(v, dtype=np.int64) for k, v in raw.items()}
        return self._offsets

    def read_chunk(self, chunk: int) -> list[tuple[int, bytes]]:
        """One batched read -> [(file_id, record_bytes), ...] in slot order."""
        offs = self._index()[chunk]
        files = self.plan.files_in_chunk(chunk)
        with open(self.root / f"chunk_{chunk:08d}.bin", "rb") as fh:
            blob = fh.read()
        return [
            (int(f), blob[offs[j] : offs[j + 1]]) for j, f in enumerate(files)
        ]

    def read_file(self, file_id: int) -> bytes:
        """Seek + ranged read of a single record (baseline access pattern)."""
        k = int(self.plan.chunk_of[file_id])
        j = int(self.plan.slot_of[file_id])
        offs = self._index()[k]
        with open(self.root / f"chunk_{k:08d}.bin", "rb") as fh:
            fh.seek(int(offs[j]))
            return fh.read(int(offs[j + 1] - offs[j]))

    @staticmethod
    def open(root: str | Path) -> "ChunkStore":
        root = Path(root)
        plan = ChunkingPlan.load(root / "plan.npz")
        return ChunkStore(root, plan)
