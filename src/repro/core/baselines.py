"""The paper's three baselines, protocol-exact (paper §5.1 "Baselines").

* :class:`PyTorchStyleLoader` — per-file random reads in sequence order,
  memory managed by an OS-page-cache-like byte-capacity LRU. Under a
  uniformly random exactly-once sequence with dataset ≫ memory the LRU hit
  rate collapses toward ``memory/dataset`` — the paper's §2.1 observation.
* :class:`CoorDLLoader` — MinIO-style fixed cache [Mohan et al., VLDB'21]:
  a static fraction of files is pinned in memory, never evicted; in the
  distributed setting a file cached on a *peer* is fetched over the network
  instead of from disk. No randomness sacrificed; hit rate bounded by the
  global memory/dataset ratio.
* :class:`NoIOLoader` — zero-I/O upper bound (data synthesised on the fly).

All loaders consume the *same* access sequences, report the same
:class:`~repro.core.stats.StepIO` demand units, and are priced by the same
:class:`~repro.core.stats.PipelineTimeModel`, so speedups are apples to
apples.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from .chunking import ChunkingPlan
from .sampler import EpochSampler
from .stats import NodeStats, StepIO

__all__ = ["PyTorchStyleLoader", "CoorDLLoader", "NoIOLoader", "run_baseline_epoch"]


class _LRUBytes:
    """Byte-capacity LRU of file ids (page-cache stand-in)."""

    def __init__(self, capacity: int, sizes: np.ndarray):
        self.capacity = int(capacity)
        self._sizes = sizes
        self._cache: OrderedDict[int, int] = OrderedDict()
        self.used = 0

    def hit(self, f: int) -> bool:
        if f in self._cache:
            self._cache.move_to_end(f)
            return True
        return False

    def admit(self, f: int) -> None:
        size = int(self._sizes[f])
        if size > self.capacity:
            return
        while self.used + size > self.capacity and self._cache:
            _, old = self._cache.popitem(last=False)
            self.used -= old
        self._cache[f] = size
        self.used += size


class PyTorchStyleLoader:
    """Native-DataLoader baseline: one small-file read per access."""

    name = "pytorch"

    def __init__(self, plan: ChunkingPlan, num_nodes: int, memory_bytes: int):
        self.plan = plan
        self.num_nodes = num_nodes
        self.caches = [
            _LRUBytes(memory_bytes, plan.file_sizes) for _ in range(num_nodes)
        ]
        self.stats = NodeStats()

    def access(self, r: int, pos: int, f: int, io_by_node: dict[int, StepIO]) -> int:
        self.stats.accesses += 1
        if self.caches[r].hit(f):
            self.stats.local_hits += 1
            return f
        self.stats.memory_misses += 1
        io = io_by_node.setdefault(r, StepIO())
        io.file_reads += 1
        io.disk_bytes += int(self.plan.file_sizes[f])
        self.stats.disk_bytes += int(self.plan.file_sizes[f])
        self.stats.filled_bytes += int(self.plan.file_sizes[f])
        self.caches[r].admit(f)
        return f


class CoorDLLoader:
    """Fixed-cache baseline with cross-node cache sharing (CoorDL/MinIO)."""

    name = "coordl"

    def __init__(self, plan: ChunkingPlan, num_nodes: int, memory_bytes: int, seed: int = 0):
        self.plan = plan
        self.num_nodes = num_nodes
        rng = np.random.default_rng(seed)
        order = rng.permutation(plan.num_files)
        # Pin a prefix of a random order on each node's memory budget,
        # partitioned so each file is cached on at most one node.
        self.cached_on = np.full(plan.num_files, -1, dtype=np.int32)
        budgets = [memory_bytes] * num_nodes
        node = 0
        for f in order:
            size = int(plan.file_sizes[f])
            placed = False
            for _ in range(num_nodes):
                if budgets[node] >= size:
                    self.cached_on[f] = node
                    budgets[node] -= size
                    placed = True
                    break
                node = (node + 1) % num_nodes
            if not placed:
                break
            node = (node + 1) % num_nodes
        self.stats = NodeStats()

    def access(self, r: int, pos: int, f: int, io_by_node: dict[int, StepIO]) -> int:
        self.stats.accesses += 1
        holder = int(self.cached_on[f])
        io = io_by_node.setdefault(r, StepIO())
        if holder == r:
            self.stats.local_hits += 1
        elif holder >= 0:
            # Peer-cache fetch over the network (CoorDL's cross-node sharing).
            self.stats.remote_requests += 1
            io.net_messages += 1
            io.net_bytes += int(self.plan.file_sizes[f])
            self.stats.net_bytes += int(self.plan.file_sizes[f])
        else:
            self.stats.memory_misses += 1
            io.file_reads += 1
            io.disk_bytes += int(self.plan.file_sizes[f])
            self.stats.disk_bytes += int(self.plan.file_sizes[f])
        return f


class NoIOLoader:
    """Upper bound: data generated in memory, zero I/O demand."""

    name = "no_io"

    def __init__(self, plan: ChunkingPlan, num_nodes: int):
        self.plan = plan
        self.num_nodes = num_nodes
        self.stats = NodeStats()

    def access(self, r: int, pos: int, f: int, io_by_node: dict[int, StepIO]) -> int:
        self.stats.accesses += 1
        self.stats.local_hits += 1
        return f


def run_baseline_epoch(
    loader, sampler: EpochSampler, epoch: int, batch_per_node: int
) -> tuple[NodeStats, list[list[StepIO]]]:
    """Drive one epoch of a baseline loader with the DP-barrier step loop."""
    import math

    seqs = sampler.node_sequences(epoch)
    num_nodes = loader.num_nodes
    steps = max(math.ceil(len(s) / batch_per_node) for s in seqs)
    per_node_step_io: list[list[StepIO]] = [[] for _ in range(num_nodes)]
    for step in range(steps):
        io_by_node: dict[int, StepIO] = {}
        for r in range(num_nodes):
            seq = seqs[r]
            lo, hi = step * batch_per_node, min((step + 1) * batch_per_node, seq.size)
            for pos in range(lo, hi):
                loader.access(r, pos, int(seq[pos]), io_by_node)
        for r in range(num_nodes):
            per_node_step_io[r].append(io_by_node.get(r, StepIO()))
    return loader.stats, per_node_step_io
