"""Counters and the epoch-time pipeline model.

The protocol itself is deterministic given its RNG, so every quantity the
paper reports (Table 4/5, Fig. 12-14) is either an exact counter collected
here or a time derived from the counters through
:class:`PipelineTimeModel` (documented below, calibration in DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "DeviceStats",
    "NodeStats",
    "PipelineTimeModel",
    "PlannerStats",
    "ServiceStats",
    "StatsDict",
    "StepIO",
]


class StatsDict:
    """Round-trippable dict form for stats dataclasses.

    ``to_dict()`` emits the dataclass fields only (derived ``@property``
    ratios are recomputed on the way back in), so
    ``cls.from_dict(x.to_dict()) == x`` holds exactly. This is the one
    serialization every consumer shares: ``MetricsRegistry.collect()``,
    the transport stats/metrics RPCs, and the benchmark JSON records.
    """

    def to_dict(self) -> dict:
        return {
            f.name: getattr(self, f.name) for f in dataclasses.fields(self)
        }

    @classmethod
    def from_dict(cls, d: dict):
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class NodeStats(StatsDict):
    """Exact per-node protocol counters for one epoch."""

    accesses: int = 0
    local_hits: int = 0            # served by a valid local abstract slot
    memory_misses: int = 0         # slot empty -> a chunk load was required
    chunk_loads: int = 0           # batched disk reads issued
    remote_requests: int = 0       # on-demand requests sent to an owner
    remote_prefetch_hits: int = 0  # served from the remote abstract memory
    prefetch_sent: int = 0         # files this node shipped as prefetch
    prefetch_received: int = 0

    disk_bytes: int = 0            # total bytes batched in from storage
    filled_bytes: int = 0          # bytes of those that landed in a slot
    wasted_bytes: int = 0          # disk_bytes - filled_bytes (paper fill_rate waste)
    net_bytes: int = 0             # on-demand + prefetch payload bytes
    net_messages: int = 0

    fill_rate_num: float = 0.0     # sum of fill_rate over chunk loads
    read_wait_s: float = 0.0       # wall time blocked on storage chunk reads
    peak_local_bytes: int = 0
    peak_remote_bytes: int = 0
    # Backend's max concurrent background reads. NB: the storage backend is
    # shared across nodes and epochs, so unlike the other peaks this mirrors
    # its store-lifetime high-water mark (identically in live and replay).
    peak_inflight_reads: int = 0

    @property
    def mean_fill_rate(self) -> float:
        return self.fill_rate_num / self.chunk_loads if self.chunk_loads else 1.0

    @property
    def read_throughput(self) -> float:
        """Observed chunk-read throughput: bytes batched in per blocked second."""
        return self.disk_bytes / self.read_wait_s if self.read_wait_s > 0 else 0.0

    def merge(self, other: "NodeStats") -> "NodeStats":
        out = NodeStats()
        for f in dataclasses.fields(NodeStats):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if f.name.startswith("peak"):
                setattr(out, f.name, max(a, b))
            else:
                setattr(out, f.name, a + b)
        return out

    def copy(self) -> "NodeStats":
        return dataclasses.replace(self)


@dataclasses.dataclass
class PlannerStats(StatsDict):
    """Counters for the clairvoyant plan/execute split (core/planner.py).

    ``scheduled_read_hits`` vs ``heuristic_prefetch_hits`` separates backend
    reads served by the planner's exact chunk schedule from those served by
    the ``_refill_hints`` heuristic readahead (the fallback when no plan is
    attached); the executing loader fills them in from the backend counters
    after the epoch.
    """

    plan_time_s: float = 0.0       # wall time to compute the EpochPlan
    planned_steps: int = 0         # training steps covered by the plan
    planned_accesses: int = 0      # total accesses scheduled
    planned_chunk_loads: int = 0   # exact chunk-read schedule length
    planned_ships: int = 0         # opportunistic prefetch ships scheduled
    scheduled_read_hits: int = 0   # backend reads served by the exact schedule
    heuristic_prefetch_hits: int = 0  # reads served by heuristic readahead


@dataclasses.dataclass
class ServiceStats(StatsDict):
    """Shared-residency counters for one job (or, merged, for a whole
    :class:`repro.service.DataService`).

    ``shared_hits`` are chunk claims served from the shared cache — each one
    is a duplicate disk read avoided (``dup_loads_avoided`` is the same
    quantity under the paper-facing name). ``physical_*`` are the reads that
    actually reached the storage backend on behalf of this job.

    On a compressed store (DESIGN.md §15) the cache holds compressed
    frames and every claim decodes its own copy, so the byte counters
    split: ``physical_bytes``/``shared_bytes``/``peak_cache_bytes`` count
    *physical* (compressed) bytes — what disk and cache capacity actually
    see — while ``logical_bytes`` counts the decoded bytes handed to
    sessions. Their ratio is the effective capacity multiplier the codec
    buys; ``decode_claims``/``decode_s`` price what it costs.
    """

    physical_reads: int = 0    # chunk reads that hit the storage backend
    physical_bytes: int = 0    # physical (on-disk, possibly compressed) bytes
    shared_hits: int = 0       # chunk claims served from the shared cache
    shared_bytes: int = 0      # physical bytes of those claims (reads avoided)
    logical_bytes: int = 0     # decoded record bytes handed to sessions
    decode_claims: int = 0     # claims that ran a per-claim frame decode
    decode_s: float = 0.0      # wall time spent in per-claim decodes
    co_refill_hits: int = 0    # refill choices steered by the co-refill hook
    evictions: int = 0         # cache-limit evictions (claims may re-read)
    cache_bypass: int = 0      # reads served but refused caching (cap pressure)
    peak_cache_bytes: int = 0  # high-water mark of shared cache residency

    @property
    def dup_loads_avoided(self) -> int:
        return self.shared_hits

    def merge(self, other: "ServiceStats") -> "ServiceStats":
        out = ServiceStats()
        for f in dataclasses.fields(ServiceStats):
            a, b = getattr(self, f.name), getattr(other, f.name)
            setattr(out, f.name, max(a, b) if f.name.startswith("peak") else a + b)
        return out

    def copy(self) -> "ServiceStats":
        return dataclasses.replace(self)


@dataclasses.dataclass
class StepIO(StatsDict):
    """Per-training-step I/O demand of one node (input to the time model)."""

    chunk_loads: int = 0
    disk_bytes: int = 0
    file_reads: int = 0   # per-file reads (baselines only; Redox never does these)
    net_messages: int = 0
    net_bytes: int = 0
    read_wait_s: float = 0.0  # *measured* storage stall (real-bytes runs only)
    # Host->device staging (DESIGN.md §12): wall time spent preparing and
    # shipping this step's device batch, and the slice of it the consumer
    # actually waited on (0 when staging was fully hidden behind compute).
    stage_s: float = 0.0
    stage_wait_s: float = 0.0

    def add(self, other: "StepIO") -> None:
        self.chunk_loads += other.chunk_loads
        self.disk_bytes += other.disk_bytes
        self.file_reads += other.file_reads
        self.net_messages += other.net_messages
        self.net_bytes += other.net_bytes
        self.read_wait_s += other.read_wait_s
        self.stage_s += other.stage_s
        self.stage_wait_s += other.stage_wait_s


@dataclasses.dataclass
class DeviceStats(StatsDict):
    """Host→device staging counters for one :class:`DeviceStager` stream.

    ``stage_s`` is wall time the staging thread spent assembling + shipping
    batches (decode/pack, ``device_put``, gather-kernel dispatch);
    ``wait_s`` is the consumer time actually blocked on a staged batch —
    the part of staging the double buffer failed to hide.
    ``overlap_fraction`` is therefore the headline number: 1.0 means the
    device path is free, 0.0 means it is fully serialized (the naive
    per-step copy behaves like 0.0 by construction).
    """

    steps: int = 0
    bytes_to_device: int = 0   # payload bytes shipped (slot buffers or grids)
    stage_s: float = 0.0       # staging-thread wall time
    wait_s: float = 0.0        # consumer wall time blocked on the queue
    kernel_steps: int = 0      # steps assembled on-device by chunk_gather
    buffers_released: int = 0  # staged-but-unconsumed batches freed at teardown

    @property
    def overlap_fraction(self) -> float:
        """Share of staging time hidden behind compute, in [0, 1].

        Zero staging time means nothing was staged, so nothing was
        overlapped — report 0.0 rather than dividing by zero (or the old,
        misleading 1.0 for an idle stager)."""
        if self.stage_s <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.wait_s / self.stage_s)


@dataclasses.dataclass(frozen=True)
class PipelineTimeModel:
    """Double-buffered loader model.

    Every DL framework under test (PyTorch DataLoader with workers, CoorDL,
    Redox clients) overlaps data loading with compute, so the wall time of a
    step is ``max(compute, io)`` and epoch time is the per-step sum, maxed
    over nodes (data-parallel barrier at each step). I/O time of a step is

        io = file_reads * file_overhead + chunk_loads * chunk_overhead
           + disk_bytes / disk_bw + net_messages * net_latency
           + net_bytes / net_bw

    ``file_overhead`` is the per-small-file cost (metadata + head positioning
    on NAS) that batching amortises — the mechanism behind the paper's Fig. 13
    I/O-throughput gains. Calibration to the paper's Table 2 setups lives in
    ``benchmarks/calibration.py``.
    """

    disk_bw: float          # bytes/s sequential
    file_overhead: float    # s per individual small-file read
    chunk_overhead: float   # s per batched chunk read
    net_bw: float           # bytes/s
    net_latency: float      # s per message

    def io_time(self, io: StepIO) -> float:
        return (
            io.file_reads * self.file_overhead
            + io.chunk_loads * self.chunk_overhead
            + io.disk_bytes / self.disk_bw
            + io.net_messages * self.net_latency
            + io.net_bytes / self.net_bw
        )

    def epoch_time(
        self, per_node_step_io: list[list[StepIO]], compute_per_step: float
    ) -> float:
        """Pipelined bound: ``max_node (max(Σcompute, Σio) + pipeline fill)``.

        Loaders run ahead through a prefetch queue, so bursty chunk loads
        (which cluster at epoch start, when the abstract memory is empty)
        are smoothed across the epoch; only the first batch's I/O sits on
        the critical path. This matches the paper's own observation that
        Brand reaches No-I/O time for compute-heavy models (Fig. 10d). The
        strict no-queue model is kept as :meth:`epoch_time_strict`.
        """
        worst = 0.0
        for steps in per_node_step_io:
            total_io = sum(self.io_time(s) for s in steps)
            fill = self.io_time(steps[0]) if steps else 0.0
            t = max(compute_per_step * len(steps), total_io) + fill
            worst = max(worst, t)
        return worst

    def epoch_time_strict(
        self, per_node_step_io: list[list[StepIO]], compute_per_step: float
    ) -> float:
        """``max_node Σ_step max(compute, io_step)`` — no prefetch queue."""
        worst = 0.0
        for steps in per_node_step_io:
            t = sum(max(compute_per_step, self.io_time(s)) for s in steps)
            worst = max(worst, t)
        return worst
