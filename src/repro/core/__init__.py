"""Redox core: batched random access with file redirection (the paper's contribution)."""

from .abstract_memory import AbstractMemory
from .baselines import CoorDLLoader, NoIOLoader, PyTorchStyleLoader, run_baseline_epoch
from .chunking import ChunkingPlan
from .distributed import Cluster, EpochResult, RemoteMemory
from .loader import RedoxLoader
from .protocol import LocalNode, RequestResult
from .sampler import EpochSampler
from .stats import NodeStats, PipelineTimeModel, StepIO
from .storage import ChunkStore

__all__ = [
    "AbstractMemory",
    "ChunkingPlan",
    "ChunkStore",
    "Cluster",
    "CoorDLLoader",
    "EpochResult",
    "EpochSampler",
    "LocalNode",
    "NoIOLoader",
    "NodeStats",
    "PipelineTimeModel",
    "PyTorchStyleLoader",
    "RedoxLoader",
    "RemoteMemory",
    "RequestResult",
    "run_baseline_epoch",
    "StepIO",
]
