"""Redox core: batched random access with file redirection (the paper's contribution)."""

from .abstract_memory import AbstractMemory
from .baselines import CoorDLLoader, NoIOLoader, PyTorchStyleLoader, run_baseline_epoch
from .chunking import ChunkingPlan
from .distributed import Cluster, EpochResult, RemoteMemory
from .elastic import ClusterSnapshot
from .loader import RedoxLoader
from .planner import EpochPlan, EpochPlanner
from .protocol import LocalNode, RequestResult
from .sampler import EpochSampler
from .spec import SessionSpec, StoreSpec
from .stats import (
    DeviceStats,
    NodeStats,
    PipelineTimeModel,
    PlannerStats,
    ServiceStats,
    StepIO,
)
from .storage import (
    BACKENDS,
    CODECS,
    BackendStats,
    ChunkStore,
    MmapBackend,
    ParallelBackend,
    StorageBackend,
    VFSBackend,
    get_codec,
    make_backend,
)

__all__ = [
    "AbstractMemory",
    "BACKENDS",
    "BackendStats",
    "CODECS",
    "ChunkingPlan",
    "ChunkStore",
    "Cluster",
    "ClusterSnapshot",
    "CoorDLLoader",
    "DeviceStager",
    "DeviceStats",
    "EpochPlan",
    "EpochPlanner",
    "EpochResult",
    "EpochSampler",
    "LocalNode",
    "MmapBackend",
    "NoIOLoader",
    "NodeStats",
    "ParallelBackend",
    "PipelineTimeModel",
    "PlannerStats",
    "PyTorchStyleLoader",
    "RedoxLoader",
    "RemoteMemory",
    "RequestResult",
    "run_baseline_epoch",
    "ServiceStats",
    "SessionSpec",
    "StepIO",
    "StorageBackend",
    "StoreSpec",
    "VFSBackend",
    "get_codec",
    "make_backend",
]


def __getattr__(name):
    # DeviceStager lives behind a lazy import: core itself is numpy-only,
    # and the transport's subprocess trainers must not pay the jax import
    # unless they actually take the device path.
    if name in ("DeviceStager", "HostPack", "pack_records"):
        from . import device

        return getattr(device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
