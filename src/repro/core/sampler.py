"""Epoch access-sequence generation and per-node partitioning (paper §2.1, §3.4).

The DL framework owns randomness: at each epoch it shuffles ``range(N)``
with a seeded RNG and walks that sequence. Redox never alters the sequence —
it redirects *what data* each index returns. In the distributed setting the
global sequence is partitioned evenly across nodes exactly like
``torch.utils.data.DistributedSampler`` (strided: node r takes positions
``r::num_nodes``), and — crucially for the prefetch protocol — the
*pre-generated* per-node sequences are replicated to every node so an owner
can look ahead into any requester's future accesses.
"""

from __future__ import annotations

import numpy as np

__all__ = ["EpochSampler"]


class EpochSampler:
    """Deterministic per-epoch global shuffles, partitioned across nodes."""

    def __init__(self, num_files: int, num_nodes: int = 1, seed: int = 1234):
        if num_nodes < 1:
            raise ValueError("num_nodes >= 1")
        self.num_files = num_files
        self.num_nodes = num_nodes
        self.seed = seed

    def global_sequence(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.num_files).astype(np.int64)

    def node_sequences(self, epoch: int) -> list[np.ndarray]:
        """Strided even partition of the global sequence (replicated to all)."""
        seq = self.global_sequence(epoch)
        return [seq[r :: self.num_nodes] for r in range(self.num_nodes)]
