"""RedoxLoader: the bridge from the redirection protocol to JAX training.

This replaces the DL framework's *data fetcher* exactly as the paper does
for PyTorch (§4.2): the framework still generates its random per-epoch
sequence; the loader walks it, but every index is served through the Redox
protocol, so the batch contains *redirected* (still uniformly random,
exactly-once) records.

Batches are fixed-shape ``(batch, seq_len)`` int32 token grids with a loss
mask (documents are clipped/padded — standard LM practice), so the jitted
train step never recompiles.

Straggler mitigation (DESIGN.md §5): an optional background prefetch queue
(`queue_depth`) runs the protocol walk (and its storage reads) ahead of
consumption on a worker thread, while decode + grid assembly happen on the
consumer side at ``__next__`` time — a two-stage pipeline. With a parallel
storage backend the chunk reads themselves also overlap (protocol hints →
bounded readahead), so a slow chunk read or remote round trip only stalls
training once the queue drains, mirroring the paper's client/server split
where clients hide server latency.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

from ..data.tokens import decode_record
from .distributed import Cluster
from .sampler import EpochSampler
from .stats import StepIO

__all__ = ["RedoxLoader", "GlobalBatch"]


class GlobalBatch(dict):
    """dict with tokens/targets/loss_mask ndarrays (converted by the step fn)."""


def _to_grid(records: list[np.ndarray], seq_len: int, pad_id: int):
    """Clip/pad variable-length documents into a fixed (B, S) grid + mask."""
    b = len(records)
    tokens = np.full((b, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((b, seq_len), dtype=np.float32)
    for i, rec in enumerate(records):
        n = min(rec.shape[0], seq_len)
        tokens[i, :n] = rec[:n]
        mask[i, :n] = 1.0
    return tokens, mask


class RedoxLoader:
    """Iterator over global batches served by a (possibly 1-node) cluster."""

    def __init__(
        self,
        cluster: Cluster,
        sampler: EpochSampler,
        *,
        batch_per_node: int,
        seq_len: int,
        pad_id: int = 0,
        queue_depth: int = 2,
    ):
        assert cluster.num_nodes == sampler.num_nodes
        self.cluster = cluster
        self.sampler = sampler
        self.batch_per_node = batch_per_node
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.queue_depth = queue_depth

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = min(len(s) for s in self.sampler.node_sequences(epoch))
        return n // self.batch_per_node

    # ------------------------------------------------------------- epochs
    def epoch(self, epoch: int):
        """Yield GlobalBatch objects; runs protocol inline (deterministic)."""
        for payloads, step, io_by_node in self._produce(epoch):
            yield self._assemble(payloads, step, io_by_node)

    def epoch_async(self, epoch: int):
        """Same batches, two-stage pipeline (double-buffered).

        Stage 1 (worker thread): protocol walk + chunk reads — with a
        parallel backend these are themselves overlapped via readahead.
        Stage 2 (this thread): record decode + ``_to_grid`` assembly,
        running while the worker's next reads are in flight.
        """
        q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        stop = object()
        failure: list[BaseException] = []

        def worker():
            try:
                for item in self._produce(epoch):
                    q.put(item)
            except BaseException as e:  # re-raised on the consumer side
                failure.append(e)
            finally:
                q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield self._assemble(*item)
        t.join()
        if failure:
            # A failed protocol walk or storage read must not end the epoch
            # cleanly — the consumer would silently train on a short epoch.
            raise failure[0]

    # ------------------------------------------------------------ internals
    def _assemble(self, payloads, step: int, io_by_node: dict[int, StepIO]):
        """Decode raw record payloads and pack the fixed-shape grid."""
        flat = [decode_record(p) for p in payloads]
        tokens, mask = _to_grid(flat, self.seq_len + 1, self.pad_id)
        return GlobalBatch(
            tokens=tokens[:, :-1],
            targets=tokens[:, 1:],
            loss_mask=mask[:, 1:],
            step=step,
            io_by_node=io_by_node,
        )

    def _produce(self, epoch: int):
        """Walk the protocol; yield (raw payloads, step, io) per step."""
        cluster, sampler = self.cluster, self.sampler
        seqs = cluster.begin_epoch(sampler, epoch)
        num_nodes = cluster.num_nodes
        steps = min(len(s) for s in seqs) // self.batch_per_node
        for step in range(steps):
            io_by_node: dict[int, StepIO] = {}
            payloads: list = []
            for r in range(num_nodes):
                lo = step * self.batch_per_node
                for pos in range(lo, lo + self.batch_per_node):
                    fid, data = cluster.access(r, pos, int(seqs[r][pos]), io_by_node)
                    assert data is not None, (
                        "RedoxLoader requires a Cluster built with a ChunkStore"
                    )
                    payloads.append(data)
            yield payloads, step, io_by_node
        # Drain the ragged tail so the exactly-once epoch invariants hold.
        io_by_node = {}
        for r in range(num_nodes):
            for pos in range(steps * self.batch_per_node, len(seqs[r])):
                cluster.access(r, pos, int(seqs[r][pos]), io_by_node)
        cluster._check_epoch_complete()
