"""RedoxLoader: the bridge from the redirection protocol to JAX training.

This replaces the DL framework's *data fetcher* exactly as the paper does
for PyTorch (§4.2): the framework still generates its random per-epoch
sequence; the loader walks it, but every index is served through the Redox
protocol, so the batch contains *redirected* (still uniformly random,
exactly-once) records.

Batches are fixed-shape ``(batch, seq_len)`` int32 token grids with a loss
mask (documents are clipped/padded — standard LM practice), so the jitted
train step never recompiles.

Straggler mitigation (DESIGN.md §5): an optional background prefetch queue
(`queue_depth`) assembles batches ahead of consumption on a worker thread —
a slow chunk read or remote round trip only stalls training once the queue
drains, mirroring the paper's client/server split where clients hide server
latency.
"""

from __future__ import annotations

import math
import queue
import threading

import numpy as np

from ..data.tokens import decode_record
from .distributed import Cluster
from .sampler import EpochSampler
from .stats import StepIO

__all__ = ["RedoxLoader", "GlobalBatch"]


class GlobalBatch(dict):
    """dict with tokens/targets/loss_mask ndarrays (converted by the step fn)."""


def _to_grid(records: list[np.ndarray], seq_len: int, pad_id: int):
    """Clip/pad variable-length documents into a fixed (B, S) grid + mask."""
    b = len(records)
    tokens = np.full((b, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((b, seq_len), dtype=np.float32)
    for i, rec in enumerate(records):
        n = min(rec.shape[0], seq_len)
        tokens[i, :n] = rec[:n]
        mask[i, :n] = 1.0
    return tokens, mask


class RedoxLoader:
    """Iterator over global batches served by a (possibly 1-node) cluster."""

    def __init__(
        self,
        cluster: Cluster,
        sampler: EpochSampler,
        *,
        batch_per_node: int,
        seq_len: int,
        pad_id: int = 0,
        queue_depth: int = 2,
    ):
        assert cluster.num_nodes == sampler.num_nodes
        self.cluster = cluster
        self.sampler = sampler
        self.batch_per_node = batch_per_node
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.queue_depth = queue_depth

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = min(len(s) for s in self.sampler.node_sequences(epoch))
        return n // self.batch_per_node

    # ------------------------------------------------------------- epochs
    def epoch(self, epoch: int):
        """Yield GlobalBatch objects; runs protocol inline (deterministic)."""
        yield from self._produce(epoch)

    def epoch_async(self, epoch: int):
        """Same batches, assembled ahead of time on a worker thread."""
        q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        stop = object()

        def worker():
            try:
                for item in self._produce(epoch):
                    q.put(item)
            finally:
                q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
        t.join()

    # ------------------------------------------------------------ internals
    def _produce(self, epoch: int):
        cluster, sampler = self.cluster, self.sampler
        seqs = cluster.begin_epoch(sampler, epoch)
        num_nodes = cluster.num_nodes
        steps = min(len(s) for s in seqs) // self.batch_per_node
        for step in range(steps):
            io_by_node: dict[int, StepIO] = {}
            per_node: list[list[np.ndarray]] = []
            for r in range(num_nodes):
                recs = []
                lo = step * self.batch_per_node
                for pos in range(lo, lo + self.batch_per_node):
                    fid, data = cluster.access(r, pos, int(seqs[r][pos]), io_by_node)
                    assert data is not None, (
                        "RedoxLoader requires a Cluster built with a ChunkStore"
                    )
                    recs.append(decode_record(data))
                per_node.append(recs)
            flat = [rec for recs in per_node for rec in recs]
            tokens, mask = _to_grid(flat, self.seq_len + 1, self.pad_id)
            yield GlobalBatch(
                tokens=tokens[:, :-1],
                targets=tokens[:, 1:],
                loss_mask=mask[:, 1:],
                step=step,
                io_by_node=io_by_node,
            )
        # Drain the ragged tail so the exactly-once epoch invariants hold.
        io_by_node = {}
        for r in range(num_nodes):
            for pos in range(steps * self.batch_per_node, len(seqs[r])):
                cluster.access(r, pos, int(seqs[r][pos]), io_by_node)
        cluster._check_epoch_complete()
