"""RedoxLoader: the bridge from the redirection protocol to JAX training.

This replaces the DL framework's *data fetcher* exactly as the paper does
for PyTorch (§4.2): the framework still generates its random per-epoch
sequence; the loader walks it, but every index is served through the Redox
protocol, so the batch contains *redirected* (still uniformly random,
exactly-once) records.

Batches are fixed-shape ``(batch, seq_len)`` int32 token grids with a loss
mask (documents are clipped/padded — standard LM practice), so the jitted
train step never recompiles.

Clairvoyant epochs (DESIGN.md §8): by default each epoch is *planned*
before it is executed — an :class:`EpochPlanner` simulates the protocol in
id-space (cheap NumPy batch work), and the epoch then replays the plan:
the storage backend receives the exact global chunk-read schedule
(``ChunkStore.schedule_reads``) so its readahead is prefetch-exact rather
than heuristic. ``use_planner=False`` restores the live walk (the
``_refill_hints`` heuristic drives readahead instead).

Straggler mitigation (DESIGN.md §5): an optional background prefetch queue
(`queue_depth`) runs the protocol walk (and its storage reads) ahead of
consumption on a worker thread, while decode + grid assembly happen on the
consumer side at ``__next__`` time — a two-stage pipeline. With a parallel
storage backend the chunk reads themselves also overlap, so a slow chunk
read or remote round trip only stalls training once the queue drains,
mirroring the paper's client/server split where clients hide server
latency.
"""

from __future__ import annotations

import json
import queue
import threading
from pathlib import Path

import numpy as np

from repro.obs import tracer as trace

from ..data.tokens import decode_record
from .distributed import Cluster
from .elastic import ClusterSnapshot
from .planner import EpochPlanner
from .sampler import EpochSampler
from .spec import SessionSpec
from .stats import StepIO

__all__ = ["RedoxLoader", "GlobalBatch", "SessionSpec"]

LOADER_MANIFEST = "loader_manifest.json"


class GlobalBatch(dict):
    """dict with tokens/targets/loss_mask ndarrays (converted by the step fn)."""


def _to_grid(records: list[np.ndarray], seq_len: int, pad_id: int):
    """Clip/pad variable-length documents into a fixed (B, S) grid + mask."""
    b = len(records)
    tokens = np.full((b, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((b, seq_len), dtype=np.float32)
    for i, rec in enumerate(records):
        n = min(rec.shape[0], seq_len)
        tokens[i, :n] = rec[:n]
        mask[i, :n] = 1.0
    return tokens, mask


class RedoxLoader:
    """Iterator over global batches served by a (possibly 1-node) cluster."""

    def __init__(
        self,
        cluster: Cluster,
        sampler: EpochSampler,
        *,
        batch_per_node: int,
        seq_len: int,
        pad_id: int = 0,
        queue_depth: int = 2,
        use_planner: "bool | None" = None,
        engine: "str | None" = None,
    ):
        assert cluster.num_nodes == sampler.num_nodes
        if engine is None:
            # Back-compat spelling: use_planner=True/False maps to the
            # planned replay vs the batched live walk.
            engine = "replay" if (use_planner is None or use_planner) else "step"
        if engine not in ("replay", "step", "per_access"):
            raise ValueError(f"unknown loader engine {engine!r}")
        self.cluster = cluster
        self.sampler = sampler
        self.batch_per_node = batch_per_node
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.queue_depth = queue_depth
        self.engine = engine
        self.last_plan = None       # EpochPlan of the most recent epoch
        self._worker: threading.Thread | None = None
        # Suspend/resume bookkeeping (DESIGN.md §10): the consumption cursor
        # (epoch, next step) advanced as batches are yielded, the underlying
        # step stream of a running sync epoch (closable by suspend), whether
        # the current epoch is consumed through epoch_async, and the pending
        # resume point installed by RedoxLoader.resume().
        self._progress: "tuple[int, int] | None" = None
        self._live_stream = None
        self._async_epoch = False
        self._resume: "dict | None" = None

    @property
    def use_planner(self) -> bool:
        return self.engine == "replay"

    @classmethod
    def from_spec(cls, spec: SessionSpec, store) -> "RedoxLoader":
        """Build the whole Cluster + EpochSampler + RedoxLoader stack from
        one :class:`~repro.core.spec.SessionSpec`.

        This is THE session constructor: ``DataService.open_session`` and
        the transport server both delegate here, so a spec means exactly
        the same stack everywhere (a single-session service run is
        byte-identical to ``RedoxLoader.from_spec(spec, store)``).
        """
        if spec.fidelity is not None:
            # Progressive decode (DESIGN.md §15): the session owns its
            # store handle (a real ChunkStore in-process, a per-session
            # _SessionStore facade under the service), so setting its
            # standing fidelity scopes truncation to this session.
            store.default_fidelity = spec.fidelity
        cluster = Cluster(
            store.plan,
            spec.num_nodes,
            policy=spec.policy,
            seed=spec.seed,
            store=store,
            prefetch=spec.prefetch,
            prefetch_window=spec.prefetch_window,
            remote_memory_limit_bytes=spec.remote_memory_limit_bytes,
        )
        sampler = EpochSampler(
            store.plan.num_files, spec.num_nodes, seed=spec.effective_sampler_seed
        )
        return cls(
            cluster,
            sampler,
            batch_per_node=spec.batch_per_node,
            seq_len=spec.seq_len,
            pad_id=spec.pad_id,
            queue_depth=spec.queue_depth,
            engine=spec.engine,
        )

    @property
    def spec(self) -> SessionSpec:
        """The SessionSpec this loader stack embodies (reconstructed from
        live state, so it is exact for ``from_spec``-built loaders and a
        best-effort description otherwise)."""
        return SessionSpec(
            policy=self.cluster.policy,
            seed=self.cluster.seed,
            sampler_seed=self.sampler.seed,
            num_nodes=self.cluster.num_nodes,
            batch_per_node=self.batch_per_node,
            seq_len=self.seq_len,
            pad_id=self.pad_id,
            engine=self.engine,
            prefetch=self.cluster.prefetch,
            prefetch_window=self.cluster.prefetch_window,
            remote_memory_limit_bytes=self.cluster._remote_limit,
            queue_depth=self.queue_depth,
            fidelity=getattr(self.cluster.store, "default_fidelity", None),
        )

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = min(len(s) for s in self.sampler.node_sequences(epoch))
        return n // self.batch_per_node

    # ------------------------------------------------------------- epochs
    def epoch(self, epoch: int, *, plan=None):
        """Yield GlobalBatch objects; runs protocol inline (deterministic)."""
        self._async_epoch = False
        produce = self._produce(epoch, plan=plan)
        self._live_stream = produce
        for item in produce:
            batch = self._assemble(*item)
            # Cursor advances as the batch is handed over: a consumer that
            # breaks right after this yield suspends at the next step.
            self._progress = (epoch, int(batch["step"]) + 1)
            yield batch

    def epoch_async(self, epoch: int, *, plan=None):
        """Same batches, two-stage pipeline (double-buffered).

        Stage 1 (worker thread): protocol walk + chunk reads — with a
        parallel backend these are themselves overlapped via readahead.
        Stage 2 (this thread): record decode + ``_to_grid`` assembly,
        running while the worker's next reads are in flight.

        If the consumer abandons the generator early (``break``, an
        exception, or explicit ``close()``), the worker is signalled to
        shut down and joined — it must never stay blocked on a full queue
        (the epoch's protocol state is then mid-flight; a later
        ``begin_epoch`` asserts on the undrained memory by design).
        """
        yield from self._pipelined(epoch, plan=plan, assemble=self._assemble)

    def epoch_device(self, epoch: int, stager=None, *, plan=None):
        """Device-resident batches (DESIGN.md §12): the host pipeline packs
        slot buffers instead of grids, and a :class:`~repro.core.device.
        DeviceStager` double-buffers ``device_put`` + the Pallas
        ``chunk_gather_train`` assembly against the consumer's train step.

        Yields ``GlobalBatch``es whose tokens/targets/loss_mask are device
        arrays. Abandoning the generator tears down stager and protocol
        worker deterministically — staged-but-unconsumed device buffers
        are released, not stranded.
        """
        from .device import DeviceStager  # deferred: pulls in jax + kernels

        if stager is None:
            stager = DeviceStager()
        def pack(*item):
            return self._pack(*item, row_pad=stager.row_pad)

        packs = self._pipelined(epoch, plan=plan, assemble=pack, track=False)
        for batch in stager.stream(packs):
            self._progress = (epoch, int(batch["step"]) + 1)
            yield batch

    def _pipelined(self, epoch: int, *, plan, assemble, track: bool = True):
        """The epoch_async machinery, parametrised over batch assembly."""
        q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        stop = object()
        abandoned = threading.Event()
        failure: list[BaseException] = []

        def put(item) -> bool:
            """Blocking put that aborts when the consumer is gone."""
            while not abandoned.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for item in self._produce(epoch, plan=plan):
                    if not put(item):
                        return
            except BaseException as e:  # re-raised on the consumer side
                failure.append(e)
            finally:
                put(stop)

        t = threading.Thread(target=worker, daemon=True)
        self._worker = t
        self._async_epoch = True
        t.start()
        try:
            while True:
                item = q.get()
                if item is stop:
                    break
                batch = assemble(*item)
                if track:
                    self._progress = (epoch, int(batch["step"]) + 1)
                yield batch
        finally:
            abandoned.set()
            while True:  # drain so a blocked put() observes the signal fast
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join()
        if failure:
            # A failed protocol walk or storage read must not end the epoch
            # cleanly — the consumer would silently train on a short epoch.
            raise failure[0]

    # ------------------------------------------------------------ internals
    def _assemble(
        self,
        payloads,
        step: int,
        io_by_node: dict[int, StepIO],
        returned: "list[np.ndarray] | None" = None,
    ):
        """Decode raw record payloads and pack the fixed-shape grid."""
        with trace.span("loader.assemble", "decode", step=int(step)):
            flat = [decode_record(p) for p in payloads]
            tokens, mask = _to_grid(flat, self.seq_len + 1, self.pad_id)
        return GlobalBatch(
            tokens=tokens[:, :-1],
            targets=tokens[:, 1:],
            loss_mask=mask[:, 1:],
            step=step,
            io_by_node=io_by_node,
            # The redirected file ids behind each grid row, in row order —
            # lets equivalence/FT tests compare streams without re-decoding.
            returned=(
                np.concatenate(returned)
                if returned is not None else np.empty(0, dtype=np.int64)
            ),
        )

    def _pack(
        self,
        payloads,
        step: int,
        io_by_node: dict[int, StepIO],
        returned: "list[np.ndarray] | None" = None,
        *,
        row_pad: int = 8,
    ):
        """Decode payloads into a HostPack for the device gather path."""
        from .device import HostPack, pack_records

        with trace.span("loader.pack", "decode", step=int(step)):
            flat = [decode_record(p) for p in payloads]
            ret = (
                np.concatenate(returned)
                if returned is not None else np.empty(0, dtype=np.int64)
            )
            slot_tokens, lens, idx = pack_records(
                flat, ret if ret.size else None,
                seq_len=self.seq_len, pad_id=self.pad_id, row_pad=row_pad,
            )
        return HostPack(
            slot_tokens=slot_tokens, lens=lens, idx=idx,
            seq_len=self.seq_len, pad_id=self.pad_id,
            step=step, io_by_node=io_by_node, returned=ret,
        )

    def _produce(self, epoch: int, *, plan=None):
        """Yield (payloads, step, io, returned) per step — plan/execute split.

        Same plan-driven driver as ``Cluster.run_epoch``: under the
        ``"replay"`` engine the epoch is first computed in id-space
        (:class:`EpochPlanner`) — or a pre-computed ``plan`` is passed in by
        a :class:`repro.service.DataService`, which plans all of its
        sessions at once — the exact chunk-read schedule is handed to the
        storage backend, and the recorded events are replayed. The live
        engines (``"step"`` batched / ``"per_access"`` reference) walk the
        protocol directly with heuristic readahead.
        """
        cluster = self.cluster
        assert cluster.store is not None, (
            "RedoxLoader requires a Cluster built with a ChunkStore"
        )
        resume = self._resume
        if resume is not None and resume["epoch"] != epoch:
            # The restored cluster holds mid-epoch state for the suspended
            # epoch; walking any other epoch over it would trip the
            # begin_epoch drain assertions with a misleading message — and
            # silently dropping the saved suffix would violate exactly-once.
            raise RuntimeError(
                f"loader was resumed mid-epoch {resume['epoch']} (next step "
                f"{resume['start_step']}); consume that epoch to completion "
                f"before asking for epoch {epoch}"
            )
        self._progress = (epoch, resume["start_step"] if resume else 0)
        if self.engine == "replay":
            if plan is None:
                if resume is not None:
                    # Re-plan only the epoch *suffix* from the snapshot; the
                    # backend's readahead schedule is exactly the remaining
                    # chunk reads.
                    plan = EpochPlanner(cluster).plan_from(resume["snapshot"])
                else:
                    plan = EpochPlanner(cluster).plan(
                        self.sampler, epoch, self.batch_per_node,
                        stepping="floor_tail",
                    )
            self.last_plan = plan
            # Per-plan hit attribution is a delta over the (possibly shared)
            # backend's counters — exact for a lone loader, approximate when
            # service sessions run concurrently over one backend.
            b = cluster.backend_stats
            before = (b.scheduled_hits, b.prefetch_hits)
            stream = cluster.replay_stream(
                plan, epoch=epoch, batch_per_node=self.batch_per_node,
                stepping="floor_tail",
            )
        else:
            plan, before = None, None
            stream = cluster.epoch_stream(
                self.sampler if resume is None else None,
                epoch, self.batch_per_node,
                stepping="floor_tail", engine=self.engine, collect_payloads=True,
                resume=resume is not None,
                start_step=resume["start_step"] if resume else 0,
            )
        for step, returned, payloads, io_by_node in stream:
            yield payloads, step, io_by_node, returned
        if self._resume is resume:
            self._resume = None  # the resumed epoch completed
        if plan is not None:
            b = cluster.backend_stats
            plan.stats.scheduled_read_hits = b.scheduled_hits - before[0]
            plan.stats.heuristic_prefetch_hits = b.prefetch_hits - before[1]

    # ------------------------------------------------------ suspend/resume
    def suspend(self, out_dir: "str | Path", *, at: "tuple[int, int] | None" = None):
        """Checkpoint the data plane mid-epoch (DESIGN.md §10).

        Writes a :class:`~repro.core.elastic.ClusterSnapshot`
        (``data_state.npz`` + ``data_manifest.json``) plus a loader manifest
        under ``out_dir`` — the data-plane sibling of a model checkpoint. A
        fresh process resumes with :meth:`RedoxLoader.resume` and the batch
        stream continues byte-identically.

        ``at=(epoch, next_step)`` defaults to the loader's own consumption
        cursor. For the ``"replay"`` engine the snapshot is *derived* (a
        store-less shadow walks the epoch prefix in id-space), so training
        can keep consuming batches while suspend() runs — snapshot-without-
        stopping, the property the ``--resume-data`` launchers rely on. For
        the live engines the loader's protocol state IS the stream state:
        the current sync epoch stream is closed at its step boundary and the
        live cluster is captured (``epoch_async`` live walks run ahead of
        consumption and cannot be suspended exactly).
        """
        at = at or self._progress or self.resume_point
        if at is None:
            raise RuntimeError("suspend() before any epoch was started")
        epoch, next_step = int(at[0]), int(at[1])
        if self.engine == "replay":
            snap = EpochPlanner(self.cluster).state_at(
                self.sampler, epoch, self.batch_per_node, next_step,
                stepping="floor_tail",
            )
        else:
            if self._async_epoch:
                raise RuntimeError(
                    "live-engine epoch_async streams prefetch ahead of "
                    "consumption and cannot be suspended exactly; use the "
                    "replay engine (default) or the synchronous epoch()"
                )
            if (self._progress or self.resume_point) != (epoch, next_step):
                raise RuntimeError(
                    "a live engine can only suspend at its own cursor "
                    f"{self._progress or self.resume_point}, not {at!r}"
                )
            if self._live_stream is not None:
                self._live_stream.close()
                self._live_stream = None
            if self.cluster.sequences is None or self.cluster.epoch != epoch:
                # The epoch was never entered (e.g. a pump suspended before
                # reaching this session): materialise its step-0 state.
                assert next_step == 0, "mid-epoch cursor but no epoch state"
                self.cluster.begin_epoch(self.sampler, epoch)
                self.cluster._grid = (self.batch_per_node, "floor_tail")
            snap = self.cluster.snapshot(step=next_step)
        out_dir = Path(out_dir)
        snap.save(out_dir)
        (out_dir / LOADER_MANIFEST).write_text(json.dumps(dict(
            engine=self.engine,
            batch_per_node=self.batch_per_node,
            seq_len=self.seq_len,
            pad_id=self.pad_id,
            queue_depth=self.queue_depth,
            epoch=epoch,
            next_step=next_step,
            sampler=dict(
                num_files=self.sampler.num_files,
                num_nodes=self.sampler.num_nodes,
                seed=self.sampler.seed,
            ),
        )))
        return out_dir

    @classmethod
    def resume(cls, in_dir: "str | Path", store, **overrides) -> "RedoxLoader":
        """Rebuild a suspended loader from :meth:`suspend` files — typically
        in a fresh process holding only the (re-opened) ChunkStore.

        The next ``loader.epoch(epoch)`` / ``epoch_async(epoch)`` call for
        the suspended epoch continues from the saved step: the replay engine
        re-plans just the suffix (``EpochPlanner.plan_from``) and hands the
        remaining chunk schedule to the backend; live engines walk on from
        the restored protocol state. ``overrides`` replace loader-only knobs
        (``queue_depth``, ``seq_len``, ...), never protocol state.
        """
        in_dir = Path(in_dir)
        mf = json.loads((in_dir / LOADER_MANIFEST).read_text())
        snap = ClusterSnapshot.load(in_dir)
        cluster = Cluster.restore(snap, store=store)
        smp = mf["sampler"]
        sampler = EpochSampler(
            int(smp["num_files"]), int(smp["num_nodes"]), seed=smp["seed"]
        )
        kwargs = dict(
            batch_per_node=int(mf["batch_per_node"]),
            seq_len=int(mf["seq_len"]),
            pad_id=int(mf["pad_id"]),
            queue_depth=int(mf["queue_depth"]),
            engine=mf["engine"],
        )
        kwargs.update(overrides)
        loader = cls(cluster, sampler, **kwargs)
        loader._resume = {
            "epoch": int(mf["epoch"]),
            "start_step": int(mf["next_step"]),
            "snapshot": snap,
        }
        return loader

    @property
    def resume_point(self) -> "tuple[int, int] | None":
        """(epoch, next_step) a resumed loader will continue from, if any."""
        if self._resume is None:
            return None
        return self._resume["epoch"], self._resume["start_step"]
