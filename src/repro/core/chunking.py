"""Chunk generation and the chunk → abstract-chunk mapping (paper §3.2, Fig. 2).

Terminology (kept 1:1 with the paper):

* **file** — one training record (variable size). ``N`` files total.
* **chunk** — ``chunk_size`` (``c``) consecutive files of a one-time global
  shuffle, stored contiguously so a chunk is read from storage in one batched
  request. Chunk membership is fixed at dataset-preparation time and reused
  across epochs *and* across training jobs.
* **slot** — a file's index inside its chunk (``0 .. c-1``).
* **abstract chunk** — ``c`` abstract memory locations. There are
  ``A = M // c`` abstract chunks for ``M`` abstract memory locations
  (``M ≈ memory_bytes / mean_file_size``).
* **chunk group** — the ``n = ceil(num_chunks / A)`` chunks mapped onto one
  abstract chunk. The paper picks *consecutive* chunks per group (it argues
  interleaving buys nothing because returned data is random anyway); we do
  the same.
* **abstract location id** — ``group_id * c + slot``; globally unique.

The plan is pure metadata (numpy arrays); no file bytes are touched here.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
from pathlib import Path

import numpy as np

__all__ = ["ChunkingPlan"]


@dataclasses.dataclass(frozen=True)
class ChunkingPlan:
    """Immutable description of the file → chunk → abstract-chunk mapping."""

    num_files: int
    chunk_size: int
    num_chunks: int
    num_groups: int  # == number of abstract chunks (A)
    group_width: int  # n: max chunks per group
    seed: int

    file_sizes: np.ndarray  # int64[N] bytes
    # chunk_files[k, j] = file id at slot j of chunk k, or -1 (partial last chunk)
    chunk_files: np.ndarray  # int64[num_chunks, c]
    chunk_of: np.ndarray  # int32[N]
    slot_of: np.ndarray  # int32[N]
    group_of_chunk: np.ndarray  # int32[num_chunks]
    chunk_bytes: np.ndarray  # int64[num_chunks] total bytes incl. every member file

    # ------------------------------------------------------------------ build
    @staticmethod
    def create(
        file_sizes: np.ndarray,
        chunk_size: int,
        *,
        num_slots: int | None = None,
        memory_bytes: int | None = None,
        seed: int = 0,
    ) -> "ChunkingPlan":
        """Build the one-time plan (paper Fig. 2a/2b).

        Exactly one of ``num_slots`` (M) or ``memory_bytes`` (C) must be
        given; the paper sets ``M = C / mean_file_size``.
        """
        file_sizes = np.asarray(file_sizes, dtype=np.int64)
        n_files = int(file_sizes.shape[0])
        if n_files == 0:
            raise ValueError("empty dataset")
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if (num_slots is None) == (memory_bytes is None):
            raise ValueError("give exactly one of num_slots / memory_bytes")
        if num_slots is None:
            mean_size = float(file_sizes.mean())
            num_slots = max(int(memory_bytes / mean_size), chunk_size)
        # M must cover at least one abstract chunk.
        num_slots = max(int(num_slots), chunk_size)

        num_chunks = math.ceil(n_files / chunk_size)
        num_groups = min(max(num_slots // chunk_size, 1), num_chunks)
        group_width = math.ceil(num_chunks / num_groups)

        rng = np.random.default_rng(seed)
        perm = rng.permutation(n_files).astype(np.int64)

        chunk_files = np.full((num_chunks, chunk_size), -1, dtype=np.int64)
        flat = chunk_files.reshape(-1)
        flat[:n_files] = perm

        chunk_of = np.empty(n_files, dtype=np.int32)
        slot_of = np.empty(n_files, dtype=np.int32)
        k_idx = np.arange(num_chunks * chunk_size) // chunk_size
        s_idx = np.arange(num_chunks * chunk_size) % chunk_size
        chunk_of[perm] = k_idx[:n_files].astype(np.int32)
        slot_of[perm] = s_idx[:n_files].astype(np.int32)

        group_of_chunk = (
            np.arange(num_chunks, dtype=np.int32) // group_width
        ).astype(np.int32)

        padded_sizes = np.where(chunk_files >= 0, file_sizes[np.maximum(chunk_files, 0)], 0)
        chunk_bytes = padded_sizes.sum(axis=1).astype(np.int64)

        return ChunkingPlan(
            num_files=n_files,
            chunk_size=chunk_size,
            num_chunks=num_chunks,
            num_groups=num_groups,
            group_width=group_width,
            seed=seed,
            file_sizes=file_sizes,
            chunk_files=chunk_files,
            chunk_of=chunk_of,
            slot_of=slot_of,
            group_of_chunk=group_of_chunk,
            chunk_bytes=chunk_bytes,
        )

    # ------------------------------------------------------------- accessors
    @property
    def num_slots(self) -> int:
        """Total abstract memory locations M (= A * c)."""
        return self.num_groups * self.chunk_size

    @functools.cached_property
    def chunk_valid(self) -> np.ndarray:
        """bool[num_chunks, c]: real member at (chunk, slot) (plan is
        immutable, so the protocol hot path caches this once)."""
        return self.chunk_files >= 0

    @functools.cached_property
    def chunk_files_clipped(self) -> np.ndarray:
        """``maximum(chunk_files, 0)``: safe gather index for -1 padding."""
        return np.maximum(self.chunk_files, 0)

    def group_chunk_range(self, group: int) -> tuple[int, int]:
        """Half-open chunk-id range [start, end) of ``group``."""
        start = group * self.group_width
        end = min(start + self.group_width, self.num_chunks)
        return start, end

    def group_of_file(self, file_id: int) -> int:
        return int(self.group_of_chunk[self.chunk_of[file_id]])

    def location_of_file(self, file_id: int) -> int:
        """Abstract location id = group * chunk_size + slot (paper Fig. 2b)."""
        return self.group_of_file(file_id) * self.chunk_size + int(self.slot_of[file_id])

    def locations_of_files(self, file_ids: np.ndarray) -> np.ndarray:
        """Vectorised :meth:`location_of_file`."""
        file_ids = np.asarray(file_ids)
        groups = self.group_of_chunk[self.chunk_of[file_ids]].astype(np.int64)
        return groups * self.chunk_size + self.slot_of[file_ids]

    def files_in_chunk(self, chunk: int) -> np.ndarray:
        files = self.chunk_files[chunk]
        return files[files >= 0]

    # ------------------------------------------------------------ persistence
    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(
            path,
            meta=json.dumps(
                dict(
                    num_files=self.num_files,
                    chunk_size=self.chunk_size,
                    num_chunks=self.num_chunks,
                    num_groups=self.num_groups,
                    group_width=self.group_width,
                    seed=self.seed,
                )
            ),
            file_sizes=self.file_sizes,
            chunk_files=self.chunk_files,
            chunk_of=self.chunk_of,
            slot_of=self.slot_of,
            group_of_chunk=self.group_of_chunk,
            chunk_bytes=self.chunk_bytes,
        )

    @staticmethod
    def load(path: str | Path) -> "ChunkingPlan":
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta"]))
            return ChunkingPlan(
                **meta,
                file_sizes=z["file_sizes"],
                chunk_files=z["chunk_files"],
                chunk_of=z["chunk_of"],
                slot_of=z["slot_of"],
                group_of_chunk=z["group_of_chunk"],
                chunk_bytes=z["chunk_bytes"],
            )
