"""Distributed Redox: ownership, remote access, opportunistic prefetch (paper §3.4).

Memory organisation (Fig. 5): every node shares one view of the global
abstract memory. Each abstract chunk (= chunk group) has a single *owner*
node which also stores the group's chunks on its local disk. Owners run the
unmodified local protocol; non-owners reach a group only through its owner.

Opportunistic prefetch (Fig. 6): on a remote miss the requester piggybacks
its current sequence position and its remaining remote-memory budget. The
owner serves the miss via the local protocol, then walks the requester's
*pre-shared* access sequence over the next ``prefetch_window`` positions and
ships any file that (a) it owns, (b) is already resident in its abstract
memory (opportunistic — never loads from disk for a prefetch), (c) whose
abstract location is provably vacant on the requester ("Prefetch Check
List": no outstanding prefetch to that location), and (d) fits the
requester's remote-memory budget. A shipped file is consumed at the sender
immediately — which empties sender slots early and *raises* later refill
fill-rates (Fig. 7's positive side-effect).

Fault tolerance: :meth:`Cluster.remap_ownership` implements the elastic
ownership remap described in DESIGN.md §5 — on node loss the dead node's
groups are reassigned to survivors, its *memory* contents are lost (those
files were not yet consumed, so the new owner simply re-fetches them from
the replicated chunk store), and its consumption journal (4 bytes/file,
durably logged in any real deployment) is recovered so exactly-once is
preserved.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .chunking import ChunkingPlan
from .protocol import LocalNode
from .sampler import EpochSampler
from .stats import NodeStats, StepIO

__all__ = ["Cluster", "EpochResult", "RemoteMemory"]


def _build_loc_index(locs: np.ndarray) -> dict[int, np.ndarray]:
    """location -> sorted positions at which a node's sequence touches it."""
    if locs.size == 0:
        return {}
    order = np.argsort(locs, kind="stable")
    sorted_locs = locs[order]
    cuts = np.nonzero(np.diff(sorted_locs))[0] + 1
    starts = np.concatenate([[0], cuts])
    ends = np.concatenate([cuts, [locs.size]])
    return {
        int(sorted_locs[a]): np.sort(order[a:b]).astype(np.int64)
        for a, b in zip(starts, ends)
    }


class RemoteMemory:
    """Requester-side bounded cache of prefetched files, keyed by location."""

    def __init__(self, limit_bytes: int, file_sizes: np.ndarray):
        self.limit_bytes = int(limit_bytes)
        self._sizes = file_sizes
        self._data: dict[int, tuple[int, bytes | None]] = {}  # loc -> (file, payload)
        self.used_bytes = 0
        self.peak_bytes = 0

    def __contains__(self, loc: int) -> bool:
        return loc in self._data

    @property
    def free_bytes(self) -> int:
        return self.limit_bytes - self.used_bytes

    def put(self, loc: int, file_id: int, data: bytes | None = None) -> None:
        size = int(self._sizes[file_id])
        assert loc not in self._data, "prefetch landed on an occupied location"
        assert size <= self.free_bytes, "prefetch overran the remote-memory budget"
        self._data[loc] = (file_id, data)
        self.used_bytes += size
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def take(self, loc: int) -> tuple[int, bytes | None]:
        file_id, data = self._data.pop(loc)
        self.used_bytes -= int(self._sizes[file_id])
        return file_id, data

    def __len__(self) -> int:
        return len(self._data)


@dataclasses.dataclass
class EpochResult:
    stats: NodeStats                      # cluster-wide aggregate
    node_stats: list[NodeStats]
    per_node_step_io: list[list[StepIO]]  # input to PipelineTimeModel
    returned: list[np.ndarray]            # per node: files actually consumed


class Cluster:
    """In-process distributed Redox cluster (protocol-exact, timing-modelled).

    Flags reproduce the paper's ablations (Table 4/5):

    * ``policy="max_fill", prefetch=True``   -> Brand
    * ``policy="random",   prefetch=True``   -> Brand-random-selection
    * ``policy="max_fill", prefetch=False``  -> Brand-no-prefetching
    * ``policy="random",   prefetch=False``  -> Brand-no-optimization
    """

    def __init__(
        self,
        plan: ChunkingPlan,
        num_nodes: int,
        *,
        remote_memory_limit_bytes: int = 1 << 62,
        prefetch_window: int = 64,
        policy: str = "max_fill",
        prefetch: bool = True,
        seed: int = 0,
        store=None,
    ):
        self.plan = plan
        self.num_nodes = num_nodes
        self.prefetch_window = prefetch_window
        self.prefetch = prefetch
        # Contiguous group ranges per owner: data is partitioned across node
        # disks before training (paper §3.4).
        g = np.arange(plan.num_groups, dtype=np.int64)
        self.owner_of_group = np.minimum(
            g * num_nodes // max(plan.num_groups, 1), num_nodes - 1
        ).astype(np.int32)
        self.nodes = [
            LocalNode(plan, policy=policy, seed=(seed, 7, r), store=store)
            for r in range(num_nodes)
        ]
        self.remote_mem = [
            RemoteMemory(remote_memory_limit_bytes, plan.file_sizes)
            for _ in range(num_nodes)
        ]
        # pending[o][r]: location -> sequence position of r when the prefetch
        # was sent. Mirrors r's remote memory restricted to o-owned locations.
        self.pending: list[list[dict[int, int]]] = [
            [dict() for _ in range(num_nodes)] for _ in range(num_nodes)
        ]
        self.sequences: list[np.ndarray] | None = None
        self._loc_of_seq: list[np.ndarray] | None = None
        self._loc_positions: list[dict[int, np.ndarray]] | None = None
        self.failed = np.zeros(num_nodes, dtype=bool)

    @property
    def store(self):
        """The shared ChunkStore (None in simulation-only mode)."""
        return self.nodes[0].store if self.nodes else None

    @property
    def backend_stats(self):
        """Aggregate storage-backend counters, or None without a store.

        All LocalNodes share one store/backend instance (one disk per node is
        modelled by the time model, not by separate backends), so this is the
        cluster-wide view: prefetch hits, peak in-flight reads, and the
        blocking-read throughput that ``benchmarks/io_overhead.py --backend``
        reports per backend.
        """
        store = self.store
        return store.backend_stats if store is not None else None

    # ------------------------------------------------------------ lifecycle
    def begin_epoch(self, sampler: EpochSampler, epoch: int) -> list[np.ndarray]:
        for node in self.nodes:
            node.begin_epoch()
        for rm in self.remote_mem:
            assert len(rm) == 0, "remote abstract memory not drained"
        for row in self.pending:
            for d in row:
                d.clear()
        self.sequences = sampler.node_sequences(epoch)
        # Per-node position index: location -> sorted positions at which the
        # node will access it. Owners use this to run the Prefetch Check List
        # without any extra communication (sequences are pre-shared).
        self._loc_of_seq = [self.plan.locations_of_files(s) for s in self.sequences]
        self._loc_positions = [_build_loc_index(locs) for locs in self._loc_of_seq]
        return self.sequences

    # -------------------------------------------------------------- access
    def access(
        self, r: int, pos: int, file_id: int, io_by_node: dict[int, StepIO]
    ) -> tuple[int, bytes | None]:
        """Node ``r`` performs the access at position ``pos`` of its sequence.

        Returns ``(returned_file_id, payload)`` — the payload is None in
        simulation mode (no ChunkStore attached).
        """
        plan = self.plan
        g = plan.group_of_file(file_id)
        o = int(self.owner_of_group[g])
        stats_r = self.nodes[r].stats

        if o == r:
            res = self.nodes[r].request(file_id)
            io_by_node.setdefault(r, StepIO()).add(res.io)
            return res.file_id, res.data

        loc = plan.location_of_file(file_id)
        rm = self.remote_mem[r]
        if loc in rm:
            # Served by previously prefetched data — no network round trip.
            stats_r.accesses += 1
            stats_r.remote_prefetch_hits += 1
            return rm.take(loc)

        # Remote miss: request the owner (paper Fig. 6).
        stats_r.remote_requests += 1
        self._cleanup_pending(o, r, pos)
        res = self.nodes[o].request(file_id)
        # Owner's batched disk read happens on the owner; the response bytes
        # travel to the requester (see stats.py for the time model).
        io_by_node.setdefault(o, StepIO()).add(res.io)
        io_r = io_by_node.setdefault(r, StepIO())
        io_r.net_messages += 1
        io_r.net_bytes += int(plan.file_sizes[res.file_id])
        if self.prefetch:
            self._opportunistic_prefetch(o, r, pos, io_r)
        return res.file_id, res.data

    def _cleanup_pending(self, o: int, r: int, pos: int) -> None:
        """Drop pending entries the requester has provably consumed (< pos)."""
        pend = self.pending[o][r]
        if not pend:
            return
        positions = self._loc_positions[r]
        done = []
        for loc_id, sent_pos in pend.items():
            plist = positions.get(loc_id)
            if plist is None:
                continue
            nxt = np.searchsorted(plist, sent_pos, side="right")
            if nxt < plist.size and plist[nxt] < pos:
                done.append(loc_id)
        for loc_id in done:
            del pend[loc_id]

    def _opportunistic_prefetch(self, o: int, r: int, pos: int, io_r: StepIO) -> None:
        plan = self.plan
        seq = self.sequences[r]
        locs = self._loc_of_seq[r]
        pend = self.pending[o][r]
        rm = self.remote_mem[r]
        owner_mem = self.nodes[o].memory
        end = min(pos + 1 + self.prefetch_window, seq.size)
        for q in range(pos + 1, end):
            fq = int(seq[q])
            gq = plan.group_of_file(fq)
            if int(self.owner_of_group[gq]) != o:
                continue
            loc_q = int(locs[q])
            if loc_q in pend:
                continue  # requester slot occupied by an outstanding prefetch
            sq = loc_q - gq * plan.chunk_size
            file_p = owner_mem.get(gq, sq)
            if file_p < 0:
                continue  # opportunistic: never read disk for a prefetch
            size = int(plan.file_sizes[file_p])
            if size > rm.free_bytes:
                continue  # respect the piggybacked remote-memory budget
            _, data = self.nodes[o].take_for_prefetch(gq, sq)
            rm.put(loc_q, file_p, data)
            pend[loc_q] = pos
            self.nodes[r].stats.prefetch_received += 1
            io_r.net_bytes += size
            self.nodes[r].stats.peak_remote_bytes = max(
                self.nodes[r].stats.peak_remote_bytes, rm.peak_bytes
            )

    # -------------------------------------------------------------- drivers
    def run_epoch(
        self,
        sampler: EpochSampler,
        epoch: int,
        batch_per_node: int,
        *,
        collect_returned: bool = True,
    ) -> EpochResult:
        """Execute a full epoch with per-step node interleaving (DP barrier)."""
        seqs = self.begin_epoch(sampler, epoch)
        steps = max(math.ceil(len(s) / batch_per_node) for s in seqs)
        per_node_step_io: list[list[StepIO]] = [[] for _ in range(self.num_nodes)]
        returned: list[list[int]] = [[] for _ in range(self.num_nodes)]
        for step in range(steps):
            io_by_node: dict[int, StepIO] = {}
            for r in range(self.num_nodes):
                if self.failed[r]:
                    continue
                seq = self.sequences[r]
                lo, hi = step * batch_per_node, min((step + 1) * batch_per_node, seq.size)
                for pos in range(lo, hi):
                    f, _ = self.access(r, pos, int(seq[pos]), io_by_node)
                    if collect_returned:
                        returned[r].append(f)
            for r in range(self.num_nodes):
                per_node_step_io[r].append(io_by_node.get(r, StepIO()))
        self._check_epoch_complete()
        node_stats = [n.stats for n in self.nodes]
        agg = node_stats[0]
        for s in node_stats[1:]:
            agg = agg.merge(s)
        return EpochResult(
            stats=agg,
            node_stats=node_stats,
            per_node_step_io=per_node_step_io,
            returned=[np.asarray(rt, dtype=np.int64) for rt in returned],
        )

    def _check_epoch_complete(self) -> None:
        """Every file consumed at its (current) owner; all memories drained.

        Exactly-once of the *returned stream* is asserted separately by the
        property tests (counting each file in ``EpochResult.returned``) —
        here we check the owner-side bookkeeping, which must hold even after
        an elastic ownership remap.
        """
        for r in range(self.num_nodes):
            if self.failed[r]:
                continue
            assert self.nodes[r].memory.is_empty(), "local abstract memory not drained"
            assert len(self.remote_mem[r]) == 0, "remote abstract memory not drained"
        owner_of_file = self.owner_of_group[
            self.plan.group_of_chunk[self.plan.chunk_of]
        ]
        for r in range(self.num_nodes):
            if self.failed[r]:
                continue
            mask = owner_of_file == r
            assert self.nodes[r].consumed[mask].all(), (
                "a file was never consumed (exactly-once violated)"
            )

    # ------------------------------------------------------- fault tolerance
    def fail_node(self, dead: int, processed_upto: int) -> None:
        """Node ``dead`` fails after completing ``processed_upto`` accesses.

        Its unprocessed sequence tail is redistributed round-robin and
        *appended* to the survivors' sequences — appending keeps every
        existing position stable, so outstanding prefetch bookkeeping
        (keyed by position) remains exact; only the location index is
        rebuilt. Ownership is then remapped (see :meth:`remap_ownership`).
        """
        assert self.sequences is not None, "fail_node outside an epoch"
        tail = self.sequences[dead][processed_upto:]
        self.sequences[dead] = self.sequences[dead][:processed_upto]
        self.remap_ownership(dead)
        survivors = [r for r in range(self.num_nodes) if not self.failed[r]]
        shares = [tail[i :: len(survivors)] for i in range(len(survivors))]
        for r, share in zip(survivors, shares):
            self.sequences[r] = np.concatenate([self.sequences[r], share])
        # Rebuild the per-node location indexes (positions in the unchanged
        # prefixes are preserved, so pending[o][r] entries stay valid).
        self._loc_of_seq = [self.plan.locations_of_files(s) for s in self.sequences]
        self._loc_positions = [_build_loc_index(locs) for locs in self._loc_of_seq]

    def remap_ownership(self, dead: int) -> None:
        """Elastic remap after node ``dead`` fails mid-epoch (DESIGN.md §5).

        Durable state (disk chunks — replicated/NAS-resident in the paper's
        setups — and the consumption journal) survives; volatile state (the
        node's abstract-memory residents and its un-consumed prefetches held
        *for* it) is re-fetchable from disk precisely because never-evicted
        residents are by definition un-consumed.
        """
        assert not self.failed[dead]
        self.failed[dead] = True
        survivors = [r for r in range(self.num_nodes) if not self.failed[r]]
        assert survivors, "no survivors"
        # 1. Reassign the dead node's groups round-robin to survivors.
        dead_groups = np.nonzero(self.owner_of_group == dead)[0]
        for i, grp in enumerate(dead_groups):
            self.owner_of_group[grp] = survivors[i % len(survivors)]
        # 2. Its residents are lost with its memory: un-consume nothing (they
        #    were never consumed) and clear the slots so the new owner's
        #    refills can re-fetch the files from the replicated store.
        mem = self.nodes[dead].memory
        live = np.nonzero(mem.resident.reshape(-1) >= 0)[0]
        for flat in live:
            g, s = divmod(int(flat), self.plan.chunk_size)
            mem.take(g, s)
        # 3. Migrate the consumption journal to the new owners. Our in-process
        #    LocalNodes each hold a full-size consumed bitmap, so survivors
        #    merge the dead node's journal directly.
        journal = self.nodes[dead].consumed
        for r in survivors:
            self.nodes[r].consumed |= journal
        # 4. Outstanding prefetches *from* the dead node already live in the
        #    requesters' remote memories (real data — still valid). Pending
        #    bookkeeping moves nowhere: new owners start with empty pending,
        #    which is safe (conservative) because requesters re-miss at most
        #    once per location.
        for r in range(self.num_nodes):
            merged: dict[int, int] = {}
            merged.update(self.pending[dead][r])
            for loc, p in merged.items():
                g = loc // self.plan.chunk_size
                new_o = int(self.owner_of_group[g])
                self.pending[new_o][r][loc] = p
            self.pending[dead][r] = {}
        # 5. Prefetched files sitting in the dead node's *remote memory* were
        #    journalled as consumed by their senders but never reached
        #    training. Requesters durably journal remote consumptions too (4
        #    bytes/file, same as the owner journal), so on recovery the
        #    senders un-consume exactly the lost ones; survivors will then
        #    re-fetch them from the chunk store through normal refills.
        rm_dead = self.remote_mem[dead]
        for loc in list(rm_dead._data):
            f, _ = rm_dead.take(loc)
            for r in survivors:
                self.nodes[r].consumed[f] = False
        for o in range(self.num_nodes):
            self.pending[o][dead] = {}
        # 6. Repatriation: a survivor may now *own* a location for which it
        #    holds a prefetched file in its remote memory (the prefetch came
        #    from the dead ex-owner). The owner path never consults remote
        #    memory, so convert such entries back into ordinary residents of
        #    the new owner's local abstract memory (un-consuming them — a
        #    resident is by definition un-consumed).
        c = self.plan.chunk_size
        for r in survivors:
            rm_r = self.remote_mem[r]
            self_locs = [
                loc for loc in rm_r._data
                if int(self.owner_of_group[loc // c]) == r
            ]
            for loc in self_locs:
                f, data = rm_r.take(loc)
                for r2 in survivors:
                    self.nodes[r2].consumed[f] = False
                gq, sq = divmod(loc, c)
                self.nodes[r].memory.fill(gq, sq, f)
                if data is not None:
                    self.nodes[r].buffer[f] = data
                self.pending[r][r].pop(loc, None)
