"""Distributed Redox: ownership, remote access, opportunistic prefetch (paper §3.4).

Memory organisation (Fig. 5): every node shares one view of the global
abstract memory. Each abstract chunk (= chunk group) has a single *owner*
node which also stores the group's chunks on its local disk. Owners run the
unmodified local protocol; non-owners reach a group only through its owner.

Opportunistic prefetch (Fig. 6): on a remote miss the requester piggybacks
its current sequence position and its remaining remote-memory budget. The
owner serves the miss via the local protocol, then walks the requester's
*pre-shared* access sequence over the next ``prefetch_window`` positions and
ships any file that (a) it owns, (b) is already resident in its abstract
memory (opportunistic — never loads from disk for a prefetch), (c) whose
abstract location is provably vacant on the requester ("Prefetch Check
List": no outstanding prefetch to that location), and (d) fits the
requester's remote-memory budget. A shipped file is consumed at the sender
immediately — which empties sender slots early and *raises* later refill
fill-rates (Fig. 7's positive side-effect).

Fault tolerance: :meth:`Cluster.remap_ownership` implements the elastic
ownership remap described in DESIGN.md §5 — on node loss the dead node's
groups are reassigned to survivors, its *memory* contents are lost (those
files were not yet consumed, so the new owner simply re-fetches them from
the replicated chunk store), and its consumption journal (4 bytes/file,
durably logged in any real deployment) is recovered so exactly-once is
preserved.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.obs import tracer as trace

from .chunking import ChunkingPlan
from .protocol import _SCAN_BLOCK, LocalNode, _prev_occurrence
from .sampler import EpochSampler
from .stats import NodeStats, StepIO

__all__ = ["Cluster", "EpochResult", "RemoteMemory"]




class RemoteMemory:
    """Requester-side bounded cache of prefetched files, keyed by location.

    Backed by a dense ``location -> file_id`` array so the batched access
    engine can test/consume whole runs of remote-prefetch hits with gather/
    scatter operations; payload bytes (real-bytes mode only) live in a side
    dict keyed by location.
    """

    def __init__(self, limit_bytes: int, file_sizes: np.ndarray, num_locs: int):
        self.limit_bytes = int(limit_bytes)
        self._sizes = file_sizes
        self._loc_file = np.full(int(num_locs), -1, dtype=np.int64)
        self._payloads: dict[int, bytes] = {}
        self._count = 0
        self.used_bytes = 0
        self.peak_bytes = 0

    def __contains__(self, loc: int) -> bool:
        return self._loc_file[loc] >= 0

    @property
    def free_bytes(self) -> int:
        return self.limit_bytes - self.used_bytes

    def put(self, loc: int, file_id: int, data: bytes | None = None) -> None:
        size = int(self._sizes[file_id])
        assert self._loc_file[loc] < 0, "prefetch landed on an occupied location"
        assert size <= self.free_bytes, "prefetch overran the remote-memory budget"
        self._loc_file[loc] = file_id
        if data is not None:
            self._payloads[loc] = data
        self._count += 1
        self.used_bytes += size
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def take(self, loc: int) -> tuple[int, bytes | None]:
        file_id = int(self._loc_file[loc])
        assert file_id >= 0, "take() on an empty remote location"
        self._loc_file[loc] = -1
        self._count -= 1
        self.used_bytes -= int(self._sizes[file_id])
        return file_id, self._payloads.pop(loc, None)

    # ------------------------------------------------------- batched variants
    def file_at(self, locs: np.ndarray) -> np.ndarray:
        """Vectorised lookup: file id held at each location, or -1."""
        return self._loc_file[locs]

    def put_many(self, locs: np.ndarray, file_ids: np.ndarray) -> None:
        """Vectorised :meth:`put` of distinct empty locations (bulk ship)."""
        sizes = int(self._sizes[file_ids].sum())
        assert (self._loc_file[locs] < 0).all(), (
            "prefetch landed on an occupied location"
        )
        assert sizes <= self.free_bytes, "prefetch overran the remote-memory budget"
        self._loc_file[locs] = file_ids
        self._count += int(locs.size)
        self.used_bytes += sizes
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)

    def store_payload(self, loc: int, data: bytes) -> None:
        """Attach the payload for a location filled via :meth:`put_many`."""
        self._payloads[loc] = data

    def take_many(self, locs: np.ndarray) -> np.ndarray:
        """Vectorised :meth:`take` of distinct occupied locations.

        Payloads are *not* popped — the real-bytes caller drains them with
        :meth:`pop_payload` while flattening the batch.
        """
        files = self._loc_file[locs]
        assert (files >= 0).all(), "take_many() on an empty remote location"
        self._loc_file[locs] = -1
        self._count -= int(locs.size)
        self.used_bytes -= int(self._sizes[files].sum())
        return files

    def pop_payload(self, loc: int) -> bytes | None:
        return self._payloads.pop(loc, None)

    def locations(self) -> np.ndarray:
        """Occupied locations (ascending)."""
        return np.nonzero(self._loc_file >= 0)[0]

    def __len__(self) -> int:
        return self._count


@dataclasses.dataclass
class EpochResult:
    stats: NodeStats                      # cluster-wide aggregate
    node_stats: list[NodeStats]
    per_node_step_io: list[list[StepIO]]  # input to PipelineTimeModel
    returned: list[np.ndarray]            # per node: files actually consumed


class Cluster:
    """In-process distributed Redox cluster (protocol-exact, timing-modelled).

    Flags reproduce the paper's ablations (Table 4/5):

    * ``policy="max_fill", prefetch=True``   -> Brand
    * ``policy="random",   prefetch=True``   -> Brand-random-selection
    * ``policy="max_fill", prefetch=False``  -> Brand-no-prefetching
    * ``policy="random",   prefetch=False``  -> Brand-no-optimization
    """

    def __init__(
        self,
        plan: ChunkingPlan,
        num_nodes: int,
        *,
        remote_memory_limit_bytes: int = 1 << 62,
        prefetch_window: int = 64,
        policy: str = "max_fill",
        prefetch: bool = True,
        seed: int = 0,
        store=None,
    ):
        self.plan = plan
        self.num_nodes = num_nodes
        self.prefetch_window = prefetch_window
        self.prefetch = prefetch
        self.policy = policy
        self.seed = seed
        # Contiguous group ranges per owner: data is partitioned across node
        # disks before training (paper §3.4).
        g = np.arange(plan.num_groups, dtype=np.int64)
        self.owner_of_group = np.minimum(
            g * num_nodes // max(plan.num_groups, 1), num_nodes - 1
        ).astype(np.int32)
        self.nodes = [
            LocalNode(plan, policy=policy, seed=(seed, 7, r), store=store, node_id=r)
            for r in range(num_nodes)
        ]
        self.remote_mem = [
            RemoteMemory(remote_memory_limit_bytes, plan.file_sizes, plan.num_slots)
            for _ in range(num_nodes)
        ]
        self._remote_limit = int(remote_memory_limit_bytes)
        # pending[o][r]: the paper's Prefetch Check List — location ``loc``
        # of r's remote memory has an outstanding prefetch from owner o,
        # sent when r was at sequence position ``pending_sent[o][r][loc]``.
        # Entries are dropped lazily, at the next (o, r) round trip, once the
        # piggybacked position proves the requester consumed the location.
        self.pending: list[list[np.ndarray]] = [
            [np.zeros(plan.num_slots, dtype=bool) for _ in range(num_nodes)]
            for _ in range(num_nodes)
        ]
        self.pending_sent: list[list[np.ndarray]] = [
            [np.zeros(plan.num_slots, dtype=np.int64) for _ in range(num_nodes)]
            for _ in range(num_nodes)
        ]
        self.sequences: list[np.ndarray] | None = None
        self._loc_of_seq: list[np.ndarray] | None = None
        self._owner_of_seq: list[np.ndarray] | None = None
        self._lockeys: list[np.ndarray] | None = None
        self.failed = np.zeros(num_nodes, dtype=bool)
        # Per-node access cursor: how many positions of ``sequences[r]`` have
        # been served. The epoch drivers step nodes by ``batch_per_node`` from
        # these cursors (identical to the old fixed step*batch grid for a
        # static cluster) — which is what lets a node join mid-epoch at
        # cursor 0 and a resumed epoch continue from a snapshot's cursors.
        self.positions = np.zeros(num_nodes, dtype=np.int64)
        # Driver bookkeeping consumed by Cluster.snapshot(): the epoch being
        # executed, the next step index, and the step grid in force.
        self.epoch: "int | None" = None
        self.current_step = 0
        self._grid: "tuple[int | None, str | None]" = (None, None)
        self._recorder = None
        # Engine flag: the batched ("step") engine uses the vectorised
        # check-list helpers; the reference per-access engine keeps the
        # scalar originals. Both implement identical protocol semantics —
        # tests/test_planner.py asserts byte-identical runs.
        self._vectorized = True

    def set_recorder(self, recorder) -> None:
        """Attach (or detach, with None) a planner event recorder."""
        self._recorder = recorder
        for node in self.nodes:
            node.recorder = recorder

    @property
    def store(self):
        """The shared ChunkStore (None in simulation-only mode)."""
        return self.nodes[0].store if self.nodes else None

    @property
    def backend_stats(self):
        """Aggregate storage-backend counters, or None without a store.

        All LocalNodes share one store/backend instance (one disk per node is
        modelled by the time model, not by separate backends), so this is the
        cluster-wide view: prefetch hits, peak in-flight reads, and the
        blocking-read throughput that ``benchmarks/io_overhead.py --backend``
        reports per backend.
        """
        store = self.store
        return store.backend_stats if store is not None else None

    def planning_clone(self) -> "Cluster":
        """A fresh, store-less cluster with identical protocol configuration.

        The clairvoyant planner simulates an epoch on the clone (id-space
        only, no bytes touched) to compute the live cluster's exact schedule;
        per-epoch RNG derivation (see :meth:`LocalNode.begin_epoch`) makes
        the clone's epoch-``e`` run identical to the live one regardless of
        which epochs either side executed before.
        """
        return Cluster(
            self.plan,
            self.num_nodes,
            remote_memory_limit_bytes=self._remote_limit,
            prefetch_window=self.prefetch_window,
            policy=self.policy,
            prefetch=self.prefetch,
            seed=self.seed,
        )

    # ------------------------------------------------------------ lifecycle
    def begin_epoch(self, sampler: EpochSampler, epoch: int) -> list[np.ndarray]:
        for node in self.nodes:
            node.begin_epoch(epoch)
        for rm in self.remote_mem:
            assert len(rm) == 0, "remote abstract memory not drained"
            rm.peak_bytes = rm.used_bytes  # per-epoch peak, like NodeStats
        for row in self.pending:
            for mask in row:
                mask[:] = False
        self.positions[:] = 0
        self.epoch = epoch
        self.current_step = 0
        self.sequences = sampler.node_sequences(epoch)
        self._index_sequences()
        return self.sequences

    def _index_sequences(self) -> None:
        """Precompute per-node sequence indexes (rebuilt after fail_node).

        * ``_loc_of_seq[r]`` — abstract location of every position (owners
          look ahead into these to run the opportunistic-prefetch check list
          without extra communication; sequences are pre-shared, §3.4);
        * ``_owner_of_seq[r]`` — owning node of every position;
        * ``_lockeys[r]`` — ``(location << 32) | position`` sorted: lets the
          vectorised check-list cleanup resolve "r's next access of ``loc``
          after position p" for every pending entry in one searchsorted.
        """
        self._loc_of_seq = [self.plan.locations_of_files(s) for s in self.sequences]
        c = self.plan.chunk_size
        self._owner_of_seq = [
            self.owner_of_group[locs // c].astype(np.int64)
            for locs in self._loc_of_seq
        ]
        self._lockeys = [
            np.sort((locs << 32) | np.arange(locs.size, dtype=np.int64))
            for locs in self._loc_of_seq
        ]

    # -------------------------------------------------------------- access
    def access(
        self, r: int, pos: int, file_id: int, io_by_node: dict[int, StepIO]
    ) -> tuple[int, bytes | None]:
        """Node ``r`` performs the access at position ``pos`` of its sequence.

        Returns ``(returned_file_id, payload)`` — the payload is None in
        simulation mode (no ChunkStore attached).
        """
        plan = self.plan
        g = plan.group_of_file(file_id)
        o = int(self.owner_of_group[g])
        stats_r = self.nodes[r].stats
        self.positions[r] = pos + 1

        if o == r:
            res = self.nodes[r].request(file_id)
            io_by_node.setdefault(r, StepIO()).add(res.io)
            return res.file_id, res.data

        loc = plan.location_of_file(file_id)
        rm = self.remote_mem[r]
        if loc in rm:
            # Served by previously prefetched data — no network round trip.
            stats_r.accesses += 1
            stats_r.remote_prefetch_hits += 1
            return rm.take(loc)

        # Remote miss: request the owner (paper Fig. 6).
        stats_r.remote_requests += 1
        self._cleanup_pending(o, r, pos)
        res = self.nodes[o].request(file_id)
        # Owner's batched disk read happens on the owner; the response bytes
        # travel to the requester (see stats.py for the time model).
        io_by_node.setdefault(o, StepIO()).add(res.io)
        io_r = io_by_node.setdefault(r, StepIO())
        io_r.net_messages += 1
        io_r.net_bytes += int(plan.file_sizes[res.file_id])
        if self.prefetch:
            self._opportunistic_prefetch(o, r, pos, io_r)
        return res.file_id, res.data

    def _cleanup_pending(self, o: int, r: int, pos: int) -> None:
        """Drop pending entries the requester has provably consumed (< pos).

        An entry (loc, sent) is retired when r's next access of ``loc``
        strictly after ``sent`` happened before ``pos`` — that access was
        the remote-memory hit that consumed the prefetch. Two equivalent
        implementations: the reference walks entries in Python (the
        original per-access protocol); the batched engine resolves every
        entry with one vectorised searchsorted over ``_lockeys``.

        Elastic events keep entries valid without special cases here:
        ``fail_node``/``join_node`` only ever migrate entries between
        *owners* (requester positions — and therefore ``sent`` — are
        untouched), and an entry whose consuming access is donated to a
        joining node is released outright (see _release_moved_prefetches).
        """
        mask = self.pending[o][r]
        entries = np.nonzero(mask)[0]
        if entries.size == 0:
            return
        keys = self._lockeys[r]
        sent = self.pending_sent[o][r][entries]
        if self._vectorized:
            idx = np.searchsorted(keys, (entries << 32) | sent, side="right")
            valid = idx < keys.size
            nxt = keys[np.minimum(idx, keys.size - 1)]
            drop = valid & (nxt >> 32 == entries) & ((nxt & 0xFFFFFFFF) < pos)
            mask[entries[drop]] = False
            return
        for loc, sent_pos in zip(entries.tolist(), sent.tolist()):
            i = int(np.searchsorted(keys, (loc << 32) | sent_pos, side="right"))
            if i < keys.size:
                nxt = int(keys[i])
                if (nxt >> 32) == loc and (nxt & 0xFFFFFFFF) < pos:
                    mask[loc] = False

    def _opportunistic_prefetch(self, o: int, r: int, pos: int, io_r: StepIO) -> None:
        if self._vectorized:
            return self._opportunistic_prefetch_vec(o, r, pos, io_r)
        return self._opportunistic_prefetch_scalar(o, r, pos, io_r)

    def _opportunistic_prefetch_scalar(
        self, o: int, r: int, pos: int, io_r: StepIO
    ) -> None:
        """Reference implementation: the paper's Fig. 6 walk, one position
        at a time (the per-access engine's event path)."""
        plan = self.plan
        seq = self.sequences[r]
        locs = self._loc_of_seq[r]
        pend = self.pending[o][r]
        rm = self.remote_mem[r]
        owner_mem = self.nodes[o].memory
        end = min(pos + 1 + self.prefetch_window, seq.size)
        for q in range(pos + 1, end):
            gq = plan.group_of_file(int(seq[q]))
            if int(self.owner_of_group[gq]) != o:
                continue
            loc_q = int(locs[q])
            if pend[loc_q]:
                continue  # requester slot occupied by an outstanding prefetch
            sq = loc_q - gq * plan.chunk_size
            file_p = owner_mem.get(gq, sq)
            if file_p < 0:
                continue  # opportunistic: never read disk for a prefetch
            size = int(plan.file_sizes[file_p])
            if size > rm.free_bytes:
                continue  # respect the piggybacked remote-memory budget
            _, data = self.nodes[o].take_for_prefetch(gq, sq)
            rm.put(loc_q, file_p, data)
            pend[loc_q] = True
            self.pending_sent[o][r][loc_q] = pos
            self.nodes[r].stats.prefetch_received += 1
            io_r.net_bytes += size
            self.nodes[r].stats.peak_remote_bytes = max(
                self.nodes[r].stats.peak_remote_bytes, rm.peak_bytes
            )
            if self._recorder is not None:
                self._recorder.on_ship(o, r, file_p, loc_q)

    def _opportunistic_prefetch_vec(
        self, o: int, r: int, pos: int, io_r: StepIO
    ) -> None:
        plan = self.plan
        seq = self.sequences[r]
        locs = self._loc_of_seq[r]
        pend = self.pending[o][r]
        rm = self.remote_mem[r]
        owner_mem = self.nodes[o].memory
        c = plan.chunk_size
        end = min(pos + 1 + self.prefetch_window, seq.size)
        if end <= pos + 1:
            return
        # Candidate filter, vectorised over the whole lookahead window:
        # o-owned, first-occurrence positions whose location has no
        # outstanding prefetch and whose file is resident in the owner's
        # abstract memory (opportunistic — never reads disk for a prefetch).
        # The snapshot stays exact through the walk: ships only *remove*
        # residents, at locations the dedup already excludes from re-use.
        w_locs = locs[pos + 1 : end]
        cand = np.nonzero(
            (self._owner_of_seq[r][pos + 1 : end] == o) & ~pend[w_locs]
        )[0]
        if cand.size == 0:
            return
        # Duplicate locations in the window: only the first may ship (the
        # ship occupies the location; live re-check was via pend).
        cand_locs = w_locs[cand]
        first = np.zeros(cand.size, dtype=bool)
        first[np.unique(cand_locs, return_index=True)[1]] = True
        ship_locs = cand_locs[first]
        gq = ship_locs // c
        sq = ship_locs - gq * c
        files = owner_mem.resident[gq, sq]
        ok = files >= 0
        gq, sq, files, ship_locs = gq[ok], sq[ok], files[ok], ship_locs[ok]
        if files.size == 0:
            return
        sizes = plan.file_sizes[files]
        # Budget walk (greedy, in window order): the all-fits prefix ships
        # in bulk; the remainder falls back to the exact per-file walk
        # (a later smaller file may still fit after a larger one did not).
        fits = np.cumsum(sizes) <= rm.free_bytes
        k = int(fits.sum()) if fits.all() else int(np.argmin(fits))
        if k:
            owner = self.nodes[o]
            shipped = owner.memory.take_many(gq[:k], sq[:k])
            assert not owner.consumed[shipped].any()
            owner.consumed[shipped] = True
            owner.stats.prefetch_sent += k
            rm.put_many(ship_locs[:k], shipped)
            pend[ship_locs[:k]] = True
            self.pending_sent[o][r][ship_locs[:k]] = pos
            if owner.store is not None:
                for f, lc in zip(shipped.tolist(), ship_locs[:k].tolist()):
                    rm.store_payload(lc, owner.buffer.pop(f))
            self.nodes[r].stats.prefetch_received += k
            io_r.net_bytes += int(sizes[:k].sum())
            self.nodes[r].stats.peak_remote_bytes = max(
                self.nodes[r].stats.peak_remote_bytes, rm.peak_bytes
            )
            if self._recorder is not None:
                for f, lc in zip(shipped.tolist(), ship_locs[:k].tolist()):
                    self._recorder.on_ship(o, r, f, lc)
        for gq1, sq1, loc_q, file_p, size in zip(
            gq[k:].tolist(), sq[k:].tolist(), ship_locs[k:].tolist(),
            files[k:].tolist(), sizes[k:].tolist(),
        ):
            if size > rm.free_bytes:
                continue  # respect the piggybacked remote-memory budget
            _, data = self.nodes[o].take_for_prefetch(gq1, sq1)
            rm.put(loc_q, file_p, data)
            pend[loc_q] = True
            self.pending_sent[o][r][loc_q] = pos
            self.nodes[r].stats.prefetch_received += 1
            io_r.net_bytes += size
            self.nodes[r].stats.peak_remote_bytes = max(
                self.nodes[r].stats.peak_remote_bytes, rm.peak_bytes
            )
            if self._recorder is not None:
                self._recorder.on_ship(o, r, file_p, loc_q)

    def access_step(
        self,
        r: int,
        lo: int,
        hi: int,
        io_by_node: dict[int, StepIO],
        *,
        payloads: "list | None" = None,
    ) -> np.ndarray:
        """Node ``r`` performs its sequence positions ``[lo, hi)``, batched.

        Byte-identical to calling :meth:`access` per position: runs of
        consecutive hits — local abstract-memory hits and remote-prefetch
        hits — are consumed with NumPy gather/scatter; only protocol
        *events* (misses, remote round trips, opportunistic ships) drop to
        the per-access path, which preserves the exact RNG draw order.
        """
        n = hi - lo
        out = np.empty(n, dtype=np.int64)
        if n <= 0:
            return out
        fids = np.asarray(self.sequences[r][lo:hi], dtype=np.int64)
        locs = self._loc_of_seq[r][lo:hi]
        owners = self._owner_of_seq[r][lo:hi]
        node = self.nodes[r]
        if (owners == r).all():
            # Whole slice is owner-local (always true for 1-node clusters).
            self.positions[r] = hi
            io = io_by_node.setdefault(r, StepIO())
            return node.request_step(fids, io, payloads=payloads, locs=locs)
        rm = self.remote_mem[r]
        prev = _prev_occurrence(locs)
        resident = node.memory.resident_flat
        i = 0
        while i < n:
            # Scan one block at a time: during the miss-heavy epoch prefix
            # this bounds the per-event vector work; during the hit-heavy
            # remainder runs extend block by block.
            j = min(i + _SCAN_BLOCK, n)
            sub_loc = locs[i:j]
            local = owners[i:j] == r
            res_f = resident[sub_loc]
            rm_f = rm.file_at(sub_loc)
            # Safe bulk hits: a valid local resident (owner-local access) or
            # an already-prefetched remote location — and no earlier position
            # in the run targeting the same location (hits self-invalidate).
            safe = np.where(local, res_f >= 0, rm_f >= 0) & (prev[i:j] < i)
            k = int(safe.argmin())
            run = j - i if safe[k] else k
            if run:
                lm = local[:run]
                ret = np.where(lm, res_f[:run], rm_f[:run])
                n_local = int(lm.sum())
                if n_local:
                    node.memory.take_many_flat(sub_loc[:run][lm])
                    node.consumed[res_f[:run][lm]] = True
                    node.stats.local_hits += n_local
                    node.stats.peak_local_bytes = max(
                        node.stats.peak_local_bytes, node.memory.peak_bytes
                    )
                    io_by_node.setdefault(r, StepIO())
                if run - n_local:
                    rm.take_many(sub_loc[:run][~lm])
                    node.stats.remote_prefetch_hits += run - n_local
                node.stats.accesses += run
                out[i : i + run] = ret
                if node.store is not None:
                    for f, is_local, lc in zip(
                        ret.tolist(), lm.tolist(), sub_loc[:run].tolist()
                    ):
                        data = node.buffer.pop(f) if is_local else rm.pop_payload(lc)
                        if payloads is not None:
                            payloads.append(data)
                i += run
                if run == j - (i - run):  # block exhausted by hits: next block
                    continue
            if i >= n:
                break
            # The breaker is a genuine miss: either its location is invalid
            # (not resident / not prefetched) or its in-run predecessor was
            # just consumed, which empties the location either way.
            f, data = self.access(r, lo + i, int(fids[i]), io_by_node)
            out[i] = f
            if payloads is not None:
                payloads.append(data)
            i += 1
        self.positions[r] = hi
        return out

    # -------------------------------------------------------------- drivers
    def _step_bounds(self, r: int, batch_per_node: int) -> tuple[int, int]:
        """Node ``r``'s next step slice: ``batch_per_node`` accesses from its
        cursor. For a static cluster this is exactly the old fixed
        ``[step*b, (step+1)*b)`` grid; cursors are what let a freshly joined
        node start at 0 mid-epoch and a restored cluster resume mid-grid."""
        lo = int(self.positions[r])
        return lo, min(lo + batch_per_node, int(self.sequences[r].size))

    def _live_exhausted(self) -> bool:
        return all(
            self.positions[r] >= self.sequences[r].size
            for r in range(self.num_nodes)
            if not self.failed[r]
        )

    def epoch_stream(
        self,
        sampler: "EpochSampler | None",
        epoch: int,
        batch_per_node: int,
        *,
        stepping: str = "ceil",
        engine: str = "step",
        collect_payloads: bool = False,
        recorder=None,
        failures: "dict[int, int] | None" = None,
        joins: "dict[int, int] | None" = None,
        start_step: int = 0,
        resume: bool = False,
    ):
        """THE epoch driver: every live epoch walk goes through here.

        Yields ``(step, returned_per_node, payloads, io_by_node)`` per
        training step. ``stepping`` controls the step grid:

        * ``"ceil"`` — ``max_r ceil(len_r / b)`` steps, ragged last step
          included in the grid (the :meth:`run_epoch` accounting used by the
          time model);
        * ``"floor_tail"`` — ``min_r len_r // b`` full-size steps are
          yielded; the ragged remainder is drained afterwards *without*
          yielding (the loader contract: fixed-shape batches only).

        ``engine`` selects the batched id-space walk (``"step"``) or the
        reference per-access walk (``"per_access"``) — kept for planner
        equivalence tests and as the benchmark baseline.

        Elastic events, both applied at step barriers and in this order:
        ``failures`` maps a step index to a node id to kill
        (:meth:`fail_node`); ``joins`` maps a step index to a count of fresh
        nodes to admit (:meth:`join_node`). Step keys are absolute, so the
        same schedules drive a resumed suffix unchanged.

        Resume (DESIGN.md §10): with ``resume=True`` the cluster's mid-epoch
        state — installed by :meth:`Cluster.restore` — is used as-is (no
        ``begin_epoch``; ``sampler`` may be None) and the walk continues
        from ``start_step``. The recorder, when given, sees steps relative
        to the stream's own start (a resumed recorder builds a *suffix*
        plan); the yielded step indices stay absolute.
        """
        assert stepping in ("ceil", "floor_tail")
        assert engine in ("step", "per_access")
        if resume:
            assert self.sequences is not None, "resume without restored state"
        else:
            assert start_step == 0
            self.begin_epoch(sampler, epoch)
        self._grid = (batch_per_node, stepping)
        self.current_step = start_step
        self._vectorized = engine == "step"
        if recorder is not None:
            self.set_recorder(recorder)
        try:
            if stepping == "floor_tail":
                assert not failures and not joins, (
                    "elastic-event schedules require ceil stepping"
                )
                num_steps = min(s.size for s in self.sequences) // batch_per_node
            step = start_step
            while True:
                if stepping == "ceil":
                    if failures and step in failures:
                        dead = failures[step]
                        self.fail_node(dead, int(self.positions[dead]))
                    if joins and step in joins:
                        for _ in range(joins[step]):
                            self.join_node()
                    if self._live_exhausted():
                        break
                elif step >= num_steps:
                    break
                tracer = trace.get()
                t0 = time.perf_counter() if tracer is not None else 0.0
                io_by_node: dict[int, StepIO] = {}
                if recorder is not None:
                    recorder.begin_step(step - start_step)
                returned: list[np.ndarray] = []
                payloads: "list | None" = [] if collect_payloads else None
                for r in range(self.num_nodes):
                    if self.failed[r]:
                        returned.append(np.empty(0, dtype=np.int64))
                        continue
                    lo, hi = self._step_bounds(r, batch_per_node)
                    if engine == "step":
                        ret = self.access_step(r, lo, hi, io_by_node, payloads=payloads)
                    else:
                        ret = np.empty(hi - lo, dtype=np.int64)
                        for pos in range(lo, hi):
                            f, data = self.access(
                                r, pos, int(self.sequences[r][pos]), io_by_node
                            )
                            ret[pos - lo] = f
                            if payloads is not None:
                                payloads.append(data)
                    returned.append(ret)
                if recorder is not None:
                    recorder.end_step(step - start_step, returned, io_by_node)
                self.current_step = step + 1
                if tracer is not None:
                    # Spans cover production only — consumer time between
                    # yields must not pollute the proto stage.
                    tracer.complete(
                        "proto.step", "proto", t0,
                        time.perf_counter() - t0, {"step": step},
                    )
                yield step, returned, payloads, io_by_node
                step += 1
            if stepping == "floor_tail":
                # Drain the ragged tail so exactly-once epoch invariants hold.
                io_by_node = {}
                if recorder is not None:
                    recorder.begin_step(num_steps - start_step)
                tail: list[np.ndarray] = []
                for r in range(self.num_nodes):
                    lo = int(self.positions[r])
                    # payloads popped but not collected: tail records are
                    # consumed for the invariants, never trained on
                    tail.append(
                        self.access_step(r, lo, self.sequences[r].size, io_by_node)
                    )
                if recorder is not None:
                    recorder.end_step(num_steps - start_step, tail, io_by_node)
            self._check_epoch_complete()
        finally:
            self._vectorized = True
            if recorder is not None:
                self.set_recorder(None)

    def run_epoch(
        self,
        sampler: EpochSampler,
        epoch: int,
        batch_per_node: int,
        *,
        collect_returned: bool = True,
        engine: str = "step",
        plan=None,
        recorder=None,
        failures: "dict[int, int] | None" = None,
        joins: "dict[int, int] | None" = None,
    ) -> EpochResult:
        """Execute a full epoch with per-step node interleaving (DP barrier).

        With ``plan`` (an :class:`repro.core.planner.EpochPlan`) the epoch is
        *replayed* from the pre-computed schedule instead of executed live —
        no protocol decisions, no RNG, just the recorded event stream.
        """
        empty = np.empty(0, dtype=np.int64)
        per_node_step_io: list[list[StepIO]] = [[] for _ in range(self.num_nodes)]
        returned: list[list[np.ndarray]] = [[] for _ in range(self.num_nodes)]
        steps_seen = 0
        if plan is not None:
            stream = self.replay_stream(
                plan, epoch=epoch, batch_per_node=batch_per_node, stepping="ceil"
            )
        else:
            stream = self.epoch_stream(
                sampler, epoch, batch_per_node,
                engine=engine, recorder=recorder, failures=failures, joins=joins,
            )
        for _, step_returned, _, io_by_node in stream:
            while len(per_node_step_io) < self.num_nodes:
                # A node joined mid-epoch: backfill its pre-join steps so the
                # StepIO/returned grids stay rectangular (and identical to a
                # replayed plan's padded grid).
                per_node_step_io.append([StepIO() for _ in range(steps_seen)])
                returned.append([empty] * steps_seen)
            for r in range(self.num_nodes):
                per_node_step_io[r].append(io_by_node.get(r, StepIO()))
                if collect_returned:
                    returned[r].append(
                        step_returned[r] if r < len(step_returned) else empty
                    )
            steps_seen += 1
        node_stats = [n.stats for n in self.nodes]
        agg = node_stats[0]
        for s in node_stats[1:]:
            agg = agg.merge(s)
        return EpochResult(
            stats=agg,
            node_stats=node_stats,
            per_node_step_io=per_node_step_io,
            returned=[
                np.concatenate(rt) if rt else empty for rt in returned
            ],
        )

    def replay_stream(
        self,
        plan,
        *,
        epoch: int | None = None,
        batch_per_node: int | None = None,
        stepping: str | None = None,
        collect_payloads=None,
    ):
        """Replay a pre-computed :class:`EpochPlan`: the execute half of the
        plan/execute split.

        Yields the same ``(step, returned_per_node, payloads, io_by_node)``
        stream as :meth:`epoch_stream` without running any protocol logic.
        In real-bytes mode (a ChunkStore attached) the plan's exact chunk
        schedule is handed to the storage backend up front
        (:meth:`ChunkStore.schedule_reads`), so readahead is clairvoyant
        rather than heuristic; reads/ships/returns then follow the recorded
        event order. Node stats are installed from the plan (they are exact
        protocol counters) with measured read-wait folded in.
        """
        store = self.store
        if collect_payloads is None:
            collect_payloads = store is not None
        if plan.joined_nodes and self.num_nodes == plan.num_nodes - plan.joined_nodes:
            # The plan admitted nodes mid-epoch; replay needs matching shells
            # (no protocol state — the recorded events carry everything).
            while self.num_nodes < plan.num_nodes:
                self._append_node()
        plan.validate(self, epoch, batch_per_node, stepping)
        for r, st in enumerate(plan.node_stats):
            self.nodes[r].stats = st.copy()
        if store is not None:
            store.schedule_reads(plan.load_chunk.tolist())
        # One global payload pool: exactly-once guarantees each file is
        # loaded at most once and consumed exactly once per epoch, so
        # ownership transfers (ships, remote on-demand responses) never need
        # modelling here — the byte movement they represent is priced by the
        # plan's StepIO net counters, not re-enacted.
        pool: dict[int, bytes] = {}
        if plan.start_step and store is not None:
            # Resumed suffix: files already resident/prefetched at the
            # snapshot have no load event in the suffix plan — their bytes
            # were rehydrated into the restored cluster by Cluster.restore.
            for node in self.nodes:
                pool.update(node.buffer)
            for rm in self.remote_mem:
                for loc, data in rm._payloads.items():
                    pool[int(rm._loc_file[loc])] = data
        for step in range(plan.num_steps + (1 if plan.has_tail else 0)):
            tracer = trace.get()
            t0 = time.perf_counter() if tracer is not None else 0.0
            io_by_node = plan.step_io(step)
            if store is not None:
                for li in range(*plan.load_range(step)):
                    owner = int(plan.load_owner[li])
                    t0 = time.perf_counter()
                    records = dict(store.read_chunk(int(plan.load_chunk[li])))
                    wait = time.perf_counter() - t0
                    st = self.nodes[owner].stats
                    st.read_wait_s += wait
                    if owner in io_by_node:
                        io_by_node[owner].read_wait_s += wait
                    st.peak_inflight_reads = max(
                        st.peak_inflight_reads, store.backend_stats.peak_inflight
                    )
                    for f in plan.load_files(li).tolist():
                        pool[f] = records[f]
            returned = plan.step_returned(step)
            if step >= plan.num_steps:
                if store is not None:  # tail payloads are read but never yielded
                    for ret in returned:
                        for f in ret.tolist():
                            pool.pop(f, None)
                break
            payloads = None
            if collect_payloads:
                payloads = [
                    pool.pop(int(f)) for ret in returned for f in ret.tolist()
                ]
            if tracer is not None:
                tracer.complete(
                    "replay.step", "proto", t0, time.perf_counter() - t0,
                    {"step": plan.start_step + step},
                )
            # Suffix plans (EpochPlanner.plan_from) are step-indexed from
            # their resume point; yield absolute step numbers either way.
            yield plan.start_step + step, returned, payloads, io_by_node
        assert not pool, "replay left undelivered payloads behind"

    def _check_epoch_complete(self) -> None:
        """Every file consumed at its (current) owner; all memories drained.

        Exactly-once of the *returned stream* is asserted separately by the
        property tests (counting each file in ``EpochResult.returned``) —
        here we check the owner-side bookkeeping, which must hold even after
        an elastic ownership remap.
        """
        for r in range(self.num_nodes):
            if self.failed[r]:
                continue
            assert self.nodes[r].memory.is_empty(), "local abstract memory not drained"
            assert len(self.remote_mem[r]) == 0, "remote abstract memory not drained"
        owner_of_file = self.owner_of_group[
            self.plan.group_of_chunk[self.plan.chunk_of]
        ]
        for r in range(self.num_nodes):
            if self.failed[r]:
                continue
            mask = owner_of_file == r
            assert self.nodes[r].consumed[mask].all(), (
                "a file was never consumed (exactly-once violated)"
            )

    # ------------------------------------------------------- fault tolerance
    def fail_node(self, dead: int, processed_upto: int) -> None:
        """Node ``dead`` fails after completing ``processed_upto`` accesses.

        Its unprocessed sequence tail is redistributed round-robin and
        *appended* to the survivors' sequences — appending keeps every
        existing position stable, so outstanding prefetch bookkeeping
        (keyed by position) remains exact; only the location index is
        rebuilt. Ownership is then remapped (see :meth:`remap_ownership`).
        """
        assert self.sequences is not None, "fail_node outside an epoch"
        tail = self.sequences[dead][processed_upto:]
        self.sequences[dead] = self.sequences[dead][:processed_upto]
        self.positions[dead] = processed_upto
        self.remap_ownership(dead)
        survivors = [r for r in range(self.num_nodes) if not self.failed[r]]
        shares = [tail[i :: len(survivors)] for i in range(len(survivors))]
        for r, share in zip(survivors, shares):
            self.sequences[r] = np.concatenate([self.sequences[r], share])
        # Rebuild the per-node sequence indexes (positions in the unchanged
        # prefixes are preserved, so pending[o][r] entries stay valid).
        self._index_sequences()

    def remap_ownership(self, dead: int) -> None:
        """Elastic remap after node ``dead`` fails mid-epoch (DESIGN.md §5).

        Durable state (disk chunks — replicated/NAS-resident in the paper's
        setups — and the consumption journal) survives; volatile state (the
        node's abstract-memory residents and its un-consumed prefetches held
        *for* it) is re-fetchable from disk precisely because never-evicted
        residents are by definition un-consumed.
        """
        assert not self.failed[dead]
        self.failed[dead] = True
        survivors = [r for r in range(self.num_nodes) if not self.failed[r]]
        assert survivors, "no survivors"
        # 1. Reassign the dead node's groups round-robin to survivors.
        dead_groups = np.nonzero(self.owner_of_group == dead)[0]
        for i, grp in enumerate(dead_groups):
            self.owner_of_group[grp] = survivors[i % len(survivors)]
        # 2. Its residents are lost with its memory: un-consume nothing (they
        #    were never consumed) and clear the slots so the new owner's
        #    refills can re-fetch the files from the replicated store.
        mem = self.nodes[dead].memory
        live = np.nonzero(mem.resident.reshape(-1) >= 0)[0]
        for flat in live:
            g, s = divmod(int(flat), self.plan.chunk_size)
            mem.take(g, s)
        # 3. Migrate the consumption journal to the new owners. Our in-process
        #    LocalNodes each hold a full-size consumed bitmap, so survivors
        #    merge the dead node's journal directly.
        journal = self.nodes[dead].consumed
        for r in survivors:
            self.nodes[r].consumed |= journal
        # 4. Outstanding prefetches *from* the dead node already live in the
        #    requesters' remote memories (real data — still valid). Their
        #    check-list entries migrate to the groups' new owners so nobody
        #    double-ships to a still-occupied location.
        for r in range(self.num_nodes):
            for loc in np.nonzero(self.pending[dead][r])[0].tolist():
                new_o = int(self.owner_of_group[loc // self.plan.chunk_size])
                self.pending[new_o][r][loc] = True
                self.pending_sent[new_o][r][loc] = self.pending_sent[dead][r][loc]
            self.pending[dead][r][:] = False
        # 5. Prefetched files sitting in the dead node's *remote memory* were
        #    journalled as consumed by their senders but never reached
        #    training. Requesters durably journal remote consumptions too (4
        #    bytes/file, same as the owner journal), so on recovery the
        #    senders un-consume exactly the lost ones; survivors will then
        #    re-fetch them from the chunk store through normal refills.
        rm_dead = self.remote_mem[dead]
        for loc in rm_dead.locations().tolist():
            f, _ = rm_dead.take(loc)
            for r in survivors:
                self.nodes[r].consumed[f] = False
        for o in range(self.num_nodes):
            self.pending[o][dead][:] = False
        # 6. Repatriation: a survivor may now *own* a location for which it
        #    holds a prefetched file in its remote memory (the prefetch came
        #    from the dead ex-owner). The owner path never consults remote
        #    memory, so convert such entries back into ordinary residents of
        #    the new owner's local abstract memory (un-consuming them — a
        #    resident is by definition un-consumed).
        c = self.plan.chunk_size
        for r in survivors:
            rm_r = self.remote_mem[r]
            self_locs = [
                loc for loc in rm_r.locations().tolist()
                if int(self.owner_of_group[loc // c]) == r
            ]
            for loc in self_locs:
                f, data = rm_r.take(loc)
                for r2 in survivors:
                    self.nodes[r2].consumed[f] = False
                gq, sq = divmod(loc, c)
                self.nodes[r].memory.fill(gq, sq, f)
                if data is not None:
                    self.nodes[r].buffer[f] = data
                self.pending[r][r][loc] = False

    # --------------------------------------------------------- elastic join
    def _append_node(self) -> int:
        """Structural growth: append a fresh node shell (LocalNode, remote
        memory, check-list row + column, cursor). Shared by
        :meth:`join_node` (which rebalances state onto the shell) and by
        replay of plans containing joins (replay never runs the protocol,
        so the shell needs no protocol state)."""
        new = self.num_nodes
        self.num_nodes = new + 1
        node = LocalNode(
            self.plan,
            policy=self.policy,
            seed=(self.seed, 7, new),
            store=self.store,
            node_id=new,
        )
        node.recorder = self._recorder
        self.nodes.append(node)
        self.remote_mem.append(
            RemoteMemory(self._remote_limit, self.plan.file_sizes, self.plan.num_slots)
        )
        m = self.plan.num_slots
        for row, srow in zip(self.pending, self.pending_sent):
            row.append(np.zeros(m, dtype=bool))
            srow.append(np.zeros(m, dtype=np.int64))
        self.pending.append(
            [np.zeros(m, dtype=bool) for _ in range(self.num_nodes)]
        )
        self.pending_sent.append(
            [np.zeros(m, dtype=np.int64) for _ in range(self.num_nodes)]
        )
        self.failed = np.append(self.failed, False)
        self.positions = np.append(self.positions, 0)
        return new

    def join_node(self) -> int:
        """Admit a fresh node mid-epoch: the elastic dual of :meth:`fail_node`.

        The same position-stability trick applies, mirrored: every existing
        node keeps a *prefix* of its sequence (all served positions and the
        outstanding-prefetch bookkeeping keyed by them stay valid) and only
        donates a suffix of unconsumed tail accesses, which become the new
        node's sequence. Ownership rebalances by moving whole chunk groups
        — their abstract-memory residents (and payload bytes) and their
        check-list entries migrate with the group (owner-side moves only:
        requester positions and ``pending_sent`` stay untouched), so
        exactly-once is preserved without touching disk. A donated access
        whose prefetched file sits in the donor's remote memory is handled
        like a failed node's remote memory (DESIGN.md §5/§10): the sender
        un-consumes it and the file re-enters through a normal refill.

        Deterministic given (cluster state, epoch): the planner's shadow
        walk of a ``joins`` schedule reproduces the live decisions exactly.
        """
        assert self.sequences is not None, "join_node outside an epoch"
        prev_live = [r for r in range(self.num_nodes) if not self.failed[r]]
        new = self._append_node()
        node = self.nodes[new]
        if self.epoch is not None:
            # Same per-epoch RNG derivation as LocalNode.begin_epoch: the
            # joined node's refill stream is a pure function of
            # (seed, node_id, epoch), independent of join time.
            seed = node.seed if isinstance(node.seed, tuple) else (node.seed,)
            node.rng = np.random.default_rng((*seed, self.epoch))
        # 1. Journal replication: the union of the live nodes' journals is
        #    exactly the set of files truly consumed so far (see
        #    remap_ownership step 3 — merges keep every live copy accurate).
        for r in prev_live:
            node.consumed |= self.nodes[r].consumed
        live = prev_live + [new]
        # 2. Ownership rebalance: move whole groups from the largest owners
        #    until the new node holds a fair share.
        counts = {r: int((self.owner_of_group == r).sum()) for r in prev_live}
        target = self.plan.num_groups // len(live)
        moved = 0
        while moved < target:
            donor = max(prev_live, key=lambda r: (counts[r], -r))
            if counts[donor] <= 1:
                break  # never strip an owner bare
            g = int(np.nonzero(self.owner_of_group == donor)[0][-1])
            self._move_group(g, donor, new)
            counts[donor] -= 1
            moved += 1
        # 3. Sequence rebalance: each live node donates the last
        #    ``remaining // len(live)`` of its unconsumed tail.
        tails: list[np.ndarray] = []
        for r in prev_live:
            size = int(self.sequences[r].size)
            pos = int(self.positions[r])
            move = (size - pos) // len(live)
            if move <= 0:
                continue
            cut = size - move
            self._release_moved_prefetches(r, pos, cut)
            tails.append(self.sequences[r][cut:])
            self.sequences[r] = self.sequences[r][:cut]
        self.sequences.append(
            np.concatenate(tails) if tails else np.empty(0, dtype=np.int64)
        )
        self._index_sequences()
        return new

    def _move_group(self, g: int, old: int, new: int) -> None:
        """Move chunk-group ``g`` (ownership, residents + payloads, and the
        outstanding check-list entries for its locations) between nodes."""
        c = self.plan.chunk_size
        self.owner_of_group[g] = new
        old_node, new_node = self.nodes[old], self.nodes[new]
        slots = np.nonzero(old_node.memory.resident[g] >= 0)[0]
        if slots.size:
            files = old_node.memory.resident[g][slots].copy()
            old_node.memory.take_many(np.full(slots.size, g, dtype=np.int64), slots)
            new_node.memory.fill_many(g, slots, files)
            if old_node.store is not None:
                for f in files.tolist():
                    new_node.buffer[f] = old_node.buffer.pop(f)
        lo, hi = g * c, (g + 1) * c
        for r in range(self.num_nodes):
            mask = self.pending[old][r][lo:hi]
            if mask.any():
                idx = np.nonzero(mask)[0] + lo
                self.pending[new][r][idx] = True
                self.pending_sent[new][r][idx] = self.pending_sent[old][r][idx]
                self.pending[old][r][lo:hi] = False

    def _release_moved_prefetches(self, r: int, pos: int, cut: int) -> None:
        """Node ``r`` donates sequence positions ``[cut, end)``. Any file in
        its remote memory whose consuming access (the next access of its
        location) falls in the donated suffix is released: the sender
        un-consumes it everywhere (requesters journal remote consumptions
        durably, exactly like the fail_node recovery path) and its
        check-list entry is dropped, so the file re-enters via a refill and
        is eventually consumed at the donated access's new home."""
        rm_r = self.remote_mem[r]
        held = rm_r.locations()
        if held.size == 0:
            return
        kept_window = self._loc_of_seq[r][pos:cut]
        live = [x for x in range(self.num_nodes) if not self.failed[x]]
        for loc in held.tolist():
            if (kept_window == loc).any():
                continue  # still consumed by one of r's kept positions
            f, _ = rm_r.take(loc)
            for o in range(self.num_nodes):
                self.pending[o][r][loc] = False
            for r2 in live:
                self.nodes[r2].consumed[f] = False

    # ----------------------------------------------------- snapshot/restore
    def snapshot(self, *, step: "int | None" = None):
        """Capture the full mid-epoch protocol state (see core/elastic.py).

        ``step`` overrides the driver-maintained next-step index (manual
        access-level drivers pass their own grid position)."""
        from .elastic import ClusterSnapshot

        return ClusterSnapshot.capture(self, step=step)

    @staticmethod
    def restore(snap, *, plan: "ChunkingPlan | None" = None, store=None) -> "Cluster":
        """Rebuild a mid-epoch cluster — in a fresh process — from a
        :class:`repro.core.elastic.ClusterSnapshot`.

        The plan comes from ``store`` when one is attached (real-bytes
        resume; payloads of resident/prefetched files are re-read from it),
        else must be passed explicitly (id-space resume)."""
        if plan is None:
            if store is None:
                raise ValueError("restore() needs a ChunkingPlan or a ChunkStore")
            plan = store.plan
        snap.check_plan(plan)
        cfg = snap.config
        cluster = Cluster(
            plan,
            int(cfg["num_nodes"]),
            remote_memory_limit_bytes=int(cfg["remote_memory_limit_bytes"]),
            prefetch_window=int(cfg["prefetch_window"]),
            policy=cfg["policy"],
            prefetch=bool(cfg["prefetch"]),
            seed=cfg["seed"],
            store=store,
        )
        snap.install(cluster)
        return cluster
