"""SessionSpec: the one session-describing object across loader/service/wire.

Every way of standing up a Redox data session — a co-located
:class:`~repro.core.loader.RedoxLoader`, a
:meth:`repro.service.DataService.open_session` call, or an
``open_session`` message on the out-of-process transport
(:mod:`repro.service.transport`) — used to spell the same ~10 knobs as its
own keyword list. :class:`SessionSpec` is the single frozen value object
they all accept: protocol policy and RNG seeds, cluster/batch geometry,
the execution engine, and the prefetch/plan-ahead depths. It is plain
data (JSON round-trippable by construction, because the wire protocol
ships it), so a spec built for a local loader is byte-for-byte the spec a
remote trainer sends to the service.

The legacy kwarg spellings (and the ``use_planner`` alias for
``engine``) remain as thin deprecation shims at each call site;
``tests/test_service.py`` asserts the shims and the spec form build
identical sessions.

:class:`StoreSpec` gives the *store* the same treatment (DESIGN.md §15):
one frozen value object for storage configuration — backend + backend
kwargs, codec, compression level, fidelity bands — persisted by
``ChunkStore.build`` as ``store.json`` in the store root so
``ChunkStore.open(root)`` needs no flags, and shipped over the wire so a
remote trainer resolves the served store's codec without guessing.
"""

from __future__ import annotations

import dataclasses

__all__ = ["SessionSpec", "StoreSpec"]

_ENGINES = ("replay", "step", "per_access")


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """Frozen description of one training job's data session.

    ``seed`` drives the protocol RNG (refill tie-breaks); ``sampler_seed``
    the per-epoch access permutation (defaults to ``seed + 1``, the
    historical convention). ``queue_depth`` doubles as the session's
    plan-ahead depth: the async loader's prefetch queue in-process, the
    shared-memory ring's frame budget out-of-process.
    """

    policy: str = "max_fill"
    seed: int = 0
    sampler_seed: "int | None" = None
    num_nodes: int = 1
    batch_per_node: int = 8
    seq_len: int = 128
    pad_id: int = 0
    engine: str = "replay"
    prefetch: bool = True
    prefetch_window: int = 64
    remote_memory_limit_bytes: int = 1 << 62
    queue_depth: int = 2
    #: Decode only the first ``fidelity`` bands of a progressive store
    #: (None = full fidelity). Ignored by stores built with ``bands=1``.
    fidelity: "int | None" = None

    def __post_init__(self):
        if self.engine not in _ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; expected one of {_ENGINES}"
            )
        if self.num_nodes < 1 or self.batch_per_node < 1 or self.seq_len < 1:
            raise ValueError(
                "num_nodes, batch_per_node and seq_len must be positive, got "
                f"{self.num_nodes}/{self.batch_per_node}/{self.seq_len}"
            )
        if self.fidelity is not None and self.fidelity < 1:
            raise ValueError(f"fidelity must be >= 1, got {self.fidelity}")

    # --------------------------------------------------------------- derived
    @property
    def effective_sampler_seed(self) -> int:
        return self.seed + 1 if self.sampler_seed is None else self.sampler_seed

    @property
    def global_batch(self) -> int:
        return self.num_nodes * self.batch_per_node

    def replace(self, **changes) -> "SessionSpec":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------------ wire
    def to_json(self) -> dict:
        """A plain-JSON dict (the wire form; also what launchers log)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, data: dict) -> "SessionSpec":
        """Inverse of :meth:`to_json`. Unknown keys are rejected — a typo'd
        knob silently falling back to a default is exactly the bug class
        this object exists to kill."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(data) - known
        if extra:
            raise ValueError(f"unknown SessionSpec fields: {sorted(extra)}")
        return cls(**data)

    # ------------------------------------------------------------ kwarg shim
    @classmethod
    def from_kwargs(cls, **kwargs) -> "SessionSpec":
        """Build a spec from the legacy keyword spelling (deprecation shim).

        Accepts exactly the old ``DataService.open_session`` /
        ``RedoxLoader`` keyword set, including the ``use_planner`` boolean
        alias for ``engine`` (``True`` -> ``"replay"``, ``False`` ->
        ``"step"``). New call sites should construct a SessionSpec.
        """
        use_planner = kwargs.pop("use_planner", None)
        if use_planner is not None:
            if kwargs.get("engine") is not None:
                raise ValueError("pass either use_planner or engine, not both")
            kwargs["engine"] = "replay" if use_planner else "step"
        elif kwargs.get("engine") is None:
            kwargs.pop("engine", None)
        return cls.from_json(kwargs)


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Frozen description of one chunk store's byte representation + backend.

    ``codec``/``level``/``bands`` are *layout* properties — they describe
    the bytes on disk, are fixed at build time, and round-trip through the
    ``store.json`` sidecar. ``backend``/``backend_kwargs`` are the store's
    *default* read path; an explicit ``backend=`` at ``ChunkStore.open``
    may override them (a runtime choice), but a conflicting layout is
    refused. ``bands > 1`` or ``codec != "none"`` selects the framed
    progressive layout; the default spec is byte-identical to the legacy
    raw concatenation.
    """

    backend: str = "vfs"
    backend_kwargs: dict = dataclasses.field(default_factory=dict)
    codec: str = "none"
    level: int = -1
    bands: int = 1

    def __post_init__(self):
        # Deferred import: repro.core.storage imports this module at load
        # time; by the time a StoreSpec is constructed both are initialised.
        from .storage.codec import CODECS

        if self.codec not in CODECS:
            raise ValueError(
                f"unknown codec {self.codec!r}; expected one of {sorted(CODECS)}"
            )
        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(f"backend must be a backend name, got {self.backend!r}")
        if not 1 <= self.bands <= 255:
            raise ValueError(f"bands must be in 1..255, got {self.bands}")

    # --------------------------------------------------------------- derived
    @property
    def framed(self) -> bool:
        """True when chunk files carry the frame container (codec/bands)."""
        return self.codec != "none" or self.bands > 1

    def layout_fields(self) -> dict:
        return {"codec": self.codec, "level": self.level, "bands": self.bands}

    def replace(self, **changes) -> "StoreSpec":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------------ wire
    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, data: dict) -> "StoreSpec":
        """Inverse of :meth:`to_json`; unknown keys are rejected."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(data) - known
        if extra:
            raise ValueError(f"unknown StoreSpec fields: {sorted(extra)}")
        data = dict(data)
        data["backend_kwargs"] = dict(data.get("backend_kwargs") or {})
        return cls(**data)

    # ------------------------------------------------------------ kwarg shim
    @classmethod
    def from_kwargs(cls, backend="vfs", **kwargs) -> "StoreSpec":
        """Build a spec from the legacy ``ChunkStore`` keyword spelling.

        ``backend`` may be a name or a live :class:`StorageBackend`
        instance (the historical call form) — an instance contributes its
        ``name`` and the store keeps using the instance itself. Remaining
        keywords are StoreSpec fields; anything else is a backend kwarg.
        """
        if not isinstance(backend, str):
            backend = getattr(backend, "name", "vfs")
        fields = {f.name for f in dataclasses.fields(cls)}
        spec_kw = {k: v for k, v in kwargs.items() if k in fields}
        extra = {k: v for k, v in kwargs.items() if k not in fields}
        if extra:
            spec_kw.setdefault("backend_kwargs", {}).update(extra)
        return cls(backend=backend, **spec_kw)
