"""Clairvoyant epoch planner: the *plan* half of the plan/execute split.

The Redox protocol is deterministic given its per-epoch RNG, and the
per-epoch access sequences are pre-shared across nodes (paper §3.4) — so
the entire epoch's I/O is computable before the first byte is read: every
refill chunk and its fill rate, every redirected return, every remote round
trip, every opportunistic ship. NoPFS (clairvoyant prefetching) and
FanStore (metadata/plan layer over a bulk-data layer) motivate exploiting
that, see PAPERS.md.

:class:`EpochPlanner` runs the protocol in id-space on a store-less
:meth:`Cluster.planning_clone` through the batched step engine
(``Cluster.access_step`` / ``LocalNode.request_step`` — NumPy batch
operations over whole steps; per-event Python only where an RNG draw or a
network round trip genuinely serialises the walk) and records the event
stream into an :class:`EpochPlan`. The plan is then *executed* by
``Cluster.replay_stream`` — which also hands the exact global chunk-read
schedule to the storage backend (``ChunkStore.schedule_reads``), replacing
the ``_refill_hints`` heuristic with clairvoyant readahead — or simply
queried (benchmarks price its StepIO records through the time model).

Equivalence to the live per-access walk — same returned stream, same chunk
loads, same counters — is asserted in ``tests/test_planner.py``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.obs import tracer as trace

from .distributed import Cluster
from .sampler import EpochSampler
from .stats import NodeStats, PlannerStats, StepIO

__all__ = ["EpochPlan", "EpochPlanner", "PlanRecorder"]

_IO_FIELDS = ("chunk_loads", "disk_bytes", "file_reads", "net_messages", "net_bytes")


class PlanRecorder:
    """Collects protocol events while a (shadow) cluster walks an epoch.

    Hooked into ``LocalNode._load_chunk`` (chunk-load events) and
    ``Cluster._opportunistic_prefetch`` (ship events) via
    ``Cluster.set_recorder``; the epoch driver reports step boundaries,
    returned ids, and per-step I/O. Works identically under the batched and
    the per-access engines, which is what lets the equivalence tests compare
    their event streams directly.
    """

    def __init__(self) -> None:
        self.step = 0
        self.load_step: list[int] = []
        self.load_owner: list[int] = []
        self.load_chunk: list[int] = []
        self.load_fill_rate: list[float] = []
        self.load_files: list[np.ndarray] = []
        self.ship_step: list[int] = []
        self.ship_src: list[int] = []
        self.ship_dst: list[int] = []
        self.ship_file: list[int] = []
        self.ship_loc: list[int] = []
        self.returned: list[list[np.ndarray]] = []  # [step][node]
        self.step_io: list[dict[int, StepIO]] = []

    # ------------------------------------------------------------- callbacks
    def begin_step(self, step: int) -> None:
        self.step = step

    def end_step(
        self, step: int, returned: list[np.ndarray], io_by_node: dict[int, StepIO]
    ) -> None:
        assert step == len(self.returned)
        self.returned.append(returned)
        self.step_io.append(
            {r: dataclasses.replace(io) for r, io in io_by_node.items()}
        )

    def on_load(
        self, owner: int, chunk: int, fill_rate: float, files: np.ndarray
    ) -> None:
        self.load_step.append(self.step)
        self.load_owner.append(owner)
        self.load_chunk.append(chunk)
        self.load_fill_rate.append(fill_rate)
        self.load_files.append(np.asarray(files, dtype=np.int64))

    def on_ship(self, src: int, dst: int, file_id: int, loc: int) -> None:
        self.ship_step.append(self.step)
        self.ship_src.append(src)
        self.ship_dst.append(dst)
        self.ship_file.append(file_id)
        self.ship_loc.append(loc)


@dataclasses.dataclass
class EpochPlan:
    """The pre-computed I/O schedule of one epoch (id-space, no bytes).

    Everything is stored as flat NumPy arrays in global event order; the
    ``*_range`` helpers slice them per training step for replay. When the
    plan was built with ``stepping="floor_tail"`` the final pseudo-step
    (index ``num_steps``) holds the ragged-tail drain that the loader
    consumes but never yields.
    """

    epoch: int
    batch_per_node: int
    num_nodes: int
    stepping: str
    num_steps: int               # yielded training steps
    has_tail: bool               # extra drain pseudo-step recorded at the end

    # per-node returned files: flat consumption order + per-step offsets
    returned_flat: list[np.ndarray]
    returned_offsets: list[np.ndarray]

    # chunk-load events, global order == the exact chunk-read schedule
    load_step: np.ndarray
    load_owner: np.ndarray
    load_chunk: np.ndarray
    load_fill_rate: np.ndarray
    load_files_flat: np.ndarray
    load_files_offsets: np.ndarray

    # opportunistic prefetch ships, global order
    ship_step: np.ndarray
    ship_src: np.ndarray
    ship_dst: np.ndarray
    ship_file: np.ndarray
    ship_loc: np.ndarray

    # per-(step, node) StepIO counter grid, shape (num_steps [+1], num_nodes)
    io_grid: np.ndarray
    io_nodes_present: np.ndarray  # bool grid: live walk created an entry

    node_stats: list[NodeStats]   # exact end-of-epoch protocol counters
    # Elastic extensions: a *suffix* plan (EpochPlanner.plan_from) covers the
    # epoch from ``start_step`` on (its arrays are indexed relative to that);
    # ``joined_nodes`` counts nodes admitted mid-epoch by a ``joins``
    # schedule, so replay can grow matching shells.
    start_step: int = 0
    joined_nodes: int = 0
    stats: PlannerStats = dataclasses.field(default_factory=PlannerStats)

    # ------------------------------------------------------------ accessors
    @property
    def chunk_schedule(self) -> np.ndarray:
        """The exact global chunk-read schedule, in read order."""
        return self.load_chunk

    def load_range(self, step: int) -> tuple[int, int]:
        return (
            int(np.searchsorted(self.load_step, step, side="left")),
            int(np.searchsorted(self.load_step, step, side="right")),
        )

    def ship_range(self, step: int) -> tuple[int, int]:
        return (
            int(np.searchsorted(self.ship_step, step, side="left")),
            int(np.searchsorted(self.ship_step, step, side="right")),
        )

    def load_files(self, li: int) -> np.ndarray:
        """Files the ``li``-th chunk load merges into abstract memory."""
        return self.load_files_flat[
            self.load_files_offsets[li] : self.load_files_offsets[li + 1]
        ]

    def step_returned(self, step: int) -> list[np.ndarray]:
        """Per-node returned file ids of ``step``, in consumption order."""
        return [
            self.returned_flat[r][
                self.returned_offsets[r][step] : self.returned_offsets[r][step + 1]
            ]
            for r in range(self.num_nodes)
        ]

    def step_io(self, step: int) -> dict[int, StepIO]:
        """Fresh StepIO objects reproducing the live walk's ``io_by_node``."""
        out: dict[int, StepIO] = {}
        for r in range(self.num_nodes):
            if not self.io_nodes_present[step, r]:
                continue
            vals = self.io_grid[step, r]
            out[r] = StepIO(**{f: int(v) for f, v in zip(_IO_FIELDS, vals)})
        return out

    @staticmethod
    def from_recorder(
        rec: "PlanRecorder",
        *,
        epoch: int,
        batch_per_node: int,
        num_nodes: int,
        stepping: str,
        num_steps: int,
        node_stats: "list[NodeStats]",
        start_step: int = 0,
        joined_nodes: int = 0,
    ) -> "EpochPlan":
        """Assemble a plan from a recorded epoch walk.

        Shared by :class:`EpochPlanner` (solo shadow walk) and the data
        service's joint planner (``repro/service``), which interleaves many
        shadow clusters and therefore drives the streams itself. A node
        joined mid-walk has no entries in the pre-join steps; its rows are
        padded with empty returns there (matching the live driver's grid).
        """
        has_tail = len(rec.returned) > num_steps
        none = np.empty(0, dtype=np.int64)

        returned_flat, returned_offsets = [], []
        for r in range(num_nodes):
            per_step = [s[r] if r < len(s) else none for s in rec.returned]
            offs = np.zeros(len(per_step) + 1, dtype=np.int64)
            np.cumsum([p.size for p in per_step], out=offs[1:])
            returned_flat.append(
                np.concatenate(per_step) if per_step else np.empty(0, np.int64)
            )
            returned_offsets.append(offs)

        file_counts = [f.size for f in rec.load_files]
        load_files_offsets = np.zeros(len(file_counts) + 1, dtype=np.int64)
        np.cumsum(file_counts, out=load_files_offsets[1:])

        io_grid = np.zeros(
            (len(rec.step_io), num_nodes, len(_IO_FIELDS)), dtype=np.int64
        )
        io_present = np.zeros((len(rec.step_io), num_nodes), dtype=bool)
        for s, io_by_node in enumerate(rec.step_io):
            for r, io in io_by_node.items():
                io_present[s, r] = True
                io_grid[s, r] = [getattr(io, f) for f in _IO_FIELDS]

        plan = EpochPlan(
            epoch=epoch,
            batch_per_node=batch_per_node,
            num_nodes=num_nodes,
            stepping=stepping,
            num_steps=num_steps,
            has_tail=has_tail,
            returned_flat=returned_flat,
            returned_offsets=returned_offsets,
            load_step=np.asarray(rec.load_step, dtype=np.int64),
            load_owner=np.asarray(rec.load_owner, dtype=np.int64),
            load_chunk=np.asarray(rec.load_chunk, dtype=np.int64),
            load_fill_rate=np.asarray(rec.load_fill_rate, dtype=np.float64),
            load_files_flat=(
                np.concatenate(rec.load_files)
                if rec.load_files else np.empty(0, np.int64)
            ),
            load_files_offsets=load_files_offsets,
            ship_step=np.asarray(rec.ship_step, dtype=np.int64),
            ship_src=np.asarray(rec.ship_src, dtype=np.int64),
            ship_dst=np.asarray(rec.ship_dst, dtype=np.int64),
            ship_file=np.asarray(rec.ship_file, dtype=np.int64),
            ship_loc=np.asarray(rec.ship_loc, dtype=np.int64),
            io_grid=io_grid,
            io_nodes_present=io_present,
            node_stats=[s.copy() for s in node_stats],
            start_step=start_step,
            joined_nodes=joined_nodes,
        )
        plan.stats = PlannerStats(
            planned_steps=num_steps,
            planned_accesses=sum(int(f.size) for f in returned_flat),
            planned_chunk_loads=int(plan.load_chunk.size),
            planned_ships=int(plan.ship_file.size),
        )
        return plan

    def validate(
        self,
        cluster: Cluster,
        epoch: int | None = None,
        batch_per_node: int | None = None,
        stepping: str | None = None,
    ) -> None:
        """Refuse to replay under a different grid than the plan was cut for."""
        if cluster.num_nodes != self.num_nodes:
            raise ValueError(
                f"plan is for {self.num_nodes} nodes, cluster has {cluster.num_nodes}"
            )
        if epoch is not None and epoch != self.epoch:
            raise ValueError(f"plan is for epoch {self.epoch}, asked to replay {epoch}")
        if batch_per_node is not None and batch_per_node != self.batch_per_node:
            raise ValueError(
                f"plan was computed for batch_per_node={self.batch_per_node}, "
                f"asked to replay with {batch_per_node}"
            )
        if stepping is not None and stepping != self.stepping:
            raise ValueError(
                f"plan uses {self.stepping!r} stepping, replay expects {stepping!r}"
            )


class EpochPlanner:
    """Computes :class:`EpochPlan` objects for a live cluster.

    The planner never touches the live cluster's state: it simulates on a
    fresh store-less clone with identical configuration. Per-epoch RNG
    derivation makes the clone's epoch-``e`` walk bit-identical to the live
    cluster's, independent of execution history — the paper's determinism
    argument (§3.4) turned into an artifact.
    """

    def __init__(self, cluster: Cluster):
        self.cluster = cluster

    def plan(
        self,
        sampler: EpochSampler,
        epoch: int,
        batch_per_node: int,
        *,
        stepping: str = "ceil",
        failures: "dict[int, int] | None" = None,
        joins: "dict[int, int] | None" = None,
    ) -> EpochPlan:
        t0 = time.perf_counter()
        shadow = self.cluster.planning_clone()
        initial_nodes = shadow.num_nodes
        rec = PlanRecorder()
        steps = 0
        for step, _, _, _ in shadow.epoch_stream(
            sampler, epoch, batch_per_node,
            stepping=stepping, recorder=rec, failures=failures, joins=joins,
        ):
            steps = step + 1
        plan = EpochPlan.from_recorder(
            rec,
            epoch=epoch,
            batch_per_node=batch_per_node,
            num_nodes=shadow.num_nodes,
            stepping=stepping,
            num_steps=steps,
            node_stats=[n.stats for n in shadow.nodes],
            joined_nodes=shadow.num_nodes - initial_nodes,
        )
        plan.stats.plan_time_s = time.perf_counter() - t0
        tracer = trace.get()
        if tracer is not None:
            tracer.complete(
                "planner.plan", "plan", t0, plan.stats.plan_time_s,
                {"epoch": epoch, "steps": steps},
            )
        return plan

    def plan_from(
        self,
        snapshot,
        *,
        failures: "dict[int, int] | None" = None,
        joins: "dict[int, int] | None" = None,
    ) -> EpochPlan:
        """Re-plan the epoch *suffix* from a mid-epoch snapshot.

        A store-less shadow is restored from the snapshot and walked to the
        end of the epoch; the recorded events become a suffix
        :class:`EpochPlan` (``start_step = snapshot.step``, arrays indexed
        relative to it) that ``replay_stream`` executes — handing the
        backend exactly the *remaining* chunk-read schedule. Elastic-event
        schedules are keyed by absolute step, so passing the original
        ``failures``/``joins`` dicts replays the scenario's suffix events.
        """
        t0 = time.perf_counter()
        shadow = Cluster.restore(snapshot, plan=self.cluster.plan)
        initial_nodes = shadow.num_nodes
        batch = snapshot.grid.get("batch_per_node")
        stepping = snapshot.grid.get("stepping") or "ceil"
        assert batch is not None, "snapshot carries no step grid to re-plan on"
        rec = PlanRecorder()
        steps = 0
        for step, _, _, _ in shadow.epoch_stream(
            None, snapshot.epoch, batch,
            stepping=stepping, recorder=rec, failures=failures, joins=joins,
            start_step=snapshot.step, resume=True,
        ):
            steps = step - snapshot.step + 1
        plan = EpochPlan.from_recorder(
            rec,
            epoch=snapshot.epoch,
            batch_per_node=batch,
            num_nodes=shadow.num_nodes,
            stepping=stepping,
            num_steps=steps,
            node_stats=[n.stats for n in shadow.nodes],
            start_step=snapshot.step,
            joined_nodes=shadow.num_nodes - initial_nodes,
        )
        plan.stats.plan_time_s = time.perf_counter() - t0
        tracer = trace.get()
        if tracer is not None:
            tracer.complete(
                "planner.plan_from", "plan", t0, plan.stats.plan_time_s,
                {"epoch": snapshot.epoch, "start_step": snapshot.step,
                 "steps": steps},
            )
        return plan

    def state_at(
        self,
        sampler: EpochSampler,
        epoch: int,
        batch_per_node: int,
        step: int,
        *,
        stepping: str = "ceil",
        failures: "dict[int, int] | None" = None,
        joins: "dict[int, int] | None" = None,
    ):
        """The cluster's exact protocol state at the ``step`` barrier of
        ``epoch``, as a :class:`~repro.core.elastic.ClusterSnapshot` —
        computed on a store-less shadow (the live cluster is untouched).

        This is how a *replay* session suspends: its protocol state is
        implicit in the plan, so the snapshot is derived by simulating the
        prefix in id-space (per-epoch RNG derivation makes the shadow walk
        bit-identical to the live one)."""
        shadow = self.cluster.planning_clone()
        if step == 0:
            shadow.begin_epoch(sampler, epoch)
            shadow._grid = (batch_per_node, stepping)
            return shadow.snapshot(step=0)
        for s, _, _, _ in shadow.epoch_stream(
            sampler, epoch, batch_per_node,
            stepping=stepping, failures=failures, joins=joins,
        ):
            if s + 1 >= step:
                break
        return shadow.snapshot(step=step)
