"""Elastic data plane: snapshot/restore of mid-epoch protocol state (DESIGN.md §10).

The Redox protocol keeps *all* of its state explicit — per-node access
sequences and positions, abstract-memory residency, the consumption
journal, the prefetch check lists, and the refill RNG streams — which is
what makes the mid-epoch state machine checkpointable: a
:class:`ClusterSnapshot` captures every one of those arrays, round-trips
through an ``.npz`` + JSON-manifest pair (the same format family as
``repro.checkpoint``), and a **fresh process** can rebuild the cluster and
continue the epoch with a byte-identical stream (``tests/elastic_harness.py``
proves it differentially).

Payload bytes are deliberately *not* part of the snapshot: a resident file
is by definition un-consumed, so its chunk is still on disk — restore
re-reads exactly the chunks backing resident/prefetched files
(:func:`ClusterSnapshot.install` rehydration). This is the same durability
argument that makes ``Cluster.fail_node`` sound (never-evicted residents
are re-fetchable), applied to suspend/resume.
"""

from __future__ import annotations

import copy
import dataclasses
import json
import uuid
from pathlib import Path

import numpy as np

from .stats import NodeStats

__all__ = ["ClusterSnapshot"]

STATE_FILE = "data_state.npz"
MANIFEST_FILE = "data_manifest.json"


@dataclasses.dataclass
class ClusterSnapshot:
    """Full mid-epoch protocol state of one :class:`repro.core.Cluster`.

    The state inventory (one entry per protocol subsystem):

    * ``sequences``/``positions`` — per-node access sequences (as rebalanced
      by any ``fail_node``/``join_node`` so far) and the per-node cursor of
      accesses already served;
    * ``resident``/``mem_peak`` — every node's abstract-memory slot table;
    * ``consumed`` — the per-node consumption journals (exactly-once);
    * ``remote_loc``/``remote_peak`` — requester-side prefetched files;
    * ``pending``/``pending_sent`` — the outstanding-prefetch check lists;
    * ``rng_states`` — each node's refill RNG, mid-stream;
    * ``node_stats`` — exact protocol counters so the resumed epoch's
      end-of-epoch NodeStats equal the uninterrupted run's;
    * ``owner_of_group``/``failed`` — the elastic ownership map.
    """

    config: dict                # Cluster constructor configuration
    plan_fp: dict               # ChunkingPlan fingerprint (restore validation)
    epoch: int
    step: int                   # next step index of the epoch driver
    grid: dict                  # {"batch_per_node": int|None, "stepping": str|None}
    owner_of_group: np.ndarray  # int32[G]
    failed: np.ndarray          # bool[N]
    positions: np.ndarray       # int64[N] accesses served per node
    sequences: list             # list[int64[...]] per node
    resident: np.ndarray        # int64[N, G, c] abstract-memory slot tables
    mem_peak: np.ndarray        # int64[N]
    consumed: np.ndarray        # bool[N, num_files]
    remote_loc: np.ndarray      # int64[N, M] remote-memory location tables
    remote_peak: np.ndarray     # int64[N]
    pending: np.ndarray         # bool[N, N, M] prefetch check lists
    pending_sent: np.ndarray    # int64[N, N, M]
    rng_states: list            # list[dict] PCG64 states (json-able)
    node_stats: list            # list[NodeStats]

    # ------------------------------------------------------------- capture
    @staticmethod
    def capture(cluster, *, step: "int | None" = None) -> "ClusterSnapshot":
        """Copy every piece of mid-epoch state out of ``cluster``.

        ``step`` is the next step index the epoch driver would execute
        (defaults to the driver-maintained ``cluster.current_step``); manual
        access-level drivers pass their own.
        """
        assert cluster.sequences is not None, "snapshot outside an epoch"
        plan = cluster.plan
        n = cluster.num_nodes
        batch, stepping = cluster._grid
        return ClusterSnapshot(
            config=dict(
                num_nodes=n,
                policy=cluster.policy,
                prefetch=bool(cluster.prefetch),
                prefetch_window=int(cluster.prefetch_window),
                seed=cluster.seed,
                remote_memory_limit_bytes=int(cluster._remote_limit),
            ),
            plan_fp=dict(
                num_files=plan.num_files,
                chunk_size=plan.chunk_size,
                num_chunks=plan.num_chunks,
                num_groups=plan.num_groups,
                seed=plan.seed,
            ),
            epoch=int(cluster.epoch),
            step=int(cluster.current_step if step is None else step),
            grid={"batch_per_node": batch, "stepping": stepping},
            owner_of_group=cluster.owner_of_group.copy(),
            failed=cluster.failed.copy(),
            positions=np.asarray(cluster.positions, dtype=np.int64).copy(),
            sequences=[s.copy() for s in cluster.sequences],
            resident=np.stack([nd.memory.resident for nd in cluster.nodes]).copy(),
            mem_peak=np.array(
                [nd.memory.peak_bytes for nd in cluster.nodes], dtype=np.int64
            ),
            consumed=np.stack([nd.consumed for nd in cluster.nodes]).copy(),
            remote_loc=np.stack(
                [rm._loc_file for rm in cluster.remote_mem]
            ).copy(),
            remote_peak=np.array(
                [rm.peak_bytes for rm in cluster.remote_mem], dtype=np.int64
            ),
            pending=np.stack(
                [np.stack(row) for row in cluster.pending]
            ).copy(),
            pending_sent=np.stack(
                [np.stack(row) for row in cluster.pending_sent]
            ).copy(),
            rng_states=[
                copy.deepcopy(nd.rng.bit_generator.state) for nd in cluster.nodes
            ],
            node_stats=[nd.stats.copy() for nd in cluster.nodes],
        )

    # ------------------------------------------------------------- install
    def install(self, cluster, *, rehydrate: bool = True) -> None:
        """Write this snapshot's state into a freshly constructed cluster.

        ``cluster`` must have been built with this snapshot's configuration
        (``Cluster.restore`` does both halves). With a ChunkStore attached
        and ``rehydrate=True``, payload bytes for resident and prefetched
        files are re-read from storage — exactly one ``read_chunk`` per
        chunk backing live state.
        """
        plan = cluster.plan
        cluster.owner_of_group[:] = self.owner_of_group
        cluster.failed[:] = self.failed
        cluster.positions = self.positions.copy()
        cluster.sequences = [s.copy() for s in self.sequences]
        for r, node in enumerate(cluster.nodes):
            mem = node.memory
            mem.resident[:] = self.resident[r]
            live = mem.resident_flat[mem.resident_flat >= 0]
            mem.used_bytes = int(plan.file_sizes[live].sum())
            mem.resident_count = int(live.size)
            mem.peak_bytes = int(self.mem_peak[r])
            node.consumed[:] = self.consumed[r]
            node.rng.bit_generator.state = copy.deepcopy(self.rng_states[r])
            node.stats = self.node_stats[r].copy()
            rm = cluster.remote_mem[r]
            rm._loc_file[:] = self.remote_loc[r]
            held = rm._loc_file[rm._loc_file >= 0]
            rm._count = int(held.size)
            rm.used_bytes = int(plan.file_sizes[held].sum())
            rm.peak_bytes = int(self.remote_peak[r])
        for o in range(cluster.num_nodes):
            for r in range(cluster.num_nodes):
                cluster.pending[o][r][:] = self.pending[o, r]
                cluster.pending_sent[o][r][:] = self.pending_sent[o, r]
        cluster.epoch = int(self.epoch)
        cluster.current_step = int(self.step)
        cluster._grid = (self.grid.get("batch_per_node"), self.grid.get("stepping"))
        cluster._index_sequences()
        if rehydrate and cluster.store is not None:
            self._rehydrate_payloads(cluster)

    def _rehydrate_payloads(self, cluster) -> None:
        """Re-read the chunks backing resident/prefetched files (real-bytes
        mode): un-consumed state is by definition still on disk."""
        plan = cluster.plan
        # file -> ("local", node) or ("remote", node, loc)
        wanted: "dict[int, tuple]" = {}
        for r, node in enumerate(cluster.nodes):
            for f in node.memory.resident_flat[
                node.memory.resident_flat >= 0
            ].tolist():
                wanted[int(f)] = ("local", r)
            rm = cluster.remote_mem[r]
            for loc in rm.locations().tolist():
                wanted[int(rm._loc_file[loc])] = ("remote", r, int(loc))
        if not wanted:
            return
        chunks = np.unique(plan.chunk_of[np.fromiter(wanted, dtype=np.int64)])
        for k in chunks.tolist():
            records = dict(cluster.store.read_chunk(int(k)))
            for f in plan.files_in_chunk(int(k)).tolist():
                where = wanted.get(int(f))
                if where is None:
                    continue
                if where[0] == "local":
                    cluster.nodes[where[1]].buffer[int(f)] = records[int(f)]
                else:
                    cluster.remote_mem[where[1]].store_payload(
                        where[2], records[int(f)]
                    )

    # --------------------------------------------------------- persistence
    def save(self, out_dir: "str | Path") -> Path:
        """Write ``data_state.npz`` + ``data_manifest.json`` under ``out_dir``.

        Both files are written to temp names and atomically replaced, and
        both carry a shared per-save token: a crash between the two
        replaces (the launchers overwrite the same directory at every
        checkpoint) leaves a *torn* pair that :meth:`load` rejects with a
        clear error instead of resuming from mixed state.
        """
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        token = uuid.uuid4().hex
        seq_offs = np.zeros(len(self.sequences) + 1, dtype=np.int64)
        np.cumsum([s.size for s in self.sequences], out=seq_offs[1:])
        seq_flat = (
            np.concatenate(self.sequences)
            if self.sequences else np.empty(0, np.int64)
        )
        tmp_state = out_dir / (".tmp_" + STATE_FILE)
        tmp_manifest = out_dir / (".tmp_" + MANIFEST_FILE)
        try:
            np.savez_compressed(
                tmp_state,
                token=np.array(token),
                seq_flat=seq_flat,
                seq_offs=seq_offs,
                owner_of_group=self.owner_of_group,
                failed=self.failed,
                positions=self.positions,
                resident=self.resident,
                mem_peak=self.mem_peak,
                consumed=self.consumed,
                remote_loc=self.remote_loc,
                remote_peak=self.remote_peak,
                pending=self.pending,
                pending_sent=self.pending_sent,
            )
            manifest = dict(
                token=token,
                config=self.config,
                plan_fp=self.plan_fp,
                epoch=self.epoch,
                step=self.step,
                grid=self.grid,
                rng_states=self.rng_states,
                node_stats=[dataclasses.asdict(s) for s in self.node_stats],
            )
            tmp_manifest.write_text(json.dumps(manifest))
            tmp_state.replace(out_dir / STATE_FILE)
            tmp_manifest.replace(out_dir / MANIFEST_FILE)
        except BaseException:
            tmp_state.unlink(missing_ok=True)
            tmp_manifest.unlink(missing_ok=True)
            raise
        return out_dir

    @staticmethod
    def load(in_dir: "str | Path") -> "ClusterSnapshot":
        in_dir = Path(in_dir)
        manifest = json.loads((in_dir / MANIFEST_FILE).read_text())
        with np.load(in_dir / STATE_FILE, allow_pickle=False) as z:
            if str(z["token"]) != manifest["token"]:
                raise ValueError(
                    f"torn snapshot in {in_dir}: {STATE_FILE} and "
                    f"{MANIFEST_FILE} come from different save() calls "
                    "(crash mid-overwrite?) — restore from an older "
                    "checkpoint"
                )
            seq_offs = z["seq_offs"]
            seq_flat = z["seq_flat"]
            sequences = [
                seq_flat[seq_offs[i] : seq_offs[i + 1]].copy()
                for i in range(seq_offs.size - 1)
            ]
            return ClusterSnapshot(
                config=manifest["config"],
                plan_fp=manifest["plan_fp"],
                epoch=int(manifest["epoch"]),
                step=int(manifest["step"]),
                grid=manifest["grid"],
                owner_of_group=z["owner_of_group"].copy(),
                failed=z["failed"].copy(),
                positions=z["positions"].copy(),
                sequences=sequences,
                resident=z["resident"].copy(),
                mem_peak=z["mem_peak"].copy(),
                consumed=z["consumed"].copy(),
                remote_loc=z["remote_loc"].copy(),
                remote_peak=z["remote_peak"].copy(),
                pending=z["pending"].copy(),
                pending_sent=z["pending_sent"].copy(),
                rng_states=manifest["rng_states"],
                node_stats=[
                    NodeStats(**d) for d in manifest["node_stats"]
                ],
            )

    # ----------------------------------------------------------- validation
    def check_plan(self, plan) -> None:
        fp = dict(
            num_files=plan.num_files,
            chunk_size=plan.chunk_size,
            num_chunks=plan.num_chunks,
            num_groups=plan.num_groups,
            seed=plan.seed,
        )
        if fp != self.plan_fp:
            raise ValueError(
                f"snapshot was taken against a different ChunkingPlan: "
                f"{self.plan_fp} != {fp}"
            )
