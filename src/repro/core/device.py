"""DeviceStager: the host→device half of the data plane (DESIGN.md §12).

Everything before this module ends at host memory: the protocol batches
chunks, the loader assembles (B, S) grids, and the train loop pays a
synchronous ``jnp.asarray`` per step — decode, grid assembly, and the
host→device copy all sit on the critical path. The stager moves that
whole tail off it:

* a dedicated **staging thread** drives the host batch pipeline (protocol
  walk stays on the loader's own worker thread), so decode/pack and the
  ``jax.device_put`` transfer run while the consumer's previous train
  step computes;
* batches are **double-buffered** (``depth`` staged batches in flight):
  stage(step k+1) overlaps train_step(k), the same pipeline the paper's
  clients use to hide server latency, applied to the PCIe/ICI hop;
* with ``use_kernel=True`` the host ships one lane-padded int32 *slot
  buffer* plus the scalar redirection/length tables instead of three
  pre-assembled grids (~1/3 of the H2D bytes), and the
  :func:`~repro.kernels.chunk_gather.ops.chunk_gather_train` Pallas pass
  assembles tokens/targets/loss-mask on-device — the paper's redirection
  table as a scalar-prefetch gather.

On TPU the slot buffer lands in HBM as one contiguous transfer from
pinned host memory and the gather happens in the BlockSpec index_map DMA;
on CPU/interpret backends ``device_put`` degrades to a memcpy on the
staging thread, which still buys the overlap (NumPy and XLA release the
GIL). Buffer lifetime: staged-but-unconsumed device buffers are tracked
and explicitly released on teardown — including abandoned-consumer
shutdown — so a ``break`` mid-epoch never strands device memory; consumed
batches are donated to the train step's ``donate_argnums`` and die with
it.

Per-step accounting lands in :class:`~repro.core.stats.StepIO`
(``stage_s`` / ``stage_wait_s``) and the stream-level aggregate in
:class:`~repro.core.stats.DeviceStats` (``overlap_fraction``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time

import jax
import numpy as np

from repro.obs import tracer as trace

from ..kernels.chunk_gather.ops import chunk_gather_train
from ..kernels.common import resolve_interpret, round_up
from .stats import DeviceStats

__all__ = ["DeviceStager", "HostPack", "pack_records"]

_GRID_KEYS = ("tokens", "targets", "loss_mask")


class HostPack(dict):
    """Host-side staging payload for the on-device gather: one slot-padded
    token buffer (``slot_tokens``), the clipped record lengths and the
    redirection index table, plus the batch metadata that rides along
    (``step`` / ``io_by_node`` / ``returned`` / ``seq_len`` / ``pad_id``)."""


def pack_records(
    records: "list[np.ndarray]",
    returned: "np.ndarray | None",
    *,
    seq_len: int,
    pad_id: int = 0,
    row_pad: int = 8,
) -> tuple:
    """Pack decoded records into (slot_tokens, lens, idx) for the gather.

    Rows redirected to the same record share one slot (``returned`` file
    ids key the dedup — exactly-once makes them distinct within an epoch,
    but the pack stays correct for any index pattern). Slot rows are
    padded to a multiple of ``row_pad`` columns (128 on real TPUs — the
    lane width the kernel DMAs in; small on interpret backends).
    """
    n_rows = len(records)
    if returned is not None and len(returned) == n_rows:
        uniq, first, inv = np.unique(
            np.asarray(returned), return_index=True, return_inverse=True
        )
    else:
        first = np.arange(n_rows)
        inv = np.arange(n_rows)
    full = seq_len + 1
    lp = round_up(full, row_pad)
    slot_tokens = np.full((len(first), lp), pad_id, dtype=np.int32)
    lens = np.zeros(len(first), dtype=np.int32)
    for s, r in enumerate(first):
        rec = records[int(r)]
        n = min(rec.shape[0], full)
        slot_tokens[s, :n] = rec[:n]
        lens[s] = n
    return slot_tokens, lens, inv.astype(np.int32)


class DeviceStager:
    """Double-buffered host→device staging with optional on-device gather.

    ``use_kernel=None`` (auto) enables the Pallas assembly whenever the
    input stream carries :class:`HostPack` items (the
    ``RedoxLoader.epoch_device`` path) and falls back to plain grid
    staging for pre-assembled batches (the ``RedoxClient`` ring path,
    whose frames ship grids). ``interpret`` follows the kernel convention
    (``None`` -> compiled on TPU, interpreted elsewhere).
    """

    def __init__(
        self,
        *,
        device=None,
        use_kernel: "bool | None" = None,
        interpret: "bool | None" = None,
        depth: int = 2,
    ):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.device = device if device is not None else jax.devices()[0]
        self.use_kernel = use_kernel
        self.interpret = resolve_interpret(interpret)
        self.depth = depth
        self.stats = DeviceStats()
        self._inflight: list = []
        self._lock = threading.Lock()
        self._thread: "threading.Thread | None" = None
        self._streaming = False

    @property
    def row_pad(self) -> int:
        """Slot-row column padding the packer must honour: the (8, 128)
        lane width when the gather compiles, a token-level 8 otherwise."""
        return 8 if self.interpret else 128

    @property
    def live_buffers(self) -> int:
        """Staged-but-unconsumed device batches currently held."""
        with self._lock:
            return len(self._inflight)

    # ---------------------------------------------------------------- stage
    def stage(self, item: dict) -> dict:
        """Ship one host batch/pack to the device; returns the device batch.

        Dispatches asynchronously where the backend allows: the returned
        arrays are futures, forced only when the consumer's train step
        reads them.
        """
        t0 = time.perf_counter()
        is_pack = "slot_tokens" in item
        if is_pack and self.use_kernel is not False:
            slot = jax.device_put(item["slot_tokens"], self.device)
            lens = jax.device_put(item["lens"], self.device)
            idx = jax.device_put(item["idx"], self.device)
            tokens, targets, loss_mask = chunk_gather_train(
                slot, lens, idx,
                seq_len=int(item["seq_len"]),
                pad_id=int(item["pad_id"]),
                interpret=self.interpret,
            )
            moved = (
                item["slot_tokens"].nbytes
                + item["lens"].nbytes
                + item["idx"].nbytes
            )
            self.stats.kernel_steps += 1
        else:
            if is_pack:
                raise ValueError(
                    "DeviceStager(use_kernel=False) cannot stage HostPacks; "
                    "feed it assembled batches (epoch_async) instead"
                )
            tokens = jax.device_put(item["tokens"], self.device)
            targets = jax.device_put(item["targets"], self.device)
            loss_mask = jax.device_put(item["loss_mask"], self.device)
            moved = sum(np.asarray(item[k]).nbytes for k in _GRID_KEYS)
        stage_s = time.perf_counter() - t0
        tracer = trace.get()
        if tracer is not None:
            tracer.complete(
                "stager.stage", "stage", t0, stage_s,
                {"step": int(item.get("step", -1)), "bytes": int(moved),
                 "kernel": bool(is_pack and self.use_kernel is not False)},
            )
        # Copy the StepIO entries before annotating: replay-engine batches
        # share them with the EpochPlan, which must stay reusable.
        io = {
            n: dataclasses.replace(s, stage_s=0.0, stage_wait_s=0.0)
            for n, s in item.get("io_by_node", {}).items()
        }
        if io:
            io[min(io)].stage_s = stage_s
        out = dict(item)
        for k in ("slot_tokens", "lens", "idx", "seq_len", "pad_id"):
            out.pop(k, None)
        out.update(
            tokens=tokens, targets=targets, loss_mask=loss_mask,
            io_by_node=io, stage_s=stage_s, stage_wait_s=0.0,
        )
        self.stats.steps += 1
        self.stats.bytes_to_device += int(moved)
        self.stats.stage_s += stage_s
        return out

    # --------------------------------------------------------------- stream
    def stream(self, batches):
        """Yield device-resident batches for a host batch/pack iterator.

        The staging thread drives ``batches`` (so a generator's own
        pipeline — e.g. the loader's protocol worker — runs ahead too),
        stages each item, and feeds a bounded queue of ``depth`` device
        batches. Abandoning this generator tears everything down
        deterministically: the staging thread is signalled and joined, the
        inner iterator is closed *from the staging thread* (its
        ``finally`` runs immediately, not at GC time), and every staged
        batch the consumer never saw has its device buffers released.
        """
        if self._streaming:
            raise RuntimeError("DeviceStager.stream is one-at-a-time; "
                               "create one stager per concurrent stream")
        self._streaming = True
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        end = object()
        stop = threading.Event()
        failure: list[BaseException] = []

        def put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            it = iter(batches)
            try:
                for item in it:
                    staged = self.stage(item)
                    with self._lock:
                        self._inflight.append(staged)
                    if not put(staged):
                        return
            except BaseException as e:
                failure.append(e)
            finally:
                # Close the inner generator from the thread that iterated
                # it — legal (it is suspended) and deterministic.
                close = getattr(it, "close", None)
                if close is not None:
                    try:
                        close()
                    except BaseException as e:
                        failure.append(e)
                put(end)

        t = threading.Thread(target=worker, daemon=True)
        self._thread = t
        t.start()
        try:
            while True:
                t0 = time.perf_counter()
                item = q.get()
                wait = time.perf_counter() - t0
                tracer = trace.get()
                if tracer is not None:
                    # The slice of staging the double buffer failed to hide.
                    tracer.complete("stager.wait", "stage", t0, wait)
                if item is end:
                    break
                with self._lock:
                    self._inflight.remove(item)
                self.stats.wait_s += wait
                item["stage_wait_s"] = wait
                io = item["io_by_node"]
                if io:
                    io[min(io)].stage_wait_s = wait
                yield item
            if failure:
                raise failure[0]
        finally:
            stop.set()
            t.join()
            self._release_inflight()
            self._streaming = False

    # ------------------------------------------------------------- teardown
    def _release_inflight(self) -> None:
        with self._lock:
            stranded, self._inflight = self._inflight, []
        for batch in stranded:
            for k in _GRID_KEYS:
                arr = batch.get(k)
                if hasattr(arr, "delete"):
                    try:
                        arr.delete()
                    except RuntimeError:
                        pass  # already donated/freed
            self.stats.buffers_released += 1

    def close(self) -> None:
        """Release any staged-but-unconsumed device buffers (idempotent).

        ``stream``'s own ``finally`` already does this on abandonment;
        ``close`` exists for explicit lifecycle management and for
        symmetry with the loader/client teardown paths."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("close() while a stream is active; abandon "
                               "or exhaust the stream generator first")
        self._release_inflight()
