"""Abstract memory: virtual slots with dynamic byte accounting (paper §3.2, Fig. 3).

An abstract memory location is *not* bound to physical memory. Bytes are
allocated when a file becomes resident in a slot and freed the moment the
file is consumed (self-invalidation) or shipped to a remote node (prefetch).
This lets variable-sized files share one location without fragmentation —
the paper's answer to variable data-access granularity.
"""

from __future__ import annotations

import numpy as np

__all__ = ["AbstractMemory"]

EMPTY = np.int64(-1)


class AbstractMemory:
    """Slot table for one node's *local* abstract memory.

    ``resident[g, s]`` holds the file id currently cached at abstract
    location ``g * c + s`` or ``-1``. Byte usage is tracked exactly so the
    benchmarks can report peak physical footprint against the node's budget.
    """

    def __init__(self, num_groups: int, chunk_size: int, file_sizes: np.ndarray):
        self.num_groups = num_groups
        self.chunk_size = chunk_size
        self._file_sizes = file_sizes
        self.resident = np.full((num_groups, chunk_size), EMPTY, dtype=np.int64)
        #: flat view sharing storage with ``resident`` — batched engines
        #: gather/scatter by abstract location id (= g * c + s) directly.
        self.resident_flat = self.resident.reshape(-1)
        self.used_bytes = 0
        self.peak_bytes = 0
        self.resident_count = 0

    # ----------------------------------------------------------- operations
    def get(self, group: int, slot: int) -> int:
        """File id at (group, slot) or -1."""
        return int(self.resident[group, slot])

    def fill(self, group: int, slot: int, file_id: int) -> None:
        """Place ``file_id`` into an *empty* slot (never-evict invariant)."""
        assert self.resident[group, slot] == EMPTY, (
            "never-evict violated: attempted to overwrite a valid slot"
        )
        self.resident[group, slot] = file_id
        size = int(self._file_sizes[file_id])
        self.used_bytes += size
        self.resident_count += 1
        if self.used_bytes > self.peak_bytes:
            self.peak_bytes = self.used_bytes

    def take(self, group: int, slot: int) -> int:
        """Remove and return the file at (group, slot).

        Used both by self-invalidation on consumption (paper Fig. 4) and by
        the prefetch path, where the sender's copy is considered consumed
        the moment it is shipped (paper §3.4).
        """
        file_id = int(self.resident[group, slot])
        assert file_id >= 0, "take() on empty slot"
        self.resident[group, slot] = EMPTY
        self.used_bytes -= int(self._file_sizes[file_id])
        self.resident_count -= 1
        return file_id

    # ------------------------------------------------------- batched variants
    def fill_many(self, group: int, slots: np.ndarray, file_ids: np.ndarray) -> None:
        """Vectorised :meth:`fill` of several slots of one group (chunk merge)."""
        assert (self.resident[group, slots] == EMPTY).all(), (
            "never-evict violated: attempted to overwrite a valid slot"
        )
        self.resident[group, slots] = file_ids
        self.used_bytes += int(self._file_sizes[file_ids].sum())
        self.resident_count += int(file_ids.size)
        if self.used_bytes > self.peak_bytes:
            self.peak_bytes = self.used_bytes

    def take_many(self, groups: np.ndarray, slots: np.ndarray) -> np.ndarray:
        """Vectorised :meth:`take` of several (group, slot) locations.

        The caller guarantees the locations are distinct and resident — the
        batched hit path only ever takes first occurrences of valid slots.
        """
        return self.take_many_flat(groups * self.chunk_size + slots)

    def take_many_flat(self, locs: np.ndarray) -> np.ndarray:
        """:meth:`take_many` addressed by abstract location id."""
        file_ids = self.resident_flat[locs]
        assert (file_ids >= 0).all(), "take_many() on an empty slot"
        self.resident_flat[locs] = EMPTY
        self.used_bytes -= int(self._file_sizes[file_ids].sum())
        self.resident_count -= int(file_ids.size)
        return file_ids

    # ------------------------------------------------------------- queries
    def group_empty_mask(self, group: int) -> np.ndarray:
        """bool[c]: which slots of ``group``'s abstract chunk are empty."""
        return self.resident[group] == EMPTY

    def is_empty(self) -> bool:
        return self.resident_count == 0
