"""MetricsRegistry: counters, gauges, histograms + stats-dataclass absorption.

The repo's stats objects (``NodeStats``/``StepIO``/``PlannerStats``/
``ServiceStats``/``DeviceStats``/``BackendStats``) are exact protocol
counters, but each lives on its own object with its own ad-hoc reporting.
The registry gives them one export surface:

* **primitives** — ``counter()`` / ``gauge()`` / ``histogram()`` for new
  instrumentation (monotonic counts, point-in-time values, fixed-bucket
  latency distributions);
* **providers** — ``register_stats(name, fn, labels=...)`` absorbs an
  existing stats dataclass: ``fn()`` is called at :meth:`collect` time and
  every numeric field of its ``to_dict()`` becomes a ``name_field`` sample
  (so the live values are always current — nothing is copied eagerly);
* **export** — :meth:`collect` returns one flat snapshot dict (the
  transport ``metrics`` RPC payload), :meth:`exposition` renders
  Prometheus text format for scraping.

Metric identity is ``(name, frozen labels)``; labels are fixed at creation
(the common case here — per-job, per-backend) rather than per-observation.
"""

from __future__ import annotations

import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _label_key(labels: "dict | None") -> tuple:
    return tuple(sorted((labels or {}).items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        self.value += amount


class Gauge:
    """Point-in-time value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Fixed-bucket histogram (cumulative buckets, Prometheus-style)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: "list[float]"):
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bs
        self.counts = [0] * len(bs)  # per-bucket (non-cumulative) counts
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.buckets):
            if value <= b:
                self.counts[i] += 1
                return
        # over the last bound: lands only in +Inf (tracked via count)

    def cumulative(self) -> "list[tuple[float, int]]":
        out, acc = [], 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((b, acc))
        return out


class MetricsRegistry:
    """One scrapeable namespace of metrics + absorbed stats dataclasses."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: "dict[tuple, Counter]" = {}
        self._gauges: "dict[tuple, Gauge]" = {}
        self._hists: "dict[tuple, Histogram]" = {}
        # name -> list of (labels, provider); providers return a stats
        # dataclass with .to_dict() (or a plain dict of numbers).
        self._providers: "dict[str, list]" = {}

    # ----------------------------------------------------------- primitives
    def counter(self, name: str, labels: "dict | None" = None) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            return self._counters.setdefault(key, Counter())

    def gauge(self, name: str, labels: "dict | None" = None) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            return self._gauges.setdefault(key, Gauge())

    def histogram(
        self, name: str, buckets: "list[float]", labels: "dict | None" = None
    ) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram(buckets)
            return h

    # ------------------------------------------------------------ providers
    def register_stats(
        self, name: str, provider, labels: "dict | None" = None
    ) -> None:
        """Absorb a stats object: ``provider()`` is called at collect time;
        every numeric field of its ``to_dict()`` (or of the dict itself)
        becomes a ``{name}_{field}`` sample under ``labels``. Re-registering
        the same ``(name, labels)`` replaces the provider (idempotent), so
        dynamic populations — transport sessions opening per job — can
        re-register on every scrape."""
        key = _label_key(labels)
        with self._lock:
            entries = self._providers.setdefault(name, [])
            entries[:] = [e for e in entries if e[0] != key]
            entries.append((key, provider))

    def unregister(self, name: str, labels: "dict | None" = None) -> None:
        key = _label_key(labels)
        with self._lock:
            entries = self._providers.get(name, [])
            entries[:] = [e for e in entries if e[0] != key]
            if not entries:
                self._providers.pop(name, None)

    def _provider_samples(self):
        with self._lock:
            providers = [
                (name, labels, fn)
                for name, entries in self._providers.items()
                for labels, fn in entries
            ]
        for name, labels, fn in providers:
            obj = fn()
            if obj is None:
                continue
            d = obj if isinstance(obj, dict) else obj.to_dict()
            for field, v in d.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                yield f"{name}_{field}", labels, v

    # --------------------------------------------------------------- export
    def collect(self) -> "dict[str, float]":
        """One flat snapshot: ``name{labels}`` -> value. This is the
        transport ``metrics`` RPC payload and the benchmark-record shape."""
        out: "dict[str, float]" = {}
        with self._lock:
            prims = (
                [(n, ls, c.value) for (n, ls), c in self._counters.items()]
                + [(n, ls, g.value) for (n, ls), g in self._gauges.items()]
            )
            hists = list(self._hists.items())
        for name, labels, value in prims:
            out[name + _label_str(labels)] = value
        for (name, labels), h in hists:
            ls = _label_str(labels)
            out[f"{name}_count{ls}"] = h.count
            out[f"{name}_sum{ls}"] = h.sum
        for name, labels, value in self._provider_samples():
            out[name + _label_str(labels)] = value
        return out

    def exposition(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        lines: "list[str]" = []
        seen_type: "set[str]" = set()

        def typed(name: str, kind: str) -> None:
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {kind}")

        with self._lock:
            counters = sorted(
                (n, ls, c.value) for (n, ls), c in self._counters.items()
            )
            gauges = sorted(
                (n, ls, g.value) for (n, ls), g in self._gauges.items()
            )
            hists = sorted(self._hists.items())
        for name, labels, value in counters:
            typed(name, "counter")
            lines.append(f"{name}{_label_str(labels)} {_fmt(value)}")
        for name, labels, value in gauges:
            typed(name, "gauge")
            lines.append(f"{name}{_label_str(labels)} {_fmt(value)}")
        for (name, labels), h in hists:
            typed(name, "histogram")
            base = dict(labels)
            for bound, acc in h.cumulative():
                ls = _label_key({**base, "le": _fmt(bound)})
                lines.append(f"{name}_bucket{_label_str(ls)} {acc}")
            ls = _label_key({**base, "le": "+Inf"})
            lines.append(f"{name}_bucket{_label_str(ls)} {h.count}")
            lines.append(f"{name}_sum{_label_str(labels)} {_fmt(h.sum)}")
            lines.append(f"{name}_count{_label_str(labels)} {h.count}")
        for name, labels, value in sorted(self._provider_samples()):
            typed(name, "gauge")  # absorbed stats: point-in-time snapshots
            lines.append(f"{name}{_label_str(labels)} {_fmt(value)}")
        return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)
