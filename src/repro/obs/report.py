"""Epoch-time attribution: fold a trace into a per-stage wall-time budget.

A trace answers "what happened when"; training work overlaps across
threads (protocol walk ∥ chunk reads ∥ decode ∥ staging ∥ compute), so
naive per-category sums double-count and exceed wall time. This module
produces two views:

* ``busy_s[stage]`` — the *union* of that stage's span intervals (how much
  wall time the stage was active somewhere, overlap within the stage
  collapsed);
* ``exclusive_s[stage]`` — a sweep-line decomposition of the timeline:
  every instant is attributed to exactly ONE stage (the highest-priority
  stage active at that instant), so ``sum(exclusive_s) + idle_s == wall_s``
  *by construction* — the overlap-aware identity the acceptance test pins.

The priority order encodes the pipeline: ``compute`` wins (overlapped I/O
is hidden — it costs nothing, exactly the §6 ``max(compute, io)`` model),
then the consumer-visible waits (``stage``, ``ring``), then host work
(``decode``), then producer-side I/O (``read``), then bookkeeping
(``plan``, ``proto``, ``service``). ``plan`` outranks ``proto`` because a
planner span *encloses* its shadow protocol walk — planning time should
read as planning, while a live walk (no plan span active) still lands on
``proto``; ``service`` ranks last for the same reason (the pump span
encloses everything a pump round drives). Residual uncovered time is
``idle_s`` (scheduler gaps, uninstrumented work).

``model_columns`` prints the measured stages against the DESIGN §6
:class:`~repro.core.stats.PipelineTimeModel` prediction computed from the
same run's :class:`~repro.core.stats.StepIO` counters — the
measured-vs-model view the predictive-autotuning roadmap item consumes.
"""

from __future__ import annotations

__all__ = [
    "STAGES",
    "attribution",
    "format_report",
    "model_columns",
]

#: Attribution priority, highest first. Event categories not listed fold
#: into ``other``.
STAGES = (
    "compute",   # train_step on the consumer thread
    "stage",     # host->device staging + consumer wait on staged batches
    "ring",      # shared-memory ring write/read (incl. consumer poll wait)
    "decode",    # record decode + grid/pack assembly
    "read",      # storage chunk reads + residency claims
    "plan",      # clairvoyant epoch planning (encloses its shadow walk)
    "proto",     # protocol step walk (redirection bookkeeping)
    "service",   # multi-job pump rounds (enclose the work they drive)
)


def _intervals_by_stage(events) -> "dict[str, list[tuple[float, float]]]":
    by: "dict[str, list[tuple[float, float]]]" = {}
    for name, cat, ts, dur, tid, args in events:
        if dur < 0:
            continue  # instant events carry no duration
        stage = cat if cat in STAGES else "other"
        by.setdefault(stage, []).append((ts, ts + dur))
    return by


def _union_seconds(intervals: "list[tuple[float, float]]") -> float:
    total, cur_lo, cur_hi = 0.0, None, None
    for lo, hi in sorted(intervals):
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                total += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        total += cur_hi - cur_lo
    return total


def attribution(events, *, wall_s: "float | None" = None) -> dict:
    """Fold trace events into the per-stage breakdown.

    ``events`` are :meth:`repro.obs.Tracer.events` tuples. ``wall_s``
    overrides the epoch wall time (defaults to the trace extent — pass the
    measured wall when the trace covers only part of the run).
    """
    by = _intervals_by_stage(events)
    all_iv = [iv for ivs in by.values() for iv in ivs]
    if not all_iv:
        return {
            "wall_s": float(wall_s or 0.0), "busy_s": {}, "exclusive_s": {},
            "idle_s": float(wall_s or 0.0), "spans": 0,
        }
    t_lo = min(lo for lo, _ in all_iv)
    t_hi = max(hi for _, hi in all_iv)
    wall = float(wall_s) if wall_s is not None else t_hi - t_lo

    busy = {stage: _union_seconds(ivs) for stage, ivs in by.items()}

    # Sweep-line exclusive decomposition: at each elementary interval the
    # highest-priority active stage claims the time.
    order = {s: i for i, s in enumerate(STAGES)}
    order["other"] = len(STAGES)
    points: "list[tuple[float, int, int]]" = []  # (t, +1/-1, stage_rank)
    for stage, ivs in by.items():
        rank = order[stage]
        for lo, hi in ivs:
            points.append((lo, rank, 1))
            points.append((hi, rank, -1))
    points.sort()
    ranks = list(order)
    active = [0] * (len(STAGES) + 1)
    exclusive = dict.fromkeys(by, 0.0)
    prev_t = None
    for t, rank, delta in points:
        if prev_t is not None and t > prev_t:
            for r in range(len(active)):
                if active[r]:
                    exclusive[ranks[r]] = (
                        exclusive.get(ranks[r], 0.0) + t - prev_t
                    )
                    break
        active[rank] += delta
        prev_t = t
    covered = sum(exclusive.values())
    return {
        "wall_s": wall,
        "busy_s": busy,
        "exclusive_s": exclusive,
        "idle_s": max(0.0, wall - covered),
        "spans": len(all_iv),
    }


def model_columns(per_node_step_io, model, compute_per_step: float = 0.0) -> dict:
    """DESIGN §6 prediction from the run's own StepIO counters.

    ``per_node_step_io`` is the ``list[list[StepIO]]`` grid an
    :class:`~repro.core.EpochResult` carries (or the launcher accumulates
    from ``batch["io_by_node"]``). Returns per-component predicted seconds
    plus the pipelined epoch-time bound, keyed to line up with the
    measured stages."""
    chunk_s = bytes_s = net_s = 0.0
    for steps in per_node_step_io:
        for io in steps:
            chunk_s += (
                io.file_reads * model.file_overhead
                + io.chunk_loads * model.chunk_overhead
            )
            bytes_s += io.disk_bytes / model.disk_bw
            net_s += (
                io.net_messages * model.net_latency + io.net_bytes / model.net_bw
            )
    return {
        "read": chunk_s + bytes_s,
        "net": net_s,
        "compute": compute_per_step * max(
            (len(s) for s in per_node_step_io), default=0
        ),
        "epoch": model.epoch_time(per_node_step_io, compute_per_step),
    }


def format_report(
    att: dict, *, model: "dict | None" = None, measured_wall_s: "float | None" = None
) -> str:
    """Render the attribution (and optional model columns) as a table."""
    wall = measured_wall_s if measured_wall_s is not None else att["wall_s"]
    lines = [
        f"epoch wall time: {wall:.3f}s "
        f"(trace extent {att['wall_s']:.3f}s, {att['spans']} spans)",
        f"{'stage':<10} {'busy_s':>9} {'excl_s':>9} {'excl_%':>7}"
        + ("  model_s" if model else ""),
    ]
    stages = [s for s in (*STAGES, "other") if s in att["busy_s"]]
    for stage in stages:
        excl = att["exclusive_s"].get(stage, 0.0)
        row = (
            f"{stage:<10} {att['busy_s'][stage]:>9.3f} {excl:>9.3f} "
            f"{100.0 * excl / wall if wall else 0.0:>6.1f}%"
        )
        if model and stage in model:
            row += f"  {model[stage]:>7.3f}"
        lines.append(row)
    idle = att["idle_s"]
    lines.append(
        f"{'idle':<10} {'':>9} {idle:>9.3f} "
        f"{100.0 * idle / wall if wall else 0.0:>6.1f}%"
    )
    covered = sum(att["exclusive_s"].values()) + idle
    lines.append(
        f"attributed (exclusive + idle): {covered:.3f}s "
        f"of {att['wall_s']:.3f}s trace extent"
    )
    if model and "epoch" in model:
        lines.append(f"DESIGN §6 pipelined epoch-time bound: {model['epoch']:.3f}s")
    return "\n".join(lines)
