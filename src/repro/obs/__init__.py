"""Observability plane: span tracing, metrics, epoch-time attribution.

Three pieces (see DESIGN.md §13):

* :mod:`repro.obs.tracer` — process-wide span tracer with Chrome-trace/
  Perfetto export; instrumentation sites use ``trace.span(...)`` /
  ``trace.instant(...)`` and cost a ``None`` check when tracing is off.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of counters /
  gauges / histograms that also absorbs the repo's stats dataclasses via
  ``register_stats``; one ``collect()`` snapshot, Prometheus text
  ``exposition()``, served live over the transport ``metrics`` RPC.
* :mod:`repro.obs.report` — fold a trace into an overlap-aware per-stage
  wall-time breakdown and compare against the DESIGN §6 time model.
"""

from repro.obs import tracer as trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.report import STAGES, attribution, format_report, model_columns
from repro.obs.tracer import Tracer, tracing

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "STAGES",
    "Tracer",
    "attribution",
    "format_report",
    "model_columns",
    "trace",
    "tracing",
]
