"""Low-overhead span tracer with Chrome-trace/Perfetto JSON export.

One process-wide :class:`Tracer` (installed with :func:`enable`, removed
with :func:`disable`) collects ``(name, cat, ts, dur, tid, args)`` events
into a bounded thread-safe ring. Instrumentation sites call the module
API::

    from repro.obs import trace

    with trace.span("storage.read_chunk", "read", chunk=k):
        ...                      # timed while a tracer is installed
    trace.instant("residency.evict", "read", chunk=k)

and pay only a module-attribute load + ``None`` check when tracing is off
— the disabled path allocates nothing and takes no locks, which is what
keeps the instrumented hot loops (protocol step, ring write, staging)
inside the <5% overhead budget pinned by ``tests/test_obs.py``.

Design notes:

* the ring is a ``collections.deque(maxlen=capacity)`` — appends are
  atomic under the GIL, so producer threads never contend on a lock;
  overflow silently drops the *oldest* events (``dropped`` counts them),
  which is the right bias for "dump the trace at the end of the run".
* timestamps are ``perf_counter`` seconds relative to the tracer's epoch;
  export converts to the microseconds Chrome's ``chrome://tracing`` and
  Perfetto's trace processor expect (``ph: "X"`` complete events).
* spans nest naturally: each ``with`` records one complete event at exit,
  and the viewer reconstructs the stack per thread from containment.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path

__all__ = [
    "Tracer",
    "disable",
    "enable",
    "get",
    "instant",
    "span",
    "tracing",
]


class _NullSpan:
    """Shared, reentrant no-op context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a complete event when the ``with`` exits."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer.complete(
            self.name, self.cat, self._t0, t1 - self._t0, self.args
        )
        return False


class Tracer:
    """Thread-safe bounded ring of trace events."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._recorded = 0
        self._epoch = time.perf_counter()
        self._tid_lock = threading.Lock()
        self._tids: "dict[int, int]" = {}
        self._tid_names: "dict[int, str]" = {}

    # ------------------------------------------------------------ recording
    def _tid(self) -> int:
        """Small stable id for the calling thread (Chrome tid field)."""
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._tid_lock:
                tid = self._tids.setdefault(ident, len(self._tids))
                self._tid_names.setdefault(
                    tid, threading.current_thread().name
                )
        return tid

    def span(self, name: str, cat: str = "", **args) -> _Span:
        return _Span(self, name, cat, args or None)

    def complete(
        self, name: str, cat: str, t0: float, dur: float, args=None
    ) -> None:
        """Record a finished span: ``t0`` is absolute ``perf_counter``."""
        self._events.append(
            (name, cat, t0 - self._epoch, dur, self._tid(), args)
        )
        self._recorded += 1

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Record a zero-duration point event."""
        self.complete(name, cat, time.perf_counter(), -1.0, args or None)

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        """Events lost to ring overflow (oldest-first)."""
        return self._recorded - len(self._events)

    def events(self) -> "list[tuple]":
        """Snapshot of the ring: ``(name, cat, ts_s, dur_s, tid, args)``
        tuples (``dur_s < 0`` marks an instant event)."""
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self._recorded = 0

    # -------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """The Chrome Trace Event JSON object (Perfetto-loadable)."""
        trace_events = []
        for tid, tname in sorted(self._tid_names.items()):
            trace_events.append({
                "ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                "args": {"name": tname},
            })
        for name, cat, ts, dur, tid, args in self._events:
            ev = {
                "name": name,
                "cat": cat or "default",
                "pid": 0,
                "tid": tid,
                "ts": round(ts * 1e6, 3),
            }
            if dur < 0:
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = round(dur * 1e6, 3)
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            trace_events.append(ev)
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def dump(self, path: "str | Path") -> Path:
        """Write the Chrome-trace JSON to ``path`` (open in Perfetto UI or
        ``chrome://tracing``)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome()))
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)  # numpy scalars
    except (TypeError, ValueError):
        return str(v)


# ------------------------------------------------------------- module state
_active: "Tracer | None" = None


def enable(capacity: int = 65536) -> Tracer:
    """Install (and return) the process-wide tracer. Idempotent-ish: a
    second ``enable`` replaces the tracer (the old one keeps its events)."""
    global _active
    _active = Tracer(capacity=capacity)
    return _active


def disable() -> "Tracer | None":
    """Remove the process-wide tracer; returns it (events intact)."""
    global _active
    t, _active = _active, None
    return t


def get() -> "Tracer | None":
    """The installed tracer, or None when tracing is off."""
    return _active


def span(name: str, cat: str = "", **args):
    """Module-level span: a real span when tracing is on, a shared no-op
    context manager otherwise (the hot-path fast exit)."""
    t = _active
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, cat, args or None)


def instant(name: str, cat: str = "", **args) -> None:
    t = _active
    if t is not None:
        t.instant(name, cat, **args)


class tracing:
    """``with tracing() as t:`` — enable for a scope, restore on exit."""

    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self.tracer: "Tracer | None" = None

    def __enter__(self) -> Tracer:
        global _active
        self._prev = _active
        self.tracer = Tracer(capacity=self.capacity)
        _active = self.tracer
        return self.tracer

    def __exit__(self, *exc):
        global _active
        _active = self._prev
        return False
