"""Model-fitted autotuning: calibrate storage, pick the data-plane config.

"Predictive Modeling of I/O Performance" (PAPERS.md) applied to this
repo's own §6 pipeline model (DESIGN.md §6/§14): a short **calibration
run** measures, per storage backend, what the model needs —

* **read bandwidth + chunk latency** — each sampled chunk read is timed;
  a least-squares fit of time against bytes gives ``1/bandwidth`` (slope)
  and the per-read ``chunk_overhead`` (intercept);
* **file overhead** — timed per-file ranged reads minus their bandwidth
  cost (the small-file penalty batching amortises);
* **decode rate** — bytes/s of turning raw chunk records into arrays
  (the host-side cost the loader overlaps).

:func:`fit_time_model` folds a profile into a
:class:`~repro.core.stats.PipelineTimeModel`; :func:`select_config` then
*predicts* the epoch time of every candidate ``(backend, readahead)``
pair against a per-step I/O demand profile and returns the argmin as a
:class:`TuneChoice` — including the cache byte cap
(:func:`required_cache_bytes`: the exact residency peak of a claim
schedule under release-on-last-claim caching, i.e. the smallest cap that
never forces an eviction) and, on progressive stores, the fidelity
prefix to read (:func:`select_fidelity`: full fidelity when the model
predicts compute-bound, a truncated band prefix when I/O-bound). Both
launchers expose this as ``--autotune``;
the measured storage bandwidth also feeds the service's admission control
(``repro.service.AdmissionControl``).

Synchronous backends are scored with the strict (no-overlap) epoch bound;
async backends interpolate between strict and pipelined by how much of the
per-step load burst their readahead depth covers — deeper readahead only
helps until it covers the burst, which is what makes the depth choice
well-posed.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from pathlib import Path

import numpy as np

from .core.stats import PipelineTimeModel, StepIO
from .core.storage import BACKENDS, ChunkStore

__all__ = [
    "BackendProfile",
    "Calibration",
    "TuneChoice",
    "calibrate",
    "fit_time_model",
    "plan_step_io",
    "required_cache_bytes",
    "select_config",
    "select_fidelity",
    "tune_store",
    "uniform_step_io",
]

#: Nominal network profile used when the deployment's fabric is not
#: measured (single-box runs never touch it: net terms are zero).
DEFAULT_NET_BW = 1e9
DEFAULT_NET_LATENCY = 2e-4


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """One backend's fitted read-cost parameters (times in seconds)."""

    backend: str
    bandwidth_bytes_per_s: float
    chunk_overhead_s: float
    file_overhead_s: float
    samples: int

    def read_time(self, nbytes: int) -> float:
        return self.chunk_overhead_s + nbytes / self.bandwidth_bytes_per_s


@dataclasses.dataclass
class Calibration:
    """Everything a calibration run measured, JSON round-trippable."""

    backends: "dict[str, BackendProfile]"
    decode_bytes_per_s: float
    chunk_bytes_mean: float

    def to_dict(self) -> dict:
        return {
            "backends": {
                name: dataclasses.asdict(p) for name, p in self.backends.items()
            },
            "decode_bytes_per_s": self.decode_bytes_per_s,
            "chunk_bytes_mean": self.chunk_bytes_mean,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        return cls(
            backends={
                name: BackendProfile(**p) for name, p in d["backends"].items()
            },
            decode_bytes_per_s=d["decode_bytes_per_s"],
            chunk_bytes_mean=d["chunk_bytes_mean"],
        )

    def save(self, path: "str | Path") -> Path:
        path = Path(path)
        path.write_text(json.dumps(self.to_dict(), indent=2))
        return path

    @classmethod
    def load(cls, path: "str | Path") -> "Calibration":
        return cls.from_dict(json.loads(Path(path).read_text()))


@dataclasses.dataclass(frozen=True)
class TuneChoice:
    """The autotuner's selected data-plane configuration."""

    backend: str
    readahead: int                      # 0: backend has no readahead
    cache_limit_bytes: "int | None"
    predicted_epoch_s: float
    model: PipelineTimeModel            # the fitted §6 model it was scored with
    #: Fidelity bands to read from a progressive store (None: store is
    #: flat, or full fidelity — see :func:`select_fidelity`).
    fidelity: "int | None" = None

    def describe(self) -> str:
        cap = (
            "uncapped" if self.cache_limit_bytes is None
            else f"{self.cache_limit_bytes / 1e6:.1f} MB cap"
        )
        ra = f", readahead {self.readahead}" if self.readahead else ""
        fid = f", fidelity {self.fidelity}" if self.fidelity is not None else ""
        return (
            f"backend={self.backend}{ra}, cache {cap}{fid}, "
            f"predicted epoch {self.predicted_epoch_s:.3f}s "
            f"(disk {self.model.disk_bw / 1e6:.0f} MB/s, "
            f"chunk {self.model.chunk_overhead * 1e3:.2f} ms)"
        )


def _fit_linear(xs: np.ndarray, ts: np.ndarray) -> "tuple[float, float]":
    """(bandwidth, overhead) from per-read (bytes, seconds) samples.

    Degenerate inputs (near-uniform chunk sizes make the slope
    unidentifiable) fall back to the aggregate ratio with zero overhead —
    still the right *ranking* signal between backends.
    """
    total_bw = float(xs.sum() / max(ts.sum(), 1e-12))
    if len(xs) >= 2 and float(xs.std()) > 0.01 * float(xs.mean()):
        slope, intercept = np.polyfit(xs.astype(float), ts.astype(float), 1)
        if slope > 0:
            return min(1.0 / slope, 1e12), max(float(intercept), 0.0)
    return total_bw, 0.0


def calibrate(
    root: "str | Path",
    *,
    backends: "list[str] | None" = None,
    sample_chunks: int = 24,
    sample_files: int = 16,
    repeats: int = 2,
    seed: int = 0,
) -> Calibration:
    """Measure every candidate backend on the chunk store at ``root``.

    Reads a spread sample of chunks ``repeats`` times (per-read minimum is
    kept — the page cache makes the *minimum* the repeatable signal) and
    fits each backend's :class:`BackendProfile`. One short pass per
    backend: a few dozen reads, well under a second on local storage.
    """
    root = Path(root)
    names = list(backends) if backends is not None else sorted(BACKENDS)
    rng = np.random.default_rng(seed)
    profiles: "dict[str, BackendProfile]" = {}
    decode_rate, chunk_bytes_mean = 0.0, 0.0
    for name in names:
        store = ChunkStore.open(root, backend=name)
        try:
            plan = store.plan
            n = int(plan.num_chunks)
            ids = sorted(rng.choice(n, size=min(sample_chunks, n), replace=False))
            sizes = np.asarray([int(plan.chunk_bytes[k]) for k in ids], float)
            best = np.full(len(ids), np.inf)
            decoded_bytes, decode_s = 0, 0.0
            for _ in range(max(repeats, 1)):
                for j, k in enumerate(ids):
                    t0 = time.perf_counter()
                    records = store.read_chunk(int(k))
                    best[j] = min(best[j], time.perf_counter() - t0)
                    t1 = time.perf_counter()
                    for _fid, blob in records:
                        decoded_bytes += np.frombuffer(blob, np.uint8).size
                    decode_s += time.perf_counter() - t1
            bw, chunk_ovh = _fit_linear(sizes, best)
            # file_overhead: timed ranged per-file reads minus bandwidth cost
            fids = rng.choice(
                int(plan.num_files), size=min(sample_files, int(plan.num_files)),
                replace=False,
            )
            fbytes, ft = 0, 0.0
            for f in fids:
                t0 = time.perf_counter()
                fbytes += len(store.read_file(int(f)))
                ft += time.perf_counter() - t0
            file_ovh = max(ft - fbytes / bw, 0.0) / max(len(fids), 1)
            profiles[name] = BackendProfile(
                backend=name,
                bandwidth_bytes_per_s=bw,
                chunk_overhead_s=chunk_ovh,
                file_overhead_s=file_ovh,
                samples=len(ids),
            )
            decode_rate = max(
                decode_rate, decoded_bytes / decode_s if decode_s > 0 else 0.0
            )
            chunk_bytes_mean = float(np.asarray(plan.chunk_bytes).mean())
        finally:
            store.close()
    return Calibration(
        backends=profiles,
        decode_bytes_per_s=decode_rate,
        chunk_bytes_mean=chunk_bytes_mean,
    )


def fit_time_model(
    calib: Calibration,
    backend: str,
    *,
    net_bw: float = DEFAULT_NET_BW,
    net_latency: float = DEFAULT_NET_LATENCY,
) -> PipelineTimeModel:
    """A §6 :class:`PipelineTimeModel` from one backend's measured profile."""
    p = calib.backends[backend]
    return PipelineTimeModel(
        disk_bw=p.bandwidth_bytes_per_s,
        file_overhead=p.file_overhead_s,
        chunk_overhead=p.chunk_overhead_s,
        net_bw=net_bw,
        net_latency=net_latency,
    )


def required_cache_bytes(claims: "list[int]", chunk_bytes) -> int:
    """Exact residency peak of a claim schedule under first-to-last-claim
    caching — the smallest ``cache_limit_bytes`` that never evicts.

    Under release-on-last-claim refcounts (``SharedResidency`` with plans
    installed) a chunk occupies cache exactly over the interval from its
    first claim to its last; the peak of the interval-overlap byte count is
    therefore both achievable (Belady never evicts below it) and minimal
    (at the peak instant every resident byte has a future claim).
    """
    chunk_bytes = np.asarray(chunk_bytes)
    first: "dict[int, int]" = {}
    last: "dict[int, int]" = {}
    for i, k in enumerate(claims):
        k = int(k)
        first.setdefault(k, i)
        last[k] = i
    cur = peak = 0
    for i, k in enumerate(claims):
        k = int(k)
        if first[k] == i:
            cur += int(chunk_bytes[k])
            peak = max(peak, cur)
        if last[k] == i:
            cur -= int(chunk_bytes[k])
    return peak


def plan_step_io(plan, chunk_bytes) -> "list[StepIO]":
    """Per-step I/O demand of one :class:`EpochPlan` (tail step included)."""
    chunk_bytes = np.asarray(chunk_bytes)
    steps = []
    depth = plan.num_steps + (1 if plan.has_tail else 0)
    for s in range(depth):
        lo, hi = plan.load_range(s)
        ks = plan.load_chunk[lo:hi]
        steps.append(StepIO(
            chunk_loads=int(len(ks)),
            disk_bytes=int(chunk_bytes[ks].sum()) if len(ks) else 0,
        ))
    return steps


def uniform_step_io(
    total_bytes: int, num_chunks: int, num_steps: int
) -> "list[StepIO]":
    """Plan-free demand profile: the dataset read exactly once (the Redox
    invariant), spread evenly over ``num_steps`` — what a launcher can
    predict before any session is opened."""
    num_steps = max(int(num_steps), 1)
    per_bytes = int(total_bytes) // num_steps
    loads = max(num_chunks // num_steps, 1)
    return [
        StepIO(chunk_loads=loads, disk_bytes=per_bytes)
        for _ in range(num_steps)
    ]


def select_fidelity(
    model: PipelineTimeModel,
    step_io: "list[StepIO]",
    compute_per_step_s: float,
    bands: int,
) -> int:
    """How many fidelity bands of a progressive store to read (§6 model).

    Paper §6 applied to progressive records (PAPERS.md, "Progressive
    Compressed Records"): when the model predicts the job is
    *compute-bound* (per-epoch I/O time fits under the compute time)
    truncation buys nothing — return ``bands`` (full fidelity). When it
    predicts *I/O-bound*, pick the largest prefix whose proportionally
    shrunk read time fits the compute budget: I/O time scales ~linearly
    with the byte prefix, so ``fidelity ≈ bands * compute/io``, floored
    at one band so the epoch stream stays well-formed.
    """
    bands = max(int(bands), 1)
    if bands == 1:
        return 1
    io = model.epoch_time_strict([list(step_io)], 0.0)
    compute = compute_per_step_s * len(step_io)
    if io <= compute or io <= 0:
        return bands
    return max(1, min(bands, math.ceil(bands * compute / io)))


def select_config(
    calib: Calibration,
    step_io: "list[StepIO]",
    *,
    compute_per_step_s: float = 0.0,
    backends: "list[str] | None" = None,
    readahead_grid: "tuple[int, ...]" = (2, 4, 8, 16),
    claims: "list[int] | None" = None,
    chunk_bytes=None,
    memory_limit_bytes: "int | None" = None,
    bands: int = 1,
    net_bw: float = DEFAULT_NET_BW,
    net_latency: float = DEFAULT_NET_LATENCY,
) -> TuneChoice:
    """Predict every candidate config's epoch time; return the argmin.

    Synchronous backends are scored ``epoch_time_strict`` (every read
    blocks the step). Async backends overlap reads with compute, but only
    as far as their readahead depth covers the per-step load burst:
    coverage ``f = min(1, depth / max_step_loads)`` interpolates between
    the strict and pipelined bounds. Ties prefer the shallower depth
    (less readahead memory).

    The cache cap is :func:`required_cache_bytes` of ``claims`` when a
    claim schedule is known (clamped to ``memory_limit_bytes``), else
    ``memory_limit_bytes`` as given.

    With ``bands > 1`` (a progressive store) the winning choice also
    carries a :func:`select_fidelity` decision against its own fitted
    model — full fidelity when compute-bound, a truncated prefix when
    I/O-bound.
    """
    if not step_io:
        raise ValueError("select_config needs a non-empty per-step demand")
    names = list(backends) if backends is not None else sorted(calib.backends)
    grid = list(step_io)
    burst = max(s.chunk_loads for s in grid) or 1
    best: "TuneChoice | None" = None
    for name in names:
        model = fit_time_model(
            calib, name, net_bw=net_bw, net_latency=net_latency
        )
        strict = model.epoch_time_strict([grid], compute_per_step_s)
        pipelined = model.epoch_time([grid], compute_per_step_s)
        is_async = getattr(BACKENDS[name], "wants_prefetch", False)
        depths = tuple(readahead_grid) if is_async else (0,)
        for depth in depths:
            f = min(1.0, depth / burst) if is_async else 0.0
            predicted = strict - f * (strict - pipelined)
            if best is None or predicted < best.predicted_epoch_s - 1e-12:
                cap = None
                if claims is not None and chunk_bytes is not None:
                    cap = required_cache_bytes(claims, chunk_bytes)
                    if memory_limit_bytes is not None:
                        cap = min(cap, memory_limit_bytes)
                elif memory_limit_bytes is not None:
                    cap = memory_limit_bytes
                best = TuneChoice(
                    backend=name, readahead=depth, cache_limit_bytes=cap,
                    predicted_epoch_s=predicted, model=model,
                )
    if bands > 1 and best is not None:
        best = dataclasses.replace(
            best,
            fidelity=select_fidelity(
                best.model, grid, compute_per_step_s, bands
            ),
        )
    return best


def tune_store(
    root: "str | Path",
    *,
    compute_per_step_s: float = 0.0,
    memory_limit_bytes: "int | None" = None,
    num_steps: "int | None" = None,
    backends: "list[str] | None" = None,
    readahead_grid: "tuple[int, ...]" = (2, 4, 8, 16),
) -> "tuple[Calibration, TuneChoice]":
    """Calibrate the store at ``root`` and select a config against the
    plan-free uniform demand profile (the launcher entry point — both
    ``--autotune`` flags route through here)."""
    calib = calibrate(root, backends=backends)
    probe = ChunkStore.open(root)
    try:
        plan, bands = probe.plan, probe.spec.bands
    finally:
        probe.close()
    total = int(np.asarray(plan.chunk_bytes).sum())
    steps = int(num_steps) if num_steps else int(plan.num_chunks)
    choice = select_config(
        calib,
        uniform_step_io(total, int(plan.num_chunks), steps),
        compute_per_step_s=compute_per_step_s,
        backends=backends,
        readahead_grid=readahead_grid,
        memory_limit_bytes=memory_limit_bytes,
        bands=bands,
    )
    return calib, choice


def main(argv=None) -> int:
    """``python -m repro.autotune ROOT`` — calibrate a store and print the
    fitted profiles plus the selected configuration."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("root", help="chunk store directory (plan.npz inside)")
    ap.add_argument("--compute-per-step", type=float, default=0.0,
                    help="seconds of compute per training step (0: I/O bound)")
    ap.add_argument("--steps", type=int, default=0,
                    help="steps per epoch for the demand profile "
                         "(default: one chunk load per step)")
    ap.add_argument("--memory-mb", type=float, default=None,
                    help="cache budget ceiling in MB")
    ap.add_argument("--save", default=None, metavar="JSON",
                    help="write the calibration to this file")
    args = ap.parse_args(argv)
    calib, choice = tune_store(
        args.root,
        compute_per_step_s=args.compute_per_step,
        num_steps=args.steps or None,
        memory_limit_bytes=(
            int(args.memory_mb * 1e6) if args.memory_mb is not None else None
        ),
    )
    for name in sorted(calib.backends):
        p = calib.backends[name]
        print(f"{name:9s} bw {p.bandwidth_bytes_per_s / 1e6:9.1f} MB/s  "
              f"chunk {p.chunk_overhead_s * 1e3:6.3f} ms  "
              f"file {p.file_overhead_s * 1e3:6.3f} ms  ({p.samples} samples)")
    print(f"decode    {calib.decode_bytes_per_s / 1e6:9.1f} MB/s")
    print("selected:", choice.describe())
    if args.save:
        print("calibration ->", calib.save(args.save))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
