"""Checkpointing: sharded save/restore with elastic resharding + async writes.

Format: one ``.npz`` per top-level state entry holding flattened leaves
(keyed by tree path) + a JSON manifest (step, treedef structure hash,
mesh shape at save time). Restore accepts a *different* mesh/sharding than
the save-time one — leaves are loaded host-side and re-placed with the
target sharding — which is what makes elastic rescale (e.g. resume a
512-chip job on 256 chips) a pure restore-time concern.

Async mode snapshots device arrays to host (`jax.device_get`) then writes
on a worker thread, so the train loop resumes immediately — the standard
overlap trick; `wait()` joins before the next save or at shutdown.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "AsyncCheckpointer", "latest_step"]

_SEP = "/"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(ckpt_dir: str | Path, step: int, state) -> Path:
    ckpt_dir = Path(ckpt_dir)
    path = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    try:
        arrays = _flatten_with_paths(state)
        np.savez(tmp / "state.npz", **arrays)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "num_leaves": len(arrays),
            "keys": sorted(arrays),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # atomic publish: a checkpoint is visible only when complete
        if path.exists():
            raise FileExistsError(path)
        tmp.rename(path)
    except BaseException:
        # An abandoned save must not leave a half-written .tmp_step_* behind
        # (latest_step ignores them, but gc would trip over the stray files).
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int, state_like, shardings=None):
    """Restore into the structure of ``state_like``; optional target shardings
    (a matching pytree of NamedSharding) enable elastic re-placement."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    with np.load(path / "state.npz") as z:
        arrays = {k: z[k] for k in z.files}

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(state_like)[0]
    treedef = jax.tree_util.tree_structure(state_like)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, (pth, like) in enumerate(leaves_with_paths):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = arrays[key]
        assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
        if shard_leaves is not None:
            out.append(jax.device_put(arr.astype(like.dtype), shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a worker thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.ckpt_dir.iterdir()
            if p.name.startswith("step_")
        )
        for s in steps[: -self.keep]:
            target = self.ckpt_dir / f"step_{s:08d}"
            for f in target.iterdir():
                f.unlink()
            target.rmdir()
