"""GQA attention: dense, chunked (online-softmax), and decode paths.

Layouts: activations (B, S, d_model); q (B, S, H, D); k/v (B, S, KVH, D).

Sharding: by default heads shard over the "model"/tp mesh axis
(``shard(q, "batch", None, "heads", None)``). Architectures whose head
count does not divide the TP degree (phi3: 40, llava: 56) set
``attn_shard="seq"`` — queries shard over the *sequence* dim instead and
K/V are gathered, a context-parallel fallback that keeps compute balanced
at the price of an all-gather (visible in the roofline collective term).

The chunked path is the pure-jnp oracle for ``kernels/flash_attention``;
the Pallas kernel replaces it on real TPUs (config ``use_pallas``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.axes import shard
from .common import Param, apply_rope, make_rope, scaled_init

__all__ = ["init_attention", "attention_block", "decode_attention_block"]

NEG_INF = -1e30


def _qkv_axes(cfg):
    if cfg.attn_shard == "seq":
        # heads not divisible by tp: shard sequence instead
        return ("batch", "seq_tp", "heads_r", None)
    return ("batch", None, "heads", None)


def init_attention(rng, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim_
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": Param(scaled_init(rng.next(), (d, h * hd), dtype), ("embed", "heads_flat")),
        "wk": Param(scaled_init(rng.next(), (d, kvh * hd), dtype), ("embed", "kv_flat")),
        "wv": Param(scaled_init(rng.next(), (d, kvh * hd), dtype), ("embed", "kv_flat")),
        "wo": Param(scaled_init(rng.next(), (h * hd, d), dtype, fan_in=h * hd), ("heads_flat", "embed")),
    }


def _project_qkv(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dn->bsn", x, p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dn->bsn", x, p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _expand_kv(k, cfg):
    """(B,S,KVH,D) -> (B,S,H,D) by repeating each kv head over its group."""
    groups = cfg.num_heads // cfg.num_kv_heads
    return jnp.repeat(k, groups, axis=2)


def _dense_attention(q, k, v, cfg, q_offset=0):
    """Direct (S_q x S_kv) attention with causal/window masking. fp32 softmax."""
    scale = cfg.head_dim_ ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if cfg.causal:
        mask &= kpos <= qpos
    if cfg.window:
        mask &= kpos > qpos - cfg.window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _chunked_attention_vecq(q, k, v, cfg):
    """Online-softmax over KV chunks with ALL query blocks vectorised.

    Used for ``attn_shard="seq"`` (head count not divisible by TP): the q
    block axis stays a *batch* dimension sharded over the model axis, so
    every device processes only its own sequence shard — a scan over q
    blocks would instead make each shard recompute the full S² (observed as
    16x redundant FLOPs in the phi3/llava prefill dry-run before this path
    existed). Memory: one (b, nq_local, blk, h, blk) logits tile per step.
    """
    blk = min(cfg.attn_chunk, q.shape[1])
    b, s, h, d = q.shape
    assert s % blk == 0, (s, blk)
    nq = s // blk
    scale = d**-0.5
    qb = q.reshape(b, nq, blk, h, d)
    qb = shard(qb, "batch", "seq_tp", None, None, None)
    kb = k.reshape(b, nq, blk, h, d)
    vb = v.reshape(b, nq, blk, h, d)

    def kv_step(state, ki):
        m, l, acc = state
        kk = kb[:, ki]  # (b, blk, h, d)
        vv = vb[:, ki]
        logits = (
            jnp.einsum("bnqhd,bkhd->bnhqk", qb, kk).astype(jnp.float32) * scale
        )
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        qpos = (
            jnp.arange(nq)[:, None, None] * blk + jnp.arange(blk)[None, :, None]
        )  # (nq, blk, 1)
        kpos = (ki * blk + jnp.arange(blk))[None, None, :]
        mask = jnp.ones((nq, blk, blk), dtype=bool)
        if cfg.causal:
            mask = mask & (kpos <= qpos)
        if cfg.window:
            mask = mask & (kpos > qpos - cfg.window)
        mask = mask[None, :, None]  # (1, nq, 1, blk, blk)
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bnhqk,bkhd->bnhqd", p.astype(vv.dtype), vv
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nq, h, blk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, h, blk), jnp.float32)
    a0 = jnp.zeros((b, nq, h, blk, d), jnp.float32)
    m0, l0, a0 = (shard(t, "batch", "seq_tp", *([None] * (t.ndim - 2))) for t in (m0, l0, a0))
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nq))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 1, 3, 2, 4).reshape(b, s, h, d).astype(q.dtype)


def _chunked_attention(q, k, v, cfg):
    """Online-softmax over KV chunks, queries blocked — O(S·chunk) memory.

    This is the flash-attention recurrence in pure jnp (the ref oracle for
    the Pallas kernel). Causal masking is applied per chunk pair; the XLA
    path computes masked blocks too (see DESIGN.md roofline notes).
    """
    blk = min(cfg.attn_chunk, q.shape[1])
    b, s, h, d = q.shape
    assert s % blk == 0, (s, blk)
    nq = s // blk
    scale = d**-0.5

    qb = q.reshape(b, nq, blk, h, d)
    kb = k.reshape(b, nq, blk, h, d)
    vb = v.reshape(b, nq, blk, h, d)

    def q_block(carry, qi):
        del carry
        qi_q = qb[:, qi]  # (b, blk, h, d)

        def kv_step(state, ki):
            m, l, acc = state
            kk = kb[:, ki]
            vv = vb[:, ki]
            logits = (
                jnp.einsum("bqhd,bkhd->bhqk", qi_q, kk).astype(jnp.float32) * scale
            )
            if cfg.logit_softcap:
                logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
            qpos = qi * blk + jnp.arange(blk)[:, None]
            kpos = ki * blk + jnp.arange(blk)[None, :]
            mask = jnp.ones((blk, blk), dtype=bool)
            if cfg.causal:
                mask &= kpos <= qpos
            if cfg.window:
                mask &= kpos > qpos - cfg.window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vv.dtype), vv
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, blk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, blk), jnp.float32)
        a0 = jnp.zeros((b, h, blk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nq))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)  # (b, blk, h, d)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))  # (nq, b, blk, h, d)
    return blocks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def attention_block(p, x, cfg, *, positions=None):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    sin, cos = make_rope(positions, cfg.head_dim_, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    kv = (k, v)
    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    axes = _qkv_axes(cfg)
    q, k, v = shard(q, *axes), shard(k, *axes), shard(v, *axes)
    if s <= cfg.attn_dense_threshold:
        out = _dense_attention(q, k, v, cfg)
    elif cfg.attn_shard == "seq":
        out = _chunked_attention_vecq(q, k, v, cfg)
    else:
        out = _chunked_attention(q, k, v, cfg)
    out = shard(out, *axes)
    out = jnp.einsum(
        "bsn,nd->bsd", out.reshape(b, s, cfg.num_heads * cfg.head_dim_), p["wo"]
    )
    return out, kv


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 quantisation. t: (..., D)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def decode_attention_block(p, x, cache_k, cache_v, cache_pos, cfg,
                           k_scale=None, v_scale=None):
    """One-token decode against a (possibly rotating-window) KV cache.

    x: (B, 1, d); cache_k/v: (B, S_c, KVH, D); cache_pos: scalar int32.
    Slot ``j`` holds the KV of absolute position ``p_j = cache_pos -
    ((cache_pos - j) mod S_c)`` — when ``S_c > cache_pos`` (full cache) this
    reduces to ``p_j = j``; when ``S_c == window`` it is the rotating buffer
    that keeps zamba2's 500k decode at O(window) memory. Keys are stored
    RoPE'd at absolute positions, so rotation needs no re-rotation.

    With ``cfg.kv_cache_dtype == "int8"`` the cache is int8 with bf16
    per-(token, head) scales (k_scale/v_scale: (B, S_c, KVH, 1)): the
    decode memory term is KV-streaming-bound, so halving cache bytes halves
    it (§Perf decode lever).
    """
    b = x.shape[0]
    hd = cfg.head_dim_
    s_c = cache_k.shape[1]
    quant = cfg.kv_cache_dtype == "int8"
    q, k, v = _project_qkv(p, x, cfg)
    pos = jnp.full((b, 1), cache_pos, dtype=jnp.int32)
    sin, cos = make_rope(pos, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    write_idx = jnp.mod(cache_pos, s_c)
    upd = lambda c, t: jax.lax.dynamic_update_slice_in_dim(
        c, t.astype(c.dtype), write_idx, axis=1
    )
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache_k, k_scale = upd(cache_k, kq), upd(k_scale, ks)
        cache_v, v_scale = upd(cache_v, vq), upd(v_scale, vs)
        k_eff = dequantize_kv(cache_k, k_scale, x.dtype)
        v_eff = dequantize_kv(cache_v, v_scale, x.dtype)
    else:
        cache_k = upd(cache_k, k)
        cache_v = upd(cache_v, v)
        k_eff, v_eff = cache_k, cache_v

    groups = cfg.num_heads // cfg.num_kv_heads
    scale = hd**-0.5
    qg = q.reshape(b, 1, cfg.num_kv_heads, groups, hd)
    # (B, KVH, G, S)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_eff).astype(jnp.float32) * scale
    logits = logits[:, :, :, 0]
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    j = jnp.arange(s_c)[None, None, None, :]
    slot_pos = cache_pos - jnp.mod(cache_pos - j, s_c)  # absolute position held
    valid = slot_pos >= 0
    if cfg.window:
        valid &= slot_pos > cache_pos - cfg.window
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_eff.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_eff)
    out = out.reshape(b, 1, cfg.num_heads * hd)
    out = jnp.einsum("bsn,nd->bsd", out.astype(x.dtype), p["wo"])
    if quant:
        return out, (cache_k, k_scale), (cache_v, v_scale)
    return out, cache_k, cache_v
