"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory) + sLSTM (scalar).

mLSTM is implemented in its chunkwise-parallel form (the same chunked-scan
skeleton as SSD): per head, a matrix memory C ∈ R^{dk×dv} and normaliser
n ∈ R^{dk} decay with a scalar forget gate and accumulate i_t·k_t v_tᵀ.
TPU adaptation note (DESIGN.md §7): gates use sigmoid (GLA-style) rather
than the paper's exp-with-stabiliser — the chunkwise decay products stay in
[0,1] so no running-max state is needed; the architecture (matrix memory,
normaliser, output gating) is unchanged.

sLSTM keeps the paper's exponential gating *with* the m_t stabiliser — it
is a per-timestep ``lax.scan`` (inherently sequential; block-diagonal
recurrent weights per head), which is exactly why xLSTM places only every
k-th block as sLSTM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.axes import shard
from .common import Param, rms_norm, scaled_init

__all__ = [
    "init_mlstm",
    "mlstm_block",
    "mlstm_decode",
    "mlstm_state_shape",
    "init_slstm",
    "slstm_block",
    "slstm_decode",
    "slstm_state_shape",
]


# --------------------------------------------------------------------- mLSTM
def _mlstm_dims(cfg):
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    heads = cfg.num_heads
    dh = di // heads
    return di, heads, dh


def init_mlstm(rng, cfg, dtype):
    d = cfg.d_model
    di, heads, dh = _mlstm_dims(cfg)
    return {
        "w_up": Param(scaled_init(rng.next(), (d, 2 * di), dtype), ("embed", "inner_flat")),
        "wq": Param(scaled_init(rng.next(), (di, di), dtype), ("inner_flat", "inner_flat")),
        "wk": Param(scaled_init(rng.next(), (di, di), dtype), ("inner_flat", "inner_flat")),
        "wv": Param(scaled_init(rng.next(), (di, di), dtype), ("inner_flat", "inner_flat")),
        "w_i": Param(scaled_init(rng.next(), (di, heads), dtype), ("inner_flat", None)),
        "w_f": Param(scaled_init(rng.next(), (di, heads), dtype), ("inner_flat", None)),
        "b_f": Param(jnp.full((heads,), 3.0, dtype), (None,)),  # open forget gates
        "out_norm": Param(jnp.zeros((di,), dtype), ("inner_flat",)),
        "w_down": Param(scaled_init(rng.next(), (di, d), dtype, fan_in=di), ("inner_flat", "embed")),
    }


def mlstm_state_shape(cfg, batch):
    di, heads, dh = _mlstm_dims(cfg)
    return {"C": (batch, heads, dh, dh), "n": (batch, heads, dh)}


def _mlstm_chunked(q, k, v, ig, lf, chunk, init_state=None):
    """Chunkwise mLSTM. q/k/v: (b,s,h,dh); ig (sigmoid'd): (b,s,h);
    lf = log f (negative): (b,s,h). Returns (y, state)."""
    b, s, h, dh = q.shape
    assert s % chunk == 0
    nc = s // chunk
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    qc = q.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    ic = ig.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    fc = lf.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)

    def step(carry, inp):
        C, n = carry  # (b,h,dk,dv), (b,h,dk)
        qq, kk, vv, ii, ff = inp
        seg = jnp.cumsum(ff, axis=1)          # (b, chunk, h)
        total = seg[:, -1]
        li = seg[:, :, None, :]
        lj = seg[:, None, :, :]
        decay = jnp.exp(jnp.where(mask[None, :, :, None], li - lj, -jnp.inf))
        qk = jnp.einsum("bqhd,bkhd->bqkh", qq, kk)
        w = qk * decay * ii[:, None, :, :]     # (b,q,k,h)
        y = jnp.einsum("bqkh,bkhd->bqhd", w, vv)
        den = w.sum(axis=2)                    # q·n_q, intra part (b,q,h)
        # inter-chunk
        pd = jnp.exp(seg)                      # decay applied to entering state
        y = y + jnp.einsum("bqh,bqhd,bhde->bqhe", pd, qq, C)
        den = den + jnp.einsum("bqh,bqhd,bhd->bqh", pd, qq, n)
        # state update
        wdec = jnp.exp(total[:, None, :] - seg) * ii  # (b,k,h)
        C_new = C * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bkh,bkhd,bkhe->bhde", wdec, kk, vv
        )
        n_new = n * jnp.exp(total)[:, :, None] + jnp.einsum("bkh,bkhd->bhd", wdec, kk)
        out = y / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        return (C_new, n_new), out

    if init_state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
    else:
        C0 = init_state["C"].astype(jnp.float32)
        n0 = init_state["n"].astype(jnp.float32)
    (C, n), ys = jax.lax.scan(step, (C0, n0), (qc, kc, vc, ic, fc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return y, {"C": C, "n": n}


def _mlstm_qkvif(p_, x, cfg):
    b, s, _ = x.shape
    di, heads, dh = _mlstm_dims(cfg)
    u = jnp.einsum("bsd,de->bse", x, p_["w_up"])
    xin, z = u[..., :di], u[..., di:]
    q = jnp.einsum("bse,ef->bsf", xin, p_["wq"]).reshape(b, s, heads, dh)
    k = jnp.einsum("bse,ef->bsf", xin, p_["wk"]).reshape(b, s, heads, dh) * dh**-0.5
    v = jnp.einsum("bse,ef->bsf", xin, p_["wv"]).reshape(b, s, heads, dh)
    ig = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", xin, p_["w_i"]).astype(jnp.float32))
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xin, p_["w_f"]).astype(jnp.float32)
        + p_["b_f"].astype(jnp.float32)
    )
    return q, k, v, ig, lf, z


def mlstm_block(p_, x, cfg, *, init_state=None, chunk=256):
    b, s, d = x.shape
    di, heads, dh = _mlstm_dims(cfg)
    q, k, v, ig, lf, z = _mlstm_qkvif(p_, x, cfg)
    q = shard(q, "batch", None, None, "inner_heads")
    chunk = min(chunk, s)
    y, state = _mlstm_chunked(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        ig, lf, chunk, init_state,
    )
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y, p_["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p_["w_down"]), state


def mlstm_decode(p_, x, state, cfg):
    b = x.shape[0]
    di, heads, dh = _mlstm_dims(cfg)
    q, k, v, ig, lf, z = _mlstm_qkvif(p_, x, cfg)
    q0, k0, v0 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    f = jnp.exp(lf[:, 0])  # (b,h)
    i = ig[:, 0]
    C = state["C"] * f[:, :, None, None] + i[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", k0, v0
    )
    n = state["n"] * f[:, :, None] + i[:, :, None] * k0
    y = jnp.einsum("bhd,bhde->bhe", q0, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q0, n))
    y = (y / jnp.maximum(den, 1.0)[..., None]).reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y, p_["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p_["w_down"]), {"C": C, "n": n}


# --------------------------------------------------------------------- sLSTM
def _slstm_dims(cfg):
    d = cfg.d_model
    heads = cfg.num_heads
    dh = d // heads
    return d, heads, dh


def init_slstm(rng, cfg, dtype):
    d, heads, dh = _slstm_dims(cfg)
    p = {}
    for g in ("z", "i", "f", "o"):
        p[f"w_{g}"] = Param(scaled_init(rng.next(), (d, d), dtype), ("embed", "embed2"))
        p[f"r_{g}"] = Param(
            scaled_init(rng.next(), (heads, dh, dh), dtype, fan_in=dh) * 0.0,
            ("inner_heads", None, None),
        )
        p[f"b_{g}"] = Param(
            jnp.full((d,), 1.0 if g == "f" else 0.0, dtype), ("embed",)
        )
    p["out_norm"] = Param(jnp.zeros((d,), dtype), ("embed",))
    p["w_out"] = Param(scaled_init(rng.next(), (d, d), dtype), ("embed", "embed2"))
    return p


def slstm_state_shape(cfg, batch):
    d, heads, dh = _slstm_dims(cfg)
    return {
        "c": (batch, d), "n": (batch, d), "h": (batch, d), "m": (batch, d)
    }


def _slstm_cell(p_, xg, state, cfg):
    """One timestep. xg: dict of pre-computed W x_t (b, d) per gate."""
    d, heads, dh = _slstm_dims(cfg)
    c, n, h, m = state
    hh = h.reshape(-1, heads, dh)

    def rec(g):
        r = jnp.einsum("bhd,hde->bhe", hh, p_[f"r_{g}"].astype(jnp.float32))
        return xg[g] + r.reshape(-1, d) + p_[f"b_{g}"].astype(jnp.float32)

    zt = jnp.tanh(rec("z"))
    it = rec("i")
    ft = rec("f")
    ot = jax.nn.sigmoid(rec("o"))
    # exponential gating with stabiliser (xLSTM eq. 15-17)
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_block(p_, x, cfg, *, init_state=None):
    b, s, d = x.shape
    xf = x.astype(jnp.float32)
    pre = {
        g: jnp.einsum("bsd,de->bse", xf, p_[f"w_{g}"].astype(jnp.float32))
        for g in ("z", "i", "f", "o")
    }
    if init_state is None:
        z0 = jnp.zeros((b, d), jnp.float32)
        state0 = (z0, z0, z0, z0 - 1e30)
    else:
        state0 = tuple(init_state[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))

    def step(state, t):
        xg = {g: pre[g][:, t] for g in ("z", "i", "f", "o")}
        new = _slstm_cell(p_, xg, state, cfg)
        return new, new[2]

    state, hs = jax.lax.scan(step, state0, jnp.arange(s))
    y = hs.transpose(1, 0, 2).astype(x.dtype)  # (b, s, d)
    y = rms_norm(y, p_["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p_["w_out"])
    c, n, h, m = state
    return out, {"c": c, "n": n, "h": h, "m": m}


def slstm_decode(p_, x, state, cfg):
    b, _, d = x.shape
    xf = x[:, 0].astype(jnp.float32)
    xg = {
        g: jnp.einsum("bd,de->be", xf, p_[f"w_{g}"].astype(jnp.float32))
        for g in ("z", "i", "f", "o")
    }
    st = tuple(state[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))
    c, n, h, m = _slstm_cell(p_, xg, st, cfg)
    y = rms_norm(h[:, None].astype(x.dtype), p_["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p_["w_out"])
    return out, {"c": c, "n": n, "h": h, "m": m}
