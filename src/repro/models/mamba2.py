"""Mamba-2 (SSD) block [arXiv:2405.21060], chunked-scan formulation.

State-space duality form: per head h with state size n,
    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t x_tᵀ        (n × p state)
    y_t = C_tᵀ h_t + D · x_t
with scalar A < 0 per head, data-dependent dt, and shared B/C across heads
(n_groups = 1, as in zamba2-1.2b). Training/prefill uses the chunked
algorithm: quadratic attention-like intra-chunk term + a lax.scan over
chunk states (O(S·n·p) memory); decode is the O(1) recurrence.

This pure-jnp implementation is the oracle for ``kernels/ssd_scan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.axes import shard
from .common import Param, normal_init, scaled_init

__all__ = ["init_mamba2", "mamba2_block", "mamba2_decode", "mamba2_state_shape"]


def _dims(cfg):
    di = cfg.d_inner
    p = cfg.ssm_head_dim
    heads = di // p
    n = cfg.ssm_state
    return di, p, heads, n


def init_mamba2(rng, cfg, dtype):
    d = cfg.d_model
    di, p, heads, n = _dims(cfg)
    conv_dim = di + 2 * n  # conv over x, B, C
    return {
        "in_proj": Param(
            scaled_init(rng.next(), (d, 2 * di + 2 * n + heads), dtype),
            ("embed", "inner_flat"),
        ),
        "conv_w": Param(
            normal_init(rng.next(), (cfg.ssm_conv, conv_dim), dtype, 0.1),
            (None, "inner_flat"),
        ),
        "conv_b": Param(jnp.zeros((conv_dim,), dtype), ("inner_flat",)),
        "A_log": Param(
            jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(dtype), ("heads",)
        ),
        "dt_bias": Param(jnp.zeros((heads,), dtype), ("heads",)),
        "D": Param(jnp.ones((heads,), dtype), ("heads",)),
        "out_proj": Param(
            scaled_init(rng.next(), (di, d), dtype, fan_in=di), ("inner_flat", "embed")
        ),
    }


def mamba2_state_shape(cfg, batch):
    di, p, heads, n = _dims(cfg)
    return {
        "ssm": (batch, heads, p, n),
        "conv": (batch, cfg.ssm_conv - 1, di + 2 * n),
    }


def _split_proj(z_all, cfg):
    di, p, heads, n = _dims(cfg)
    z, rest = z_all[..., :di], z_all[..., di:]
    xbc, dt = rest[..., : di + 2 * n], rest[..., di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc, w, b, init_state=None):
    """Depthwise causal conv1d; returns (out, trailing context)."""
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+k-1, C)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(out), xp[:, -(k - 1) :] if k > 1 else pad[:, :0]


def _ssd_chunked(xh, dt, A, B, C, chunk, ssm_init=None):
    """Chunked SSD scan.

    xh: (b, s, h, p) head inputs; dt: (b, s, h) positive step sizes;
    A: (h,) negative decay rates; B, C: (b, s, n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = xh.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    la = dt * A[None, None, :]  # log decay per step (b, s, h) (negative)

    xc = xh.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    lac = la.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        """One chunk: intra-chunk quadratic term + inter-chunk state term.

        Sequential lax.scan keeps the (q,k,h) decay tensor at one-chunk size
        — the same working-set shape the Pallas ssd_scan kernel tiles into
        VMEM (a vectorised all-chunks version would materialise (nc,q,k,h)).
        """
        xcc, dcc, lcc, Bcc, Ccc = inp
        seg = jnp.cumsum(lcc, axis=1)       # (b, chunk, h) inclusive log-decay
        total = seg[:, -1]                  # (b, h)
        # intra: L[i,j] = exp(seg_i - seg_j), i >= j (decay over j+1..i)
        li = seg[:, :, None, :]
        lj = seg[:, None, :, :]
        decay = jnp.exp(jnp.where(mask[None, :, :, None], li - lj, -jnp.inf))
        cb = jnp.einsum("bqn,bkn->bqk", Ccc, Bcc)
        y = jnp.einsum("bqk,bqkh,bkh,bkhp->bqhp", cb, decay, dcc, xcc)
        # inter: contribution of the state entering this chunk
        y = y + jnp.einsum("bqn,bqh,bhpn->bqhp", Ccc, jnp.exp(seg), carry)
        # state update: S = S*exp(total) + sum_j exp(total - seg_j) dt_j B_j x_j^T
        wdec = jnp.exp(total[:, None, :] - seg) * dcc   # (b, k, h)
        st = jnp.einsum("bkh,bkn,bkhp->bhpn", wdec, Bcc, xcc)
        new = carry * jnp.exp(total)[:, :, None, None] + st
        return new, y

    init = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if ssm_init is None
        else ssm_init.astype(jnp.float32)
    )
    final, ys = jax.lax.scan(step, init, (xc, dtc, lac, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final


def mamba2_block(p_, x, cfg, *, init_state=None, chunk=None):
    """x: (B,S,d) -> (y, {"ssm","conv"} final state)."""
    chunk = chunk or cfg.ssm_chunk
    b, s, d = x.shape
    di, ph, heads, n = _dims(cfg)
    z_all = jnp.einsum("bsd,de->bse", x, p_["in_proj"])
    z, xbc, dt = _split_proj(z_all, cfg)
    conv_init = None if init_state is None else init_state["conv"]
    xbc, conv_state = _causal_conv(xbc, p_["conv_w"], p_["conv_b"], conv_init)
    xin, B, C = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p_["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, heads, ph)
    xh = shard(xh, "batch", None, "inner_heads", None)
    ssm_init = None if init_state is None else init_state["ssm"]
    chunk = min(chunk, s)
    y, final = _ssd_chunked(
        xh.astype(jnp.float32), dt, A, B.astype(jnp.float32), C.astype(jnp.float32),
        chunk, ssm_init,
    )
    y = y + xh.astype(jnp.float32) * p_["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p_["out_proj"])
    state = {"ssm": final, "conv": conv_state}
    return out, state


def mamba2_decode(p_, x, state, cfg):
    """One-token recurrence. x: (B,1,d); state from mamba2_state_shape."""
    b = x.shape[0]
    di, ph, heads, n = _dims(cfg)
    z_all = jnp.einsum("bsd,de->bse", x, p_["in_proj"])
    z, xbc, dt = _split_proj(z_all, cfg)
    # conv: shift register
    ctx = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, k, C)
    w, bb = p_["conv_w"], p_["conv_b"]
    k = w.shape[0]
    out = sum(ctx[:, i] * w[i] for i in range(k)) + bb
    xbc = jax.nn.silu(out)[:, None]
    new_conv = ctx[:, 1:]
    xin, B, C = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p_["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p_["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, 1, heads, ph).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0] * A[None, :])  # (b, h)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B[:, 0].astype(jnp.float32), xh[:, 0])
    new_ssm = state["ssm"].astype(jnp.float32) * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), new_ssm)
    y = y + xh[:, 0] * p_["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p_["out_proj"])
    return out, {"ssm": new_ssm, "conv": new_conv}
