"""Model assembly: block zoo + scan-over-layers segments + caches.

A model is a sequence of *segments* (run-length-encoded runs of identical
block kinds, ``ModelConfig.segments()``). Each segment's parameters are
stacked on a leading "layers" axis and executed with ``jax.lax.scan`` —
compile time and HLO size stay O(1 block) regardless of depth, which is
what makes the 512-device dry-run (and real-world compiles at depth 61)
tractable. Zamba2's *shared* attention block holds one parameter set
applied at every site (segments of kind "shared_attn" reference it).

Decode caches mirror the segment structure: stacked KV tensors for
attention segments (rotating window buffers when ``cfg.window`` is set, so
zamba2's 500k-context decode holds only the window), SSD/mLSTM/sLSTM state
dicts for the recurrent kinds.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.axes import shard
from .attention import attention_block, decode_attention_block, init_attention
from .common import Param, RngStream, rms_norm
from .mamba2 import init_mamba2, mamba2_block, mamba2_decode, mamba2_state_shape
from .mlp import init_mlp, mlp_block
from .moe import init_moe, moe_block, moe_block_a2a
from .xlstm import (
    init_mlstm,
    init_slstm,
    mlstm_block,
    mlstm_decode,
    mlstm_state_shape,
    slstm_block,
    slstm_decode,
    slstm_state_shape,
)

__all__ = ["Model", "build_model"]

_ATTN_KINDS = ("attn_mlp", "attn_dense_moe", "attn_moe", "shared_attn")


# ----------------------------------------------------------------- blocks
def _init_block(kind: str, rng: RngStream, cfg: ModelConfig, dtype):
    zeros = lambda: Param(jnp.zeros((cfg.d_model,), dtype), ("embed",))
    if kind in ("attn_mlp", "shared_attn"):
        return {
            "ln1": zeros(),
            "attn": init_attention(rng, cfg, dtype),
            "ln2": zeros(),
            "mlp": init_mlp(rng, cfg, dtype),
        }
    if kind == "attn_dense_moe":
        return {
            "ln1": zeros(),
            "attn": init_attention(rng, cfg, dtype),
            "ln2": zeros(),
            "mlp": init_mlp(rng, cfg, dtype, d_ff=cfg.moe_dense_ff or cfg.d_ff),
        }
    if kind == "attn_moe":
        return {
            "ln1": zeros(),
            "attn": init_attention(rng, cfg, dtype),
            "ln2": zeros(),
            "moe": init_moe(rng, cfg, dtype),
        }
    if kind == "mamba2":
        return {"ln": zeros(), "mixer": init_mamba2(rng, cfg, dtype)}
    if kind == "mlstm":
        return {"ln": zeros(), "cell": init_mlstm(rng, cfg, dtype)}
    if kind == "slstm":
        return {"ln": zeros(), "cell": init_slstm(rng, cfg, dtype)}
    raise ValueError(kind)


def _apply_block(kind, p, x, cfg, state=None):
    """Full-sequence block application.

    Returns (x_out, cache_entry, aux_loss). cache_entry is the KV (for attn
    kinds) or the final recurrent state (ssm kinds); None in pure train mode
    consumers (it is still produced — XLA DCEs it when unused).
    """
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_dense_moe", "shared_attn"):
        h, kv = attention_block(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        x = x + mlp_block(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x, kv, aux
    if kind == "attn_moe":
        h, kv = attention_block(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        moe_fn = moe_block_a2a if cfg.moe_impl == "a2a" else moe_block
        h, aux = moe_fn(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        return x + h, kv, aux
    if kind == "mamba2":
        h, st = mamba2_block(p["mixer"], rms_norm(x, p["ln"], cfg.norm_eps), cfg,
                             init_state=state)
        return x + h, st, aux
    if kind == "mlstm":
        h, st = mlstm_block(p["cell"], rms_norm(x, p["ln"], cfg.norm_eps), cfg,
                            init_state=state)
        return x + h, st, aux
    if kind == "slstm":
        h, st = slstm_block(p["cell"], rms_norm(x, p["ln"], cfg.norm_eps), cfg,
                            init_state=state)
        return x + h, st, aux
    raise ValueError(kind)


def _decode_block(kind, p, x, cache, cache_pos, cfg):
    """One-token block application against the cache. Returns (x, cache)."""
    if kind in ("attn_mlp", "attn_dense_moe", "attn_moe", "shared_attn"):
        quant = cfg.kv_cache_dtype == "int8"
        h, ck, cv = decode_attention_block(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
            cache["k"], cache["v"], cache_pos, cfg,
            k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        )
        x = x + h
        if kind == "attn_moe":
            h, _ = moe_block(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
        else:
            h = mlp_block(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
        if quant:
            return x + h, {"k": ck[0], "k_scale": ck[1], "v": cv[0], "v_scale": cv[1]}
        return x + h, {"k": ck, "v": cv}
    if kind == "mamba2":
        h, st = mamba2_decode(p["mixer"], rms_norm(x, p["ln"], cfg.norm_eps), cache, cfg)
        return x + h, st
    if kind == "mlstm":
        h, st = mlstm_decode(p["cell"], rms_norm(x, p["ln"], cfg.norm_eps), cache, cfg)
        return x + h, st
    if kind == "slstm":
        h, st = slstm_decode(p["cell"], rms_norm(x, p["ln"], cfg.norm_eps), cache, cfg)
        return x + h, st
    raise ValueError(kind)


def _cache_shapes(kind, cfg, batch, max_len, cdt):
    """(shape, dtype, logical_axes) tree for one block's cache entry.

    KV caches live in the compute dtype; recurrent states (SSD / mLSTM /
    sLSTM) live in fp32 — they integrate over the whole sequence, and the
    decode functions keep them fp32 so serve-loop lowering is dtype-stable.
    The axes (with a leading 'layers') drive the cache sharding in the
    dry-run/serving launchers.
    """
    hd = cfg.head_dim_
    f32 = jnp.float32
    if kind in _ATTN_KINDS:
        s = min(max_len, cfg.window) if cfg.window else max_len
        shp = (batch, s, cfg.num_kv_heads, hd)
        ax = ("batch", None, "kv_heads", None)
        if cfg.kv_cache_dtype == "int8":
            sshp = (batch, s, cfg.num_kv_heads, 1)
            return {
                "k": (shp, jnp.int8, ax),
                "k_scale": (sshp, jnp.bfloat16, ax),
                "v": (shp, jnp.int8, ax),
                "v_scale": (sshp, jnp.bfloat16, ax),
            }
        return {"k": (shp, cdt, ax), "v": (shp, cdt, ax)}
    if kind == "mamba2":
        shp = mamba2_state_shape(cfg, batch)
        return {
            "ssm": (shp["ssm"], f32, ("batch", "inner_heads", None, None)),
            "conv": (shp["conv"], cdt, ("batch", None, "inner_flat")),
        }
    if kind == "mlstm":
        shp = mlstm_state_shape(cfg, batch)
        return {
            "C": (shp["C"], f32, ("batch", "inner_heads", None, None)),
            "n": (shp["n"], f32, ("batch", "inner_heads", None)),
        }
    if kind == "slstm":
        shp = slstm_state_shape(cfg, batch)
        return {k: (v, f32, ("batch", "embed_state")) for k, v in shp.items()}
    raise ValueError(kind)


def _stack_params(blocks: list[dict]) -> dict:
    """Stack per-layer Param trees onto a leading 'layers' axis."""
    def stack(*ps):
        return Param(
            jnp.stack([p.value for p in ps]), ("layers", *ps[0].axes)
        )
    return jax.tree.map(stack, *blocks, is_leaf=lambda x: isinstance(x, Param))


# ------------------------------------------------------------------ model
@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- init
    def init(self, seed: int = 0):
        """Returns a Param tree (use split_params to get values + axes)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        rng = RngStream(seed)
        d = cfg.d_model
        params: dict = {
            "embed": Param(
                (jax.random.normal(rng.next(), (cfg.vocab_size, d), jnp.float32) * 0.02
                 ).astype(dtype),
                ("vocab", "embed"),
            ),
            "final_norm": Param(jnp.zeros((d,), dtype), ("embed",)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = Param(
                (jax.random.normal(rng.next(), (d, cfg.vocab_size), jnp.float32)
                 / d**0.5).astype(dtype),
                ("embed", "vocab"),
            )
        if cfg.frontend != "none":
            params["frontend"] = Param(
                (jax.random.normal(rng.next(), (cfg.frontend_dim, d), jnp.float32)
                 / cfg.frontend_dim**0.5).astype(dtype),
                (None, "embed"),
            )
        segs = []
        shared = None
        for kind, count in cfg.segments():
            if kind == "shared_attn":
                if shared is None:
                    shared = _init_block(kind, rng, cfg, dtype)
                segs.append({})  # placeholder; params live in params["shared_attn"]
            else:
                blocks = [_init_block(kind, rng, cfg, dtype) for _ in range(count)]
                segs.append(_stack_params(blocks))
        params["segments"] = segs
        if shared is not None:
            params["shared_attn"] = shared
        return params

    # ----------------------------------------------------------- embedding
    def _embed_inputs(self, values, inputs):
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        embed = values["embed"]
        if cfg.frontend == "patch":
            pe = jnp.einsum(
                "bpf,fd->bpd", inputs["patch_embeds"].astype(cdt),
                values["frontend"].astype(cdt),
            )
            tok = jnp.take(embed, inputs["tokens"], axis=0).astype(cdt)
            x = jnp.concatenate([pe, tok], axis=1)
        elif cfg.frontend == "frame":
            x = jnp.einsum(
                "bsf,fd->bsd", inputs["frames"].astype(cdt),
                values["frontend"].astype(cdt),
            )
        else:
            x = jnp.take(embed, inputs["tokens"], axis=0).astype(cdt)
        return shard(x, "batch", None, "embed_act")

    def _logits(self, values, x):
        cfg = self.cfg
        x = rms_norm(x, values["final_norm"], cfg.norm_eps)
        head = (
            values["embed"].T if cfg.tie_embeddings else values["lm_head"]
        )
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
        return shard(logits, "batch", None, "vocab")

    # ------------------------------------------------------------ forward
    def forward(self, values, inputs, *, remat: str = "none", want_cache: bool = False):
        """Full-sequence pass. Returns (logits, aux, cache_list)."""
        cfg = self.cfg
        x = self._embed_inputs(values, inputs)
        aux_total = jnp.zeros((), jnp.float32)
        caches = []
        for seg, seg_vals in zip(cfg.segments(), values["segments"]):
            kind, count = seg
            if kind == "shared_attn":
                x, kv, aux = _apply_block(kind, values["shared_attn"], x, cfg)
                caches.append(self._kv_to_cache(kv) if want_cache else None)
                aux_total = aux_total + aux
                continue

            def body(carry, lp, kind=kind):
                xx, aux_acc = carry
                xx, cache, aux = _apply_block(kind, lp, xx, cfg)
                # Megatron-SP: with run_cfg.seq_parallel the "seq_act" rule
                # maps to "model" and the residual stream lives sequence-
                # sharded between blocks (all-gather in, reduce-scatter out).
                xx = shard(xx, "batch", "seq_act", "embed_act")
                return (xx, aux_acc + aux), (
                    self._kv_to_cache(cache) if kind in _ATTN_KINDS else cache
                )

            if remat == "full":
                body = jax.checkpoint(body)
            elif remat == "dots":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                )
            (x, aux_total), seg_cache = jax.lax.scan(body, (x, aux_total), seg_vals)
            caches.append(seg_cache if want_cache else None)
        return self._logits(values, x), aux_total, caches

    def _kv_to_cache(self, kv):
        k, v = kv
        return {"k": k, "v": v}

    # ------------------------------------------------------------- decode
    def cache_specs(self, batch: int, max_len: int, dtype=None):
        """Cache shape/dtype pytree (mirrors segment structure)."""
        cfg = self.cfg
        cdt = dtype or jnp.dtype(cfg.compute_dtype)
        is_entry = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        specs = []
        for kind, count in cfg.segments():
            shapes = _cache_shapes(kind, cfg, batch, max_len, cdt)
            lead = 1 if kind == "shared_attn" else count
            specs.append(
                jax.tree.map(
                    lambda sd: jax.ShapeDtypeStruct((lead, *sd[0]), sd[1]),
                    shapes,
                    is_leaf=is_entry,
                )
            )
        return specs

    def cache_axes(self, batch: int, max_len: int, tp: int | None = None):
        """Logical axes for every cache leaf (same treedef as cache_specs).

        When the KV-head count does not divide the tensor-parallel degree
        (starcoder2/tinyllama: kv=4 vs tp=16), KV caches shard on the
        *sequence* dim instead ("kv_seq" -> model): flash-decoding-style
        split-K, which XLA realises as a partial-softmax reduction. This
        keeps e.g. starcoder2's decode_32k cache at ~0.5 GB/device instead
        of a replicated ~10 GB/device.
        """
        cfg = self.cfg
        is_entry = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        split_k = tp is not None and cfg.num_kv_heads % tp != 0
        out = []
        for kind, count in cfg.segments():
            shapes = _cache_shapes(kind, cfg, batch, max_len, jnp.bfloat16)
            axes_tree = jax.tree.map(
                lambda sd: ("layers", *sd[2]), shapes, is_leaf=is_entry
            )
            if split_k and kind in _ATTN_KINDS:
                axes_tree = jax.tree.map(
                    lambda a: ("layers", "batch", "kv_seq", None, None),
                    axes_tree,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
            out.append(axes_tree)
        return out

    def init_cache(self, batch: int, max_len: int, dtype=None):
        """Zero cache pytree (mirrors segment structure)."""
        return jax.tree.map(
            lambda sds: jnp.zeros(sds.shape, sds.dtype),
            self.cache_specs(batch, max_len, dtype),
        )

    def decode_step(self, values, caches, tokens, cache_pos):
        """One token for the whole batch. tokens: (B, 1) int32."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        x = jnp.take(values["embed"], tokens, axis=0).astype(cdt)
        new_caches = []
        for seg, seg_vals, cache in zip(cfg.segments(), values["segments"], caches):
            kind, count = seg
            if kind == "shared_attn":
                c0 = jax.tree.map(lambda t: t[0], cache)
                x, c0 = _decode_block(kind, values["shared_attn"], x, c0, cache_pos, cfg)
                new_caches.append(jax.tree.map(lambda t: t[None], c0))
                continue

            def body(xx, lp_cache, kind=kind):
                lp, c = lp_cache
                xx, c = _decode_block(kind, lp, xx, c, cache_pos, cfg)
                return xx, c

            x, new_c = jax.lax.scan(body, x, (seg_vals, cache))
            new_caches.append(new_c)
        return self._logits(values, x), new_caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
