"""Fine-grained MoE: shared + routed experts, top-k token-choice routing.

Follows DeepSeekMoE [arXiv:2401.06066] (deepseek-moe-16b: 2 shared + 64
routed, top-6) and the same structure at Kimi-K2 scale (384 routed, top-8).

Dispatch is **sort-based with capacity dropping**, grouped GShard-style by
batch row: each sequence dispatches its own tokens into per-expert capacity
slots (``cap = seq·k·cf / E``). Grouping keeps the expert buffers sharded
along the batch/data axis — a single global dispatch would make the
(E, cap, d) buffer unshardable over tokens (≈7 TB/device at kimi-k2 scale);
the grouped buffer is (B, E, cap, d) with B on the data axis and E on the
model axis (EP). A (tokens, experts, capacity) one-hot GShard dispatch
einsum was rejected for the same reason (≈4 GB/device in bf16 at kimi
scale). Under pjit, XLA lowers the batched gather/scatter across the E
axis into all-to-alls (measured in the roofline; a shard_map variant is a
§Perf candidate).

An auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:
    shard_map = jax.shard_map  # jax >= 0.4.39
except AttributeError:
    from jax.experimental.shard_map import shard_map

from ..parallel.axes import shard
from .common import Param, scaled_init

__all__ = ["init_moe", "moe_block"]


def init_moe(rng, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    p = {
        "router": Param(scaled_init(rng.next(), (d, e), dtype), ("embed", None)),
        "wi_gate": Param(
            scaled_init(rng.next(), (e, d, f), dtype, fan_in=d), ("experts", "embed", None)
        ),
        "wi_up": Param(
            scaled_init(rng.next(), (e, d, f), dtype, fan_in=d), ("experts", "embed", None)
        ),
        "wo": Param(
            scaled_init(rng.next(), (e, f, d), dtype, fan_in=f), ("experts", None, "embed")
        ),
    }
    if cfg.moe_num_shared:
        sf = f * cfg.moe_num_shared
        p["shared"] = {
            "wi_gate": Param(scaled_init(rng.next(), (d, sf), dtype), ("embed", "mlp")),
            "wi_up": Param(scaled_init(rng.next(), (d, sf), dtype), ("embed", "mlp")),
            "wo": Param(scaled_init(rng.next(), (sf, d), dtype, fan_in=sf), ("mlp", "embed")),
        }
    return p


def moe_block(p, x, cfg):
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = max(int(s * k * cfg.capacity_factor / e), 1)

    # --- routing (fp32 for numerics) ---
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (b, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch eq. 4-6), via scatter (no one-hot) ---
    t = b * s
    density = (
        jnp.zeros((e,), jnp.float32).at[top_e[..., 0].reshape(-1)].add(1.0) / t
    )
    router_mean = probs.reshape(t, e).mean(axis=0)
    aux = e * jnp.sum(density * router_mean)

    # --- per-row sort-based dispatch with capacity dropping ---
    # All scatters here move 4-byte *integers* (slot maps), never d_model
    # vectors: data moves only through gathers whose outputs carry sharding
    # ("experts" or "seq_act" on the gathered dim), so no (s*k, d)-sized
    # unsharded intermediate ever materialises (15 GB/device at kimi scale).
    flat_e = top_e.reshape(b, s * k)
    flat_p = top_p.reshape(b, s * k).astype(x.dtype)

    def slot_maps(se_r):
        """One row: se_r (s*k,) expert ids -> integer routing maps."""
        order = jnp.argsort(se_r, stable=True)
        se = se_r[order]
        st = (order // k).astype(jnp.int32)   # token of each sorted assignment
        counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(s * k, dtype=jnp.int32) - starts[se]
        keep = pos < cap
        slot = se * cap + pos                  # valid only where keep
        # slot -> token map (dropped assignments go to a dump slot e*cap)
        s2t = jnp.full((e * cap + 1,), 0, jnp.int32)
        s2t = s2t.at[jnp.where(keep, slot, e * cap)].set(st)
        s2v = jnp.zeros((e * cap + 1,), jnp.bool_)
        s2v = s2v.at[jnp.where(keep, slot, e * cap)].set(keep)
        # original-order assignment -> slot map (for the combine gathers)
        a2s = jnp.zeros((s * k,), jnp.int32).at[order].set(jnp.where(keep, slot, 0))
        a2v = jnp.zeros((s * k,), jnp.bool_).at[order].set(keep)
        return s2t[: e * cap], s2v[: e * cap], a2s, a2v

    s2t, s2v, a2s, a2v = jax.vmap(slot_maps)(flat_e)

    # gather tokens into expert buffers; output sharded over "experts"
    buf = jnp.take_along_axis(x, s2t[..., None], axis=1)       # (b, e*cap, d)
    buf = jnp.where(s2v[..., None], buf, 0).reshape(b, e, cap, d)
    buf = shard(buf, "batch", "experts", None, None)

    # --- expert FFN (grouped einsum over the expert dim; EP over "model") ---
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wi_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wi_up"])
    h = shard(h, "batch", "experts", None, None)
    y = jnp.einsum("becf,efd->becd", h, p["wo"]).reshape(b, e * cap, d)

    # --- combine: k gathers in original token order (seq-shardable) ---
    out = jnp.zeros((b, s, d), x.dtype)
    for j in range(k):
        idx = a2s.reshape(b, s, k)[:, :, j]
        wj = (flat_p * a2v).reshape(b, s, k)[:, :, j]
        yj = jnp.take_along_axis(y, idx[..., None], axis=1)    # (b, s, d)
        yj = shard(yj, "batch", "seq_act", None)
        out = out + yj * wj[..., None]
    out = shard(out, "batch", "seq_act", None)

    if cfg.moe_num_shared:
        sp_ = p["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp_["wi_gate"]))
        hs = hs * jnp.einsum("bsd,df->bsf", x, sp_["wi_up"])
        out = out + jnp.einsum("bsf,fd->bsd", hs, sp_["wo"])

    return out, aux.astype(jnp.float32)


# --------------------------------------------------------- shard_map variant
def moe_block_a2a(p, x, cfg):
    """Explicit all-to-all expert parallelism via shard_map (§Perf lever).

    GSPMD lowers the pjit dispatch above into all-gathers of the expert
    buffers (tokens replicate across the expert axis). This variant is the
    structural fix: tokens are sequence-sharded over the "model" axis, each
    shard routes its own tokens, sends exactly the chosen token vectors to
    the owning expert shard with ``jax.lax.all_to_all``, and reverses the
    route for the combine — moving tokens·k·d bytes instead of
    tokens·E_shard·cap·d. Two-stage capacity dropping (per (src,dst) pair,
    then per expert) follows GShard practice; with generous capacity the
    output equals :func:`moe_block` (equivalence-tested).

    Requires an active mesh whose "model" axis divides both the sequence
    and the expert count; ``_apply_block`` selects it via
    ``cfg.moe_impl == "a2a"``.
    """
    from jax.sharding import PartitionSpec as P

    from ..parallel.axes import current_ctx

    ctx = current_ctx()
    assert ctx is not None and "model" in ctx.mesh.shape, (
        "moe_block_a2a needs an active sharding ctx with a 'model' axis"
    )
    mesh = ctx.mesh
    e_sh = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    assert e % e_sh == 0 and s % e_sh == 0, (e, s, e_sh)
    e_l = e // e_sh
    s_l = s // e_sh
    cap_pair = max(int(s_l * k * cfg.capacity_factor / e_sh) * max(b // max(
        __import__("math").prod(mesh.shape[a] for a in dp), 1), 1), 1)
    cap_local = max(int(e_sh * cap_pair * cfg.capacity_factor / e_l), 1)

    # routing + aux loss on the global view (router weights are replicated)
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    t_all = b * s
    density = (
        jnp.zeros((e,), jnp.float32).at[top_e[..., 0].reshape(-1)].add(1.0) / t_all
    )
    aux = e * jnp.sum(density * probs.reshape(t_all, e).mean(axis=0))

    def local_fn(xl, wig, wiu, wo, te, tp):
        """One model-shard: xl (b_l, s_l, d); te/tp (b_l, s_l, k)."""
        bl, sl, _ = xl.shape
        t = bl * sl * k
        xt = xl.reshape(bl * sl, d)
        se = te.reshape(-1)
        sp = tp.reshape(-1).astype(xl.dtype)
        tok = (jnp.arange(t, dtype=jnp.int32) // k).astype(jnp.int32)
        dst = (se // e_l).astype(jnp.int32)
        eid = (se % e_l).astype(jnp.int32)

        # --- send-side: rank within destination shard, capacity-dropped ---
        order = jnp.argsort(dst, stable=True)
        dst_s, tok_s, eid_s, sp_s = dst[order], tok[order], eid[order], sp[order]
        counts = jnp.zeros((e_sh,), jnp.int32).at[dst_s].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(t, dtype=jnp.int32) - starts[dst_s]
        keep = pos < cap_pair
        slot = jnp.where(keep, dst_s * cap_pair + pos, e_sh * cap_pair)
        send_x = (
            jnp.zeros((e_sh * cap_pair + 1, d), xl.dtype).at[slot].set(xt[tok_s])
        )[: e_sh * cap_pair]
        send_e = (
            jnp.full((e_sh * cap_pair + 1,), -1, jnp.int32).at[slot].set(eid_s)
        )[: e_sh * cap_pair]

        # --- all-to-all: tokens travel to their experts' shard -------------
        recv_x = jax.lax.all_to_all(
            send_x.reshape(e_sh, cap_pair, d), "model", 0, 0, tiled=False
        ).reshape(e_sh * cap_pair, d)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(e_sh, cap_pair, 1), "model", 0, 0, tiled=False
        ).reshape(e_sh * cap_pair)

        # --- recv-side: group by local expert, capacity-dropped ------------
        r = e_sh * cap_pair
        valid = recv_e >= 0
        key = jnp.where(valid, recv_e, e_l)
        order2 = jnp.argsort(key, stable=True)
        re2 = recv_e[order2]
        counts2 = jnp.zeros((e_l + 1,), jnp.int32).at[jnp.where(valid, recv_e, e_l)].add(1)
        starts2 = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts2)[:-1]])
        pos2 = jnp.arange(r, dtype=jnp.int32) - starts2[jnp.where(re2 >= 0, re2, e_l)]
        keep2 = (re2 >= 0) & (pos2 < cap_local)
        slot2 = jnp.where(keep2, re2 * cap_local + pos2, e_l * cap_local)
        buf = (
            jnp.zeros((e_l * cap_local + 1, d), xl.dtype).at[slot2].set(recv_x[order2])
        )[: e_l * cap_local].reshape(e_l, cap_local, d)

        # --- expert FFN ----------------------------------------------------
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wig))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wiu)
        y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e_l * cap_local, d)

        # --- route back (inverse permutations + reverse all-to-all) --------
        y_sorted = jnp.where(
            keep2[:, None], y[jnp.minimum(slot2, e_l * cap_local - 1)], 0
        )
        y_recv = jnp.zeros((r, d), xl.dtype).at[order2].set(y_sorted)
        y_send = jax.lax.all_to_all(
            y_recv.reshape(e_sh, cap_pair, d), "model", 0, 0, tiled=False
        ).reshape(e_sh * cap_pair, d)
        contrib = (
            jnp.where(keep[:, None], y_send[jnp.minimum(slot, e_sh * cap_pair - 1)], 0)
            * sp_s[:, None]
        )
        out_l = jnp.zeros((bl * sl, d), xl.dtype).at[tok_s].add(contrib)
        return out_l.reshape(bl, sl, d)

    spec_x = P(dp if dp else None, "model", None)
    spec_w = P("model", None, None)
    out = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(spec_x, spec_w, spec_w, spec_w, spec_x, spec_x),
        out_specs=spec_x,
    )(x, p["wi_gate"], p["wi_up"], p["wo"], top_e, top_p)

    if cfg.moe_num_shared:
        sp_ = p["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp_["wi_gate"]))
        hs = hs * jnp.einsum("bsd,df->bsf", x, sp_["wi_up"])
        out = out + jnp.einsum("bsf,fd->bsd", hs, sp_["wo"])
    return out, aux.astype(jnp.float32)
