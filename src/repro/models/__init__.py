from .common import Param, RngStream, merge_params, split_params
from .transformer import Model, build_model

__all__ = ["Model", "Param", "RngStream", "build_model", "merge_params", "split_params"]
