"""SwiGLU MLP (LLaMA-style gated feed-forward)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.axes import shard
from .common import Param, scaled_init

__all__ = ["init_mlp", "mlp_block"]


def init_mlp(rng, cfg, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wi_gate": Param(scaled_init(rng.next(), (d, f), dtype), ("embed", "mlp")),
        "wi_up": Param(scaled_init(rng.next(), (d, f), dtype), ("embed", "mlp")),
        "wo": Param(scaled_init(rng.next(), (f, d), dtype, fan_in=f), ("mlp", "embed")),
    }


def mlp_block(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = shard(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
