"""Shared model machinery: params with logical axes, norms, RoPE, init."""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Param",
    "split_params",
    "merge_params",
    "RngStream",
    "rms_norm",
    "make_rope",
    "apply_rope",
    "normal_init",
    "scaled_init",
]


@dataclasses.dataclass
class Param:
    """A parameter leaf: value + logical axis names (one per dim).

    Registered as a pytree node (value = child, axes = static aux data) so
    ``jax.eval_shape`` can trace ``Model.init`` at full scale without ever
    allocating parameters — that's how the 1T-param dry-run stays lazy.
    """

    value: jax.Array
    axes: tuple[str | None, ...]

    def __post_init__(self):
        assert len(self.axes) == len(self.value.shape), (self.axes, self.value.shape)


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Param tree -> (values tree, axes tree) with identical structure."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_param)
    return values, axes


def merge_params(values, axes):
    return jax.tree.map(Param, values, axes, is_leaf=lambda x: x is None)


class RngStream:
    """Deterministic rng splitter: stream.next() never reuses a key."""

    def __init__(self, seed_or_key):
        self._key = (
            seed_or_key
            if isinstance(seed_or_key, jax.Array)
            else jax.random.PRNGKey(seed_or_key)
        )

    def next(self) -> jax.Array:
        self._key, out = jax.random.split(self._key)
        return out


def normal_init(rng, shape, dtype, stddev=0.02):
    return (jax.random.normal(rng, shape, jnp.float32) * stddev).astype(dtype)


def scaled_init(rng, shape, dtype, fan_in=None):
    """Truncated-normal-ish fan-in scaled init (1/sqrt(fan_in))."""
    fan_in = fan_in if fan_in is not None else shape[0]
    return (
        jax.random.normal(rng, shape, jnp.float32) / math.sqrt(max(fan_in, 1))
    ).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32, result cast back to x.dtype (LLaMA convention)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def make_rope(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """(sin, cos) tables for the given positions; fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) -> (x1 cos - x2 sin, x2 cos + x1 sin).

    x: (..., S, H, D); sin/cos: (..., S, D/2) broadcast over heads.
    Odd head_dims leave the last lane unrotated (kimi's 112 stays exact).
    """
    half = sin.shape[-1]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    x1 = x[..., :half]
    x2 = x[..., half : 2 * half]
    rest = x[..., 2 * half :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2] + ([rest] if rest.shape[-1] else []), axis=-1)
    return out.astype(x.dtype)
