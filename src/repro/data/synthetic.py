"""Synthetic datasets for tests, benchmarks, and the end-to-end example.

Two generators:

* :func:`paper_like_sizes` — file-size distributions matching the paper's
  datasets (ImageNet-1k ≈ 110 KB mean lognormal, LibriSpeech ≈ 200 KB,
  ImageNet-21k ≈ 85 KB) so the I/O benchmarks see realistic size skew.
* :class:`SyntheticTokenDataset` — an actual materialisable token dataset
  (Zipf-distributed vocabulary, Markov-ish structure so a language model
  has something learnable) used by the convergence experiment and the
  end-to-end training example.
"""

from __future__ import annotations

import numpy as np

from ..core.chunking import ChunkingPlan
from ..core.storage import ChunkStore
from .tokens import encode_record

__all__ = ["paper_like_sizes", "SyntheticTokenDataset"]

_PROFILES = {
    # mean_bytes, sigma of lognormal (paper: "file sizes vary from a few KB
    # to several hundred KB")
    "imagenet1k": (110_000, 0.6),
    "imagenet21k": (85_000, 0.7),
    "librispeech": (200_000, 0.5),
}


def paper_like_sizes(profile: str, num_files: int, seed: int = 0) -> np.ndarray:
    """File-size array (bytes) following one of the paper's dataset profiles."""
    mean, sigma = _PROFILES[profile]
    rng = np.random.default_rng((seed, hash(profile) & 0xFFFF))
    mu = np.log(mean) - sigma**2 / 2
    sizes = rng.lognormal(mu, sigma, size=num_files)
    return np.maximum(sizes, 1024).astype(np.int64)


class SyntheticTokenDataset:
    """Learnable synthetic token corpus with variable-length documents."""

    def __init__(
        self,
        num_docs: int,
        vocab_size: int,
        mean_len: int = 256,
        min_len: int = 32,
        seed: int = 0,
    ):
        self.num_docs = num_docs
        self.vocab_size = vocab_size
        self.seed = seed
        rng = np.random.default_rng((seed, 11))
        lens = rng.geometric(1.0 / mean_len, size=num_docs) + min_len
        self.lengths = np.minimum(lens, 4 * mean_len).astype(np.int64)
        self.sizes_bytes = (self.lengths * 4).astype(np.int64)
        # A tiny order-1 Markov structure: next-token distribution depends on
        # current token's bucket -> the LM has signal to learn, so the
        # convergence benchmark (paper Fig. 15) is meaningful.
        self._buckets = 16

    def record_tokens(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 13, doc_id))
        n = int(self.lengths[doc_id])
        toks = np.empty(n, dtype=np.int32)
        toks[0] = rng.integers(self.vocab_size)
        bucket_width = max(self.vocab_size // self._buckets, 1)
        for i in range(1, n):
            b = (int(toks[i - 1]) // bucket_width) % self._buckets
            center = (b * 37 + 11) % self.vocab_size
            toks[i] = (center + rng.integers(bucket_width)) % self.vocab_size
        return toks

    def __getitem__(self, doc_id: int) -> bytes:
        return encode_record(self.record_tokens(doc_id))

    def build_store(
        self, root, chunk_size: int, *, num_slots: int | None = None,
        memory_bytes: int | None = None, seed: int = 0, backend="vfs",
        spec=None, codec=None, level=None, bands=None,
    ) -> ChunkStore:
        """Materialise the corpus as a chunk store at ``root``.

        ``spec``/``codec``/``level``/``bands`` pass straight through to
        :meth:`ChunkStore.build` (with ``spec=`` the backend belongs in
        the spec, matching the store's own contract).
        """
        plan = ChunkingPlan.create(
            self.sizes_bytes, chunk_size,
            num_slots=num_slots, memory_bytes=memory_bytes, seed=seed,
        )
        if spec is not None:
            # Forward everything so ChunkStore.build can reject the
            # spec-plus-kwargs conflict itself (our "vfs" default is not
            # an explicit backend choice, so it doesn't conflict).
            return ChunkStore.build(
                root, plan, self, spec=spec,
                backend=None if backend == "vfs" else backend,
                codec=codec, level=level, bands=bands,
            )
        return ChunkStore.build(
            root, plan, self, backend=backend,
            codec=codec, level=level, bands=bands,
        )
