"""Record format: variable-length int32 token sequences.

Records play the role of the paper's "data files": variable-sized blobs
(token sequences here; image bytes there). A record is raw little-endian
int32 tokens — size in bytes is 4 × length, so the variable-size property
the paper's dynamic allocation exploits is preserved.
"""

from __future__ import annotations

import numpy as np

__all__ = ["encode_record", "decode_record"]


def encode_record(tokens: np.ndarray) -> bytes:
    tokens = np.asarray(tokens, dtype=np.int32)
    return tokens.tobytes()


def decode_record(blob: bytes) -> np.ndarray:
    return np.frombuffer(blob, dtype=np.int32)
