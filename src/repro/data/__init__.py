from .tokens import decode_record, encode_record
from .synthetic import SyntheticTokenDataset, paper_like_sizes

__all__ = [
    "decode_record",
    "encode_record",
    "SyntheticTokenDataset",
    "paper_like_sizes",
]
