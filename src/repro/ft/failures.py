"""Fault-tolerance harness: heartbeats, failure injection, elastic restart.

Two layers of resilience (DESIGN.md §5):

1. **Training state** — periodic async checkpoints; on a detected failure
   the coordinator restarts survivors from the last step and (optionally)
   reshapes the mesh (``checkpoint.restore_checkpoint`` re-places leaves
   under any target sharding).
2. **Data plane** — the Redox cluster remaps ownership of the dead node's
   abstract chunks (``core.distributed.Cluster.fail_node``), preserving the
   exactly-once epoch guarantee (test-verified).

On real fleets the heartbeat/agreement layer is the cluster manager's job;
here a thread-based monitor demonstrates the control flow and lets tests
inject deterministic failures.
"""

from __future__ import annotations

import dataclasses
import threading
import time

__all__ = ["Heartbeat", "FailureInjector", "StragglerMonitor"]


class Heartbeat:
    """Liveness registry: workers ping; the coordinator polls for the dead."""

    def __init__(self, num_workers: int, timeout_s: float = 5.0):
        self.timeout_s = timeout_s
        self._last = {w: time.monotonic() for w in range(num_workers)}
        self._lock = threading.Lock()

    def ping(self, worker: int) -> None:
        with self._lock:
            self._last[worker] = time.monotonic()

    def dead_workers(self) -> list[int]:
        now = time.monotonic()
        with self._lock:
            return [w for w, t in self._last.items() if now - t > self.timeout_s]

    def mark_dead(self, worker: int) -> None:
        with self._lock:
            self._last[worker] = -1e18


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/benchmarks: {step: worker}."""

    schedule: dict[int, int]

    def maybe_fail(self, step: int) -> int | None:
        return self.schedule.get(step)


def _median(values: "list[float]") -> float:
    """True median: mean of the two middles for even-length input."""
    values = sorted(values)
    mid = len(values) // 2
    if len(values) % 2:
        return values[mid]
    return 0.5 * (values[mid - 1] + values[mid])


class StragglerMonitor:
    """Tracks per-worker step durations; flags workers slower than
    ``threshold`` x the median as stragglers (DESIGN.md §5: the Redox
    loader responds by deepening its prefetch queue for that worker and
    re-routing remote reads away from it).

    The reference for each worker is the *leave-one-out* true median of the
    other workers' window means, so a straggler's own slowness never
    inflates its threshold. (The old upper-middle ``med[len(med)//2]``
    median made a slow worker unflaggable in 2-worker fleets: the reference
    WAS its own mean.) A worker with an **empty window** while peers have
    data is flagged explicitly — no step reports is the strongest straggler
    signal there is.
    """

    def __init__(self, num_workers: int, window: int = 16, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: list[list[float]] = [[] for _ in range(num_workers)]

    def record(self, worker: int, seconds: float) -> None:
        t = self._times[worker]
        t.append(seconds)
        if len(t) > self.window:
            t.pop(0)

    def stragglers(self) -> list[int]:
        means = {
            w: sum(t) / len(t) for w, t in enumerate(self._times) if t
        }
        if not means:
            return []  # no worker has reported yet: no baseline, no flags
        out = []
        for w, t in enumerate(self._times):
            if not t:
                out.append(w)  # peers report, this one is silent
                continue
            others = [m for w2, m in means.items() if w2 != w]
            if others and means[w] > self.threshold * _median(others):
                out.append(w)
        return out
