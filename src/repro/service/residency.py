"""Shared chunk residency: one refcounted byte cache serving many jobs.

The paper's one-time chunk layout is explicitly multi-job ("the pre-organized
data chunks can be re-used to train different models"), and FanStore
(PAPERS.md) shows that a shared, deduplicated cache across trainers is where
the large-scale I/O wins are. :class:`SharedResidency` is that cache for a
:class:`repro.service.DataService`: every session's ``read_chunk`` claims go
through it, and a chunk's bytes are read from storage exactly once per
*residency interval* — from its first claim to its last — no matter how many
jobs consume it.

Two release disciplines, matching the service's two execution modes:

* **Planned refcounts** (replay sessions): ``install_claims`` registers each
  job's exact per-chunk claim counts (from its :class:`EpochPlan`). A chunk
  is released the moment its last planned claim is served — Belady-exact,
  because the plans *are* the future.
* **Liveness** (live ``step``/``per_access`` sessions): a chunk is retained
  while any live session still *needs* it — session ``s`` will load chunk
  ``k`` again iff some file of ``k`` is neither consumed nor resident at
  ``k``'s owner node (a file can only enter memory through its own chunk's
  load). The probe runs at claim time, *before* the claiming session merges
  the chunk into its abstract memory, so live-mode retention is a
  conservative over-approximation: within an epoch it can grow toward the
  dataset size (released at the end-of-epoch sweep) — bound it with
  ``cache_limit_bytes`` when that matters. Replay sessions (the default
  engine) use the exact planned refcounts instead.

An optional ``cache_limit_bytes`` bounds residency; over-limit inserts evict
least-recently-claimed entries (their remaining claims fall back to physical
re-reads, counted in :class:`ServiceStats.evictions`).
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.obs import tracer as trace

from ..core.stats import ServiceStats

__all__ = ["SharedResidency", "session_still_needs"]


def session_still_needs(cluster, chunk: int) -> bool:
    """Exact liveness test: will ``cluster`` load ``chunk`` again this epoch?

    True iff some member file is neither consumed nor currently resident at
    the chunk's owner node. Residency can only be created by loading the
    chunk itself (redirection changes *which* file a slot returns, never
    which chunk a file lives in), so this is an iff, not an approximation.
    """
    plan = cluster.plan
    g = int(plan.group_of_chunk[chunk])
    node = cluster.nodes[int(cluster.owner_of_group[g])]
    files = plan.chunk_files[chunk]
    locs = g * plan.chunk_size + np.arange(plan.chunk_size)
    need = (
        plan.chunk_valid[chunk]
        & ~node.consumed[plan.chunk_files_clipped[chunk]]
        & (node.memory.resident_flat[locs] != files)
    )
    return bool(need.any())


class _Entry:
    __slots__ = ("records", "nbytes", "seq")

    def __init__(self, records, nbytes: int, seq: int):
        self.records = records
        self.nbytes = nbytes
        self.seq = seq


class SharedResidency:
    """Refcount/liveness-managed chunk-byte cache shared by all sessions."""

    def __init__(self, store, *, cache_limit_bytes: "int | None" = None):
        self.store = store
        self.cache_limit_bytes = cache_limit_bytes
        self._entries: "dict[int, _Entry]" = {}
        self._inflight: "dict[int, threading.Event]" = {}
        self._lock = threading.RLock()
        # Planned mode: outstanding claim counts. _refs[k] sums every
        # pool's remaining claims of chunk k; pools are keyed per
        # (job, epoch) so jobs running different epochs concurrently never
        # touch each other's accounting (chunk bytes are epoch-invariant,
        # so cross-epoch refs sharing the one _refs map is correct).
        self._refs: "dict[int, int]" = {}
        self._claims_left: "dict[tuple, dict[int, int]]" = {}
        # Live mode: callback(chunk) -> True while any live session needs it.
        self._liveness = None
        self._seq = 0
        self.cache_bytes = 0
        self.peak_cache_bytes = 0
        self.evictions = 0
        self._job_stats: "dict[object, ServiceStats]" = {}

    # ------------------------------------------------------------ bookkeeping
    def set_liveness(self, fn) -> None:
        self._liveness = fn

    def job_stats(self, job) -> ServiceStats:
        with self._lock:
            return self._job_stats.setdefault(job, ServiceStats())

    @property
    def per_job_stats(self) -> "dict[object, ServiceStats]":
        with self._lock:
            return dict(self._job_stats)

    def is_cached(self, chunk: int) -> bool:
        return chunk in self._entries

    def has_claims(self) -> bool:
        """True while any planned claims are outstanding."""
        with self._lock:
            return bool(self._refs)

    def install_claims(self, job, epoch: int, counts: "dict[int, int]") -> None:
        """Register ``job``'s planned per-chunk claim counts for ``epoch``
        (the plan-time install — keep-first: an existing pool, possibly
        partially drained by a running stream, is left untouched)."""
        key = (job, int(epoch))
        with self._lock:
            if key in self._claims_left:
                return
            self._install_pool_locked(key, counts)

    def begin_epoch_claims(self, job, epoch: int, counts: "dict[int, int]") -> None:
        """Atomically retire ``job``'s claim pools up to and including the
        epoch it is starting (drained ones from completed epochs, stale
        ones from skipped or abandoned epochs) and install the exact pool
        for that epoch. Pools for epochs the job has not reached yet are
        kept — they may have been planned ahead and their refs are what
        pins bytes for the job's future epochs. The sweep runs after the
        install, so entries pinned by the old pool for the *same* epoch
        stay resident through the swap (cross-epoch sharing)."""
        key = (job, int(epoch))
        with self._lock:
            for stale in [
                k for k in self._claims_left
                if k[0] == job and k[1] <= int(epoch)
            ]:
                self._unwind_locked(stale)
            self._install_pool_locked(key, counts)
            self._sweep_locked()

    def _install_pool_locked(self, key, counts: "dict[int, int]") -> None:
        pool: "dict[int, int]" = {}
        for k, n in counts.items():
            k, n = int(k), int(n)
            pool[k] = pool.get(k, 0) + n
            self._refs[k] = self._refs.get(k, 0) + n
        self._claims_left[key] = pool

    def drop_claims(self, job, epoch: "int | None" = None) -> None:
        """Unwind a job's outstanding claims (one epoch, or all of them for
        a closed/killed job) and sweep the cache."""
        with self._lock:
            keys = [
                key for key in self._claims_left
                if key[0] == job and (epoch is None or key[1] == epoch)
            ]
            for key in keys:
                self._unwind_locked(key)
            self._sweep_locked()

    def _unwind_locked(self, key) -> None:
        for k, n in self._claims_left.pop(key, {}).items():
            left = self._refs.get(k, 0) - n
            if left > 0:
                self._refs[k] = left
            else:
                self._refs.pop(k, None)

    def end_epoch(self) -> None:
        """Release everything no longer needed (planned refs drain to zero on
        their own; live-mode entries are re-evaluated here because liveness
        is only probed lazily, at claim time)."""
        with self._lock:
            self._sweep_locked()

    # ----------------------------------------------------------------- claims
    def read_chunk(self, job, chunk: int, *, epoch: "int | None" = None):
        """Serve one chunk claim for ``job`` (consuming epoch ``epoch``):
        shared-cache hit or physical read. Returns the store's
        ``[(file_id, bytes), ...]`` records."""
        chunk = int(chunk)
        tracer = trace.get()
        t0 = time.perf_counter() if tracer is not None else 0.0
        st = self.job_stats(job)
        while True:
            with self._lock:
                e = self._entries.get(chunk)
                if e is not None:
                    self._note_claim_locked(job, epoch, chunk)
                    st.shared_hits += 1
                    st.shared_bytes += e.nbytes
                    self._seq += 1
                    e.seq = self._seq
                    records = e.records
                    self._maybe_release_locked(chunk)
                    if tracer is not None:
                        tracer.complete(
                            "residency.claim", "read", t0,
                            time.perf_counter() - t0,
                            {"chunk": chunk, "hit": True},
                        )
                    return records
                ev = self._inflight.get(chunk)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[chunk] = ev
                    break
            # Another session is already reading this chunk; wait for its
            # insert, then retry (shared hit, or read ourselves if it chose
            # not to retain).
            ev.wait()
        try:
            records = list(self.store.read_chunk(chunk))
        except BaseException:
            with self._lock:
                self._inflight.pop(chunk, None)
            ev.set()
            raise
        nbytes = int(self.store.plan.chunk_bytes[chunk])
        with self._lock:
            self._note_claim_locked(job, epoch, chunk)
            st.physical_reads += 1
            st.physical_bytes += nbytes
            self._inflight.pop(chunk, None)
            if self._retain_locked(chunk):
                self._insert_locked(chunk, records, nbytes)
            ev.set()
        if tracer is not None:
            tracer.complete(
                "residency.claim", "read", t0, time.perf_counter() - t0,
                {"chunk": chunk, "hit": False},
            )
        return records

    # -------------------------------------------------------------- internals
    def _note_claim_locked(self, job, epoch: "int | None", chunk: int) -> None:
        mine = None if epoch is None else self._claims_left.get((job, epoch))
        if mine is None or chunk not in mine:
            return  # live-mode claim (or unplanned repeat): liveness-driven
        mine[chunk] -= 1
        if mine[chunk] <= 0:
            del mine[chunk]
        left = self._refs.get(chunk, 0) - 1
        if left > 0:
            self._refs[chunk] = left
        else:
            self._refs.pop(chunk, None)

    def _retain_locked(self, chunk: int) -> bool:
        if self._refs.get(chunk, 0) > 0:
            return True
        return bool(self._liveness is not None and self._liveness(chunk))

    def _maybe_release_locked(self, chunk: int) -> None:
        if chunk in self._entries and not self._retain_locked(chunk):
            self.cache_bytes -= self._entries.pop(chunk).nbytes

    def _sweep_locked(self) -> None:
        for chunk in list(self._entries):
            self._maybe_release_locked(chunk)

    def _insert_locked(self, chunk: int, records, nbytes: int) -> None:
        limit = self.cache_limit_bytes
        if limit is not None:
            if nbytes > limit:
                return  # a single chunk over the whole budget: never cache
            while self._entries and self.cache_bytes + nbytes > limit:
                lru = min(self._entries, key=lambda k: self._entries[k].seq)
                self.cache_bytes -= self._entries.pop(lru).nbytes
                self.evictions += 1
                trace.instant("residency.evict", "read", chunk=lru)
            if self.cache_bytes + nbytes > limit:
                return
        self._seq += 1
        self._entries[chunk] = _Entry(records, nbytes, self._seq)
        self.cache_bytes += nbytes
        self.peak_cache_bytes = max(self.peak_cache_bytes, self.cache_bytes)
