"""Shared chunk residency: one refcounted byte cache serving many jobs.

The paper's one-time chunk layout is explicitly multi-job ("the pre-organized
data chunks can be re-used to train different models"), and FanStore
(PAPERS.md) shows that a shared, deduplicated cache across trainers is where
the large-scale I/O wins are. :class:`SharedResidency` is that cache for a
:class:`repro.service.DataService`: every session's ``read_chunk`` claims go
through it, and a chunk's bytes are read from storage exactly once per
*residency interval* — from its first claim to its last — no matter how many
jobs consume it.

Two release disciplines, matching the service's two execution modes:

* **Planned refcounts** (replay sessions): ``install_claims`` registers each
  job's exact per-chunk claim counts (from its :class:`EpochPlan`). A chunk
  is released the moment its last planned claim is served — Belady-exact,
  because the plans *are* the future.
* **Liveness** (live ``step``/``per_access`` sessions): a chunk is retained
  while any live session still *needs* it — session ``s`` will load chunk
  ``k`` again iff some file of ``k`` is neither consumed nor resident at
  ``k``'s owner node (a file can only enter memory through its own chunk's
  load). The probe runs at claim time, *before* the claiming session merges
  the chunk into its abstract memory, so live-mode retention is a
  conservative over-approximation: within an epoch it can grow toward the
  dataset size (released at the end-of-epoch sweep) — bound it with
  ``cache_limit_bytes`` when that matters. Replay sessions (the default
  engine) use the exact planned refcounts instead.

**Byte cap + clairvoyant eviction.** An optional ``cache_limit_bytes``
bounds residency. When the cap bites, the default ``eviction="belady"``
policy runs Belady/MIN against the *next-use index*: the service installs
the merged multi-job claim schedule (:meth:`install_schedule` — the same
``merge_read_schedules`` order that drives backend readahead), positions
drain as claims are served, and the evicted entry is the one whose next
planned claim is farthest in the future. Entries with *no* planned next use
(live-mode liveness retention, or drained/unwound plans) are farthest of
all and are evicted first, least-recently-claimed among themselves — so a
live-only service degrades exactly to LRU, and ``eviction="lru"`` forces
that behaviour everywhere (the differential baseline for
``benchmarks/eviction.py``). On a compressed store (DESIGN.md §15) the
cache holds *compressed frames* and each claim decodes its own copy, so
``cache_limit_bytes`` counts compressed bytes — the codec's compression
ratio directly multiplies how many chunks fit under the same cap.
Belady also gates *admission*: an incoming
chunk whose own next use is farther than every resident's is not cached at
all (evicting a sooner-needed chunk for it could only lose). Evicted
claims fall back to physical re-reads (``ServiceStats.evictions``,
attributed to the claiming job); refused inserts are counted as
``ServiceStats.cache_bypass`` — never silently dropped.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.obs import tracer as trace

from ..core.stats import ServiceStats

__all__ = ["SharedResidency", "session_still_needs"]

#: Epoch stride in schedule positions: positions are ``epoch * _EPOCH_STRIDE
#: + index``, so claims of epoch ``e`` always rank before epoch ``e+1``'s
#: (the pump runs epochs in order) while staying plain ints.
_EPOCH_STRIDE = 1 << 40


def session_still_needs(cluster, chunk: int) -> bool:
    """Exact liveness test: will ``cluster`` load ``chunk`` again this epoch?

    True iff some member file is neither consumed nor currently resident at
    the chunk's owner node. Residency can only be created by loading the
    chunk itself (redirection changes *which* file a slot returns, never
    which chunk a file lives in), so this is an iff, not an approximation.
    """
    plan = cluster.plan
    g = int(plan.group_of_chunk[chunk])
    node = cluster.nodes[int(cluster.owner_of_group[g])]
    files = plan.chunk_files[chunk]
    locs = g * plan.chunk_size + np.arange(plan.chunk_size)
    need = (
        plan.chunk_valid[chunk]
        & ~node.consumed[plan.chunk_files_clipped[chunk]]
        & (node.memory.resident_flat[locs] != files)
    )
    return bool(need.any())


class _Entry:
    """One resident chunk: the store's *cacheable* payload — a compressed
    :class:`~repro.core.storage.ChunkFrame` on framed stores, the raw blob
    (or, for stores without the raw/decode split, the decoded record list)
    otherwise. ``nbytes`` is the payload's physical footprint: the byte cap
    counts compressed bytes, which is exactly the codec's capacity win."""

    __slots__ = ("payload", "nbytes", "seq")

    def __init__(self, payload, nbytes: int, seq: int):
        self.payload = payload
        self.nbytes = nbytes
        self.seq = seq


class SharedResidency:
    """Refcount/liveness-managed chunk-byte cache shared by all sessions."""

    def __init__(
        self,
        store,
        *,
        cache_limit_bytes: "int | None" = None,
        eviction: str = "belady",
    ):
        if eviction not in ("belady", "lru"):
            raise ValueError(
                f"unknown eviction policy {eviction!r}; expected 'belady' or 'lru'"
            )
        self.store = store
        self.cache_limit_bytes = cache_limit_bytes
        self.eviction = eviction
        # Stores exposing the raw/decode split (ChunkStore) are cached as
        # compressed payloads and decoded per-claim; anything else (test
        # doubles, wrappers) falls back to caching decoded records.
        self._raw_reader = getattr(store, "read_chunk_raw", None)
        self._decoder = getattr(store, "decode_chunk", None)
        self._framed = bool(getattr(getattr(store, "spec", None), "framed", False))
        self._entries: "dict[int, _Entry]" = {}
        self._inflight: "dict[int, threading.Event]" = {}
        self._lock = threading.RLock()
        # Planned mode: outstanding claim counts. _refs[k] sums every
        # pool's remaining claims of chunk k; pools are keyed per
        # (job, epoch) so jobs running different epochs concurrently never
        # touch each other's accounting (chunk bytes are epoch-invariant,
        # so cross-epoch refs sharing the one _refs map is correct).
        self._refs: "dict[int, int]" = {}
        self._claims_left: "dict[tuple, dict[int, int]]" = {}
        # Next-use index (Belady): per chunk, the ascending schedule
        # positions of its future planned claims, drained head-first as
        # claims are served. Installed per epoch from the merged multi-job
        # claim order; a chunk absent here has no planned next use.
        self._next_use: "dict[int, deque[int]]" = {}
        self._sched_epochs: "set[int]" = set()
        #: Planned claims served so far (positions drained). Exposed for the
        #: eviction property tests, which replay the schedule offline.
        self.claims_drained = 0
        #: When set to a list (tests/benchmarks), every eviction decision is
        #: appended as a dict: victim, its next-use position, the incoming
        #: chunk + position, the residents' positions, and claims_drained —
        #: enough to check the choice against the ground-truth future.
        self.eviction_log: "list[dict] | None" = None
        # Live mode: callback(chunk) -> True while any live session needs it.
        self._liveness = None
        self._seq = 0
        self.cache_bytes = 0
        self.peak_cache_bytes = 0
        self.evictions = 0
        self.cache_bypass = 0
        self._job_stats: "dict[object, ServiceStats]" = {}

    # ------------------------------------------------------------ bookkeeping
    def set_liveness(self, fn) -> None:
        self._liveness = fn

    def job_stats(self, job) -> ServiceStats:
        with self._lock:
            return self._job_stats.setdefault(job, ServiceStats())

    @property
    def per_job_stats(self) -> "dict[object, ServiceStats]":
        with self._lock:
            return dict(self._job_stats)

    def is_cached(self, chunk: int) -> bool:
        return chunk in self._entries

    def has_claims(self) -> bool:
        """True while any planned claims are outstanding."""
        with self._lock:
            return bool(self._refs)

    def install_claims(self, job, epoch: int, counts: "dict[int, int]") -> None:
        """Register ``job``'s planned per-chunk claim counts for ``epoch``
        (the plan-time install — keep-first: an existing pool, possibly
        partially drained by a running stream, is left untouched)."""
        key = (job, int(epoch))
        with self._lock:
            if key in self._claims_left:
                return
            self._install_pool_locked(key, counts)

    def install_schedule(self, epoch: int, claims: "list[int]") -> None:
        """Register the merged multi-job claim *order* for ``epoch`` — the
        Belady next-use index. ``claims`` is ``merge_read_schedules``'s
        output: every planned claim of every replay session, duplicates
        included, in pump lockstep order. Keep-first per epoch, mirroring
        :meth:`install_claims`: a re-plan of an epoch whose schedule is
        already draining must not duplicate positions. The epoch is retired
        (and reinstallable) once no claim pool for it remains — the
        end-of-epoch sweep handles that."""
        epoch = int(epoch)
        with self._lock:
            if epoch in self._sched_epochs:
                return
            self._sched_epochs.add(epoch)
            base = epoch * _EPOCH_STRIDE
            for i, k in enumerate(claims):
                self._next_use.setdefault(int(k), deque()).append(base + i)

    def next_use(self, chunk: int) -> "int | None":
        """The chunk's next planned claim position (None: no planned use)."""
        with self._lock:
            d = self._next_use.get(int(chunk))
            return int(d[0]) if d else None

    def begin_epoch_claims(self, job, epoch: int, counts: "dict[int, int]") -> None:
        """Atomically retire ``job``'s claim pools up to and including the
        epoch it is starting (drained ones from completed epochs, stale
        ones from skipped or abandoned epochs) and install the exact pool
        for that epoch. Pools for epochs the job has not reached yet are
        kept — they may have been planned ahead and their refs are what
        pins bytes for the job's future epochs. The sweep runs after the
        install, so entries pinned by the old pool for the *same* epoch
        stay resident through the swap (cross-epoch sharing)."""
        key = (job, int(epoch))
        with self._lock:
            for stale in [
                k for k in self._claims_left
                if k[0] == job and k[1] <= int(epoch)
            ]:
                self._unwind_locked(stale)
            self._install_pool_locked(key, counts)
            self._sweep_locked()

    def _install_pool_locked(self, key, counts: "dict[int, int]") -> None:
        pool: "dict[int, int]" = {}
        for k, n in counts.items():
            k, n = int(k), int(n)
            pool[k] = pool.get(k, 0) + n
            self._refs[k] = self._refs.get(k, 0) + n
        self._claims_left[key] = pool

    def drop_claims(self, job, epoch: "int | None" = None) -> None:
        """Unwind a job's outstanding claims (one epoch, or all of them for
        a closed/killed job) and sweep the cache."""
        with self._lock:
            keys = [
                key for key in self._claims_left
                if key[0] == job and (epoch is None or key[1] == epoch)
            ]
            for key in keys:
                self._unwind_locked(key)
            self._sweep_locked()

    def _unwind_locked(self, key) -> None:
        for k, n in self._claims_left.pop(key, {}).items():
            left = self._refs.get(k, 0) - n
            if left > 0:
                self._refs[k] = left
            else:
                self._refs.pop(k, None)

    def end_epoch(self) -> None:
        """Release everything no longer needed (planned refs drain to zero on
        their own; live-mode entries are re-evaluated here because liveness
        is only probed lazily, at claim time)."""
        with self._lock:
            self._sweep_locked()

    # ----------------------------------------------------------------- claims
    def _read_physical(self, chunk: int):
        """One storage read, in the store's cacheable form."""
        if self._raw_reader is not None:
            return self._raw_reader(chunk)
        return list(self.store.read_chunk(chunk))

    def _payload_nbytes(self, chunk: int, payload) -> int:
        """Physical footprint of a cacheable payload (compressed bytes on
        framed stores; logical plan bytes for fallback record lists)."""
        if self._raw_reader is None:
            return int(self.store.plan.chunk_bytes[chunk])
        physical = getattr(payload, "physical_bytes", None)
        if physical is not None:
            return int(physical)
        return memoryview(payload).nbytes

    def _decode_claim(self, st: ServiceStats, chunk: int, payload, fidelity):
        """Per-claim decode, outside the lock: every claim of a framed
        chunk decompresses its own copy so the cache itself only ever
        holds compressed bytes."""
        if self._decoder is None:
            records = payload  # fallback stores cache decoded records
        else:
            t0 = time.perf_counter()
            records = self._decoder(chunk, payload, fidelity)
            decode_s = time.perf_counter() - t0
        logical = sum(len(b) for _, b in records)
        with self._lock:
            st.logical_bytes += logical
            if self._framed:
                st.decode_claims += 1
                st.decode_s += decode_s
        return records

    def read_chunk(
        self,
        job,
        chunk: int,
        *,
        epoch: "int | None" = None,
        fidelity: "int | None" = None,
    ):
        """Serve one chunk claim for ``job`` (consuming epoch ``epoch``):
        shared-cache hit or physical read. Returns the store's
        ``[(file_id, bytes), ...]`` records, decoded at the claiming
        session's ``fidelity`` (None: the store's default)."""
        chunk = int(chunk)
        tracer = trace.get()
        t0 = time.perf_counter() if tracer is not None else 0.0
        st = self.job_stats(job)
        while True:
            with self._lock:
                e = self._entries.get(chunk)
                if e is not None:
                    self._note_claim_locked(job, epoch, chunk)
                    st.shared_hits += 1
                    st.shared_bytes += e.nbytes
                    self._seq += 1
                    e.seq = self._seq
                    payload = e.payload
                    self._maybe_release_locked(chunk)
                    hit = True
                    break
                ev = self._inflight.get(chunk)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[chunk] = ev
                    hit = False
                    break
            # Another session is already reading this chunk; wait for its
            # insert, then retry (shared hit, or read ourselves if it chose
            # not to retain).
            ev.wait()
        if hit:
            records = self._decode_claim(st, chunk, payload, fidelity)
            if tracer is not None:
                tracer.complete(
                    "residency.claim", "read", t0,
                    time.perf_counter() - t0,
                    {"chunk": chunk, "hit": True},
                )
            return records
        try:
            payload = self._read_physical(chunk)
            # Decode before insert: the first claim consumes the backend
            # worker's eager decode, so the payload that gets cached is
            # stripped back to compressed bytes only.
            records = self._decode_claim(st, chunk, payload, fidelity)
        except BaseException:
            with self._lock:
                self._inflight.pop(chunk, None)
            ev.set()
            raise
        nbytes = self._payload_nbytes(chunk, payload)
        with self._lock:
            self._note_claim_locked(job, epoch, chunk)
            st.physical_reads += 1
            st.physical_bytes += nbytes
            self._inflight.pop(chunk, None)
            if self._retain_locked(chunk):
                self._insert_locked(job, chunk, payload, nbytes)
            ev.set()
        if tracer is not None:
            tracer.complete(
                "residency.claim", "read", t0, time.perf_counter() - t0,
                {"chunk": chunk, "hit": False},
            )
        return records

    # -------------------------------------------------------------- internals
    def _note_claim_locked(self, job, epoch: "int | None", chunk: int) -> None:
        mine = None if epoch is None else self._claims_left.get((job, epoch))
        if mine is None or chunk not in mine:
            return  # live-mode claim (or unplanned repeat): liveness-driven
        mine[chunk] -= 1
        if mine[chunk] <= 0:
            del mine[chunk]
        left = self._refs.get(chunk, 0) - 1
        if left > 0:
            self._refs[chunk] = left
        else:
            self._refs.pop(chunk, None)
        # Drain the next-use index in step with the claims. Positions are
        # popped smallest-first per chunk — claims of the same chunk are
        # interchangeable across jobs, so per-job attribution of positions
        # is unnecessary.
        d = self._next_use.get(chunk)
        if d:
            d.popleft()
            if not d:
                del self._next_use[chunk]
        self.claims_drained += 1

    def _retain_locked(self, chunk: int) -> bool:
        if self._refs.get(chunk, 0) > 0:
            return True
        return bool(self._liveness is not None and self._liveness(chunk))

    def _maybe_release_locked(self, chunk: int) -> None:
        if chunk in self._entries and not self._retain_locked(chunk):
            self.cache_bytes -= self._entries.pop(chunk).nbytes

    def _sweep_locked(self) -> None:
        for chunk in list(self._entries):
            self._maybe_release_locked(chunk)
        # Prune the next-use index: positions of chunks with no outstanding
        # planned claims are stale by definition (their pools drained or
        # were unwound). Epochs with no remaining pool are retired so a
        # re-run of the same epoch reinstalls a fresh schedule.
        for chunk in [k for k, _ in self._next_use.items()
                      if self._refs.get(k, 0) == 0]:
            del self._next_use[chunk]
        if self._sched_epochs:
            active = {key[1] for key in self._claims_left}
            self._sched_epochs &= active

    # ------------------------------------------------------------- eviction
    def _next_pos_locked(self, chunk: int) -> "int | None":
        d = self._next_use.get(chunk)
        return d[0] if d else None

    def _victim_locked(self) -> "tuple[int, int | None]":
        """The entry the active policy evicts next.

        * ``belady`` — farthest (or absent) next planned use wins; entries
          with no planned use tie-break least-recently-claimed, so a
          live-only cache (no schedule installed) degrades exactly to LRU.
        * ``lru`` — least-recently-claimed, period (the differential
          baseline).
        """
        if self.eviction == "lru":
            victim = min(self._entries, key=lambda k: self._entries[k].seq)
            return victim, self._next_pos_locked(victim)
        best_key, victim, victim_next = None, None, None
        for k, e in self._entries.items():
            nxt = self._next_pos_locked(k)
            # Rank: absent next use beats any position; among absents the
            # smallest seq (least-recently-claimed) wins; among planned
            # entries the farthest position wins.
            key = (1, -e.seq) if nxt is None else (0, nxt)
            if best_key is None or key > best_key:
                best_key, victim, victim_next = key, k, nxt
        return victim, victim_next

    def _bypass_locked(self, st: ServiceStats, chunk: int, reason: str) -> None:
        """Account a refused insert — never a silent drop (DESIGN §13)."""
        self.cache_bypass += 1
        st.cache_bypass += 1
        trace.instant("residency.cache_bypass", "read", chunk=chunk, reason=reason)

    def _insert_locked(self, job, chunk: int, payload, nbytes: int) -> None:
        st = self.job_stats(job)
        limit = self.cache_limit_bytes
        if limit is not None:
            if nbytes > limit:
                # a single chunk over the whole budget: never cacheable
                self._bypass_locked(st, chunk, "oversized")
                return
            incoming_next = self._next_pos_locked(chunk)
            while self._entries and self.cache_bytes + nbytes > limit:
                victim, victim_next = self._victim_locked()
                if self.eviction == "belady" and victim_next is not None and (
                    incoming_next is None or victim_next < incoming_next
                ):
                    # Every resident (the farthest included) is needed
                    # sooner than the incoming chunk: admitting it could
                    # only trade a nearer hit for a farther one. Serve the
                    # claim uncached instead.
                    self._bypass_locked(st, chunk, "farther_next_use")
                    return
                if self.eviction_log is not None:
                    self.eviction_log.append({
                        "victim": victim,
                        "victim_next": victim_next,
                        "incoming": chunk,
                        "incoming_next": incoming_next,
                        "residents": {
                            k: self._next_pos_locked(k) for k in self._entries
                        },
                        "claims_drained": self.claims_drained,
                        "by": job,
                    })
                self.cache_bytes -= self._entries.pop(victim).nbytes
                self.evictions += 1
                st.evictions += 1  # attributed to the claiming job
                trace.instant(
                    "residency.evict", "read",
                    chunk=victim, by=str(job), policy=self.eviction,
                )
            if self.cache_bytes + nbytes > limit:
                self._bypass_locked(st, chunk, "over_limit")
                return
        self._seq += 1
        self._entries[chunk] = _Entry(payload, nbytes, self._seq)
        self.cache_bytes += nbytes
        self.peak_cache_bytes = max(self.peak_cache_bytes, self.cache_bytes)
