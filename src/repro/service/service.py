"""Multi-job data service: one chunk store + shared residency, N training jobs.

Every layer below this one is single-job: a :class:`Cluster` owns its
abstract memory, RNG stream, and epoch state. :class:`DataService` stacks N
of those (one per job — each job keeps its *own* protocol state, sampler and
seed, so its returned stream is exactly what it would be served solo) on top
of ONE physical layer: a single :class:`ChunkStore` fronted by a
:class:`SharedResidency` cache. Redirection is what makes the sharing cheap:
jobs never coordinate *which file* a slot returns, only *which chunk bytes*
back the slot — and those bytes are identical across jobs.

Execution modes:

* ``engine="replay"`` (default): :meth:`DataService.plan_epoch` runs the
  clairvoyant :class:`EpochPlanner` per session, installs exact per-chunk
  claim refcounts on the residency, merges every session's chunk-read
  schedule (``merge_read_schedules``) and hands the deduplicated physical
  order (``first_read_order``) to ``ChunkStore.schedule_reads`` — backend
  readahead stays clairvoyant across *all* jobs at once.
* ``engine="step" | "per_access"``: live walks; the residency retains chunks
  by exact liveness instead of planned refcounts.

**Co-refill** (``co_refill=True``): a pluggable refill-choice hook
(:attr:`LocalNode.refill_filter`) narrows the protocol's uniform tie-break
toward chunks that are already shared-cache resident (free bytes), else
toward chunks another session still needs (the read it forces becomes a
future shared hit). The preference is driven only by *other* jobs'
independent permutations, so each job's returned stream remains a uniform
shuffle (DESIGN.md §9; ``tests/test_randomness_property.py``). Off by
default — with it off, every session's stream is byte-identical to its solo
run, which is what the fault-tolerance tests pin down.

:meth:`DataService.co_epoch` is the shared serving loop: a round-robin pump
that advances every session one step per round (lockstep keeps claim order
equal to the merged plan order) and yields ``(job_id, GlobalBatch)``.
Sessions can instead be consumed independently — ``JobSession.epoch`` /
``epoch_async`` are the familiar loader API — and still share bytes through
the residency.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import Counter
from pathlib import Path

import numpy as np

from repro.obs import tracer as trace

from ..core.loader import RedoxLoader
from ..core.planner import EpochPlan, EpochPlanner, PlanRecorder
from ..core.spec import SessionSpec
from ..core.stats import ServiceStats
from ..core.storage import first_read_order, merge_read_schedules
from .residency import SharedResidency, session_still_needs

__all__ = [
    "AdmissionControl",
    "AdmissionRejected",
    "DataService",
    "JobSession",
]

SERVICE_MANIFEST = "service_manifest.json"


class AdmissionRejected(RuntimeError):
    """``open_session`` refused: admitting the job would push the service's
    predicted aggregate read rate past the storage budget (DESIGN.md §14).
    Relayed typed over the transport wire, so a remote trainer catches
    exactly this class."""


@dataclasses.dataclass(frozen=True)
class AdmissionControl:
    """Storage-bandwidth admission policy for :meth:`DataService.open_session`.

    Redox reads every file exactly once per epoch, so a session's steady
    demand is a pure function of known quantities: the dataset's chunk
    bytes spread over its ``steps_per_epoch`` training steps, one step per
    ``compute_per_step_s`` (the job's measured or modelled step time —
    ``repro.autotune.calibrate`` measures both this and the bandwidth). A
    session is admitted iff

        Σ_admitted epoch_bytes / (steps * compute_per_step_s)  ≤  bandwidth

    The estimate deliberately ignores shared-cache hits — overlap between
    jobs only *lowers* the physical rate, so this is a safe upper bound.

    ``mode="reject"`` raises :class:`AdmissionRejected` immediately;
    ``mode="queue"`` blocks up to ``queue_timeout_s`` for capacity to free
    (sessions closing), then raises the same typed error.
    """

    bandwidth_bytes_per_s: float
    compute_per_step_s: float
    mode: str = "reject"            # "reject" | "queue"
    queue_timeout_s: float = 30.0

    def __post_init__(self):
        if self.mode not in ("reject", "queue"):
            raise ValueError(
                f"unknown admission mode {self.mode!r}; "
                "expected 'reject' or 'queue'"
            )


class _SessionStore:
    """Per-session facade over the shared store: reads go through the
    residency under the session's job id; the merged-schedule install is
    service-owned, so the per-plan ``schedule_reads`` becomes a no-op."""

    def __init__(self, service: "DataService", job_id):
        self._service = service
        self._job = job_id
        self._real = service.store
        #: Per-session progressive fidelity (DESIGN.md §15): set by
        #: ``RedoxLoader.from_spec`` when the session's spec asks for
        #: truncated bands; claims decode at this fidelity without
        #: affecting other sessions sharing the store.
        self.default_fidelity: "int | None" = None

    @property
    def plan(self):
        return self._real.plan

    @property
    def spec(self):
        """The shared store's StoreSpec (None for spec-less store doubles)."""
        return getattr(self._real, "spec", None)

    @property
    def backend_stats(self):
        return self._real.backend_stats

    @property
    def wants_prefetch(self) -> bool:
        return self._real.wants_prefetch

    @property
    def has_schedule(self) -> bool:
        return self._real.has_schedule

    def prefetch_chunks(self, chunks) -> None:
        self._real.prefetch_chunks(chunks)

    def read_chunk(self, chunk: int):
        return self._service._read_chunk(
            self._job, chunk, fidelity=self.default_fidelity
        )

    def read_file(self, file_id: int):
        return self._real.read_file(file_id)

    def schedule_reads(self, chunks) -> None:
        pass  # the service installs ONE merged schedule on the real store

    def close(self) -> None:
        pass  # the service (or its creator) owns the real store


class JobSession:
    """One job's view of the service: a thin single-job loader session."""

    def __init__(self, service: "DataService", job_id, cluster, sampler, loader):
        self.service = service
        self.job_id = job_id
        self.cluster = cluster
        self.sampler = sampler
        self.loader = loader
        self.closed = False

    @property
    def engine(self) -> str:
        return self.loader.engine

    @property
    def spec(self) -> SessionSpec:
        """The SessionSpec describing this session's loader stack."""
        return self.loader.spec

    @property
    def last_plan(self):
        return self.loader.last_plan

    @property
    def stats(self) -> ServiceStats:
        """This job's shared-residency counters."""
        return self.service.residency.job_stats(self.job_id)

    def steps_per_epoch(self, epoch: int = 0) -> int:
        return self.loader.steps_per_epoch(epoch)

    def epoch(self, epoch: int):
        """Yield this job's GlobalBatches. The service plans its epoch on
        first touch, so independently consumed sessions still share bytes."""
        for item in self._produce_guarded(epoch):
            yield self.loader._assemble(*item)

    def epoch_async(self, epoch: int):
        """Double-buffered variant — safe to consume from a per-job thread;
        the shared residency and the service planner are lock-protected.
        For live (``step``/``per_access``) sessions under concurrent
        threads, the liveness probe reads other sessions' evolving cluster
        state unsynchronised: streams stay exact, but retention becomes
        approximate (a stale read may cost a redundant re-read or hold a
        chunk longer). Replay sessions (the default) use claim refcounts
        and are exact under concurrency."""
        plan = self._begin_epoch(epoch)
        try:
            yield from self.loader.epoch_async(epoch, plan=plan)
        finally:
            self._end_epoch(epoch)

    def _produce_guarded(self, epoch: int):
        """The session's raw step stream with claim bookkeeping around it
        (shared by :meth:`epoch` and the service pump)."""
        plan = self._begin_epoch(epoch)
        try:
            for item in self.loader._produce(epoch, plan=plan):
                # Keep the loader's suspend cursor exact for pump-driven
                # sessions: the step in hand is consumed the moment this
                # generator returns from next().
                self.loader._progress = (epoch, int(item[1]) + 1)
                yield item
        finally:
            self._end_epoch(epoch)

    def _begin_epoch(self, epoch: int):
        """Resolve this epoch's plan and (re)install the job's exact claim
        pool — full-epoch totals even when a previous run of the same epoch
        was abandoned with the pool partially drained."""
        svc = self.service
        plan = svc._plan_for(self, epoch)
        with svc._lock:
            svc._active_epoch[self.job_id] = epoch
            if plan is not None:
                svc.residency.begin_epoch_claims(
                    self.job_id, epoch, Counter(plan.load_chunk.tolist())
                )
        return plan

    def _end_epoch(self, epoch: int) -> None:
        """Retire this job's claim pool for ``epoch``: a completed epoch
        drained it to zero (removing the key lets a re-run's plan-time
        install register fresh full counts); an abandoned one left it
        under-counting the remaining reads, so unwinding it keeps other
        sessions' residency exact."""
        svc = self.service
        svc._active_epoch.pop(self.job_id, None)
        svc.residency.drop_claims(self.job_id, epoch)

    def close(self) -> None:
        self.service.close_session(self.job_id)


class DataService:
    """One shared chunk cache serving many concurrent training jobs."""

    def __init__(
        self,
        store,
        *,
        cache_limit_bytes: "int | None" = None,
        co_refill: bool = False,
        eviction: str = "belady",
        admission: "AdmissionControl | None" = None,
    ):
        self.store = store
        self.plan = store.plan
        self.co_refill = co_refill
        self.admission = admission
        self.residency = SharedResidency(
            store, cache_limit_bytes=cache_limit_bytes, eviction=eviction
        )
        self.residency.set_liveness(self._live_sessions_need)
        # Serialises planning and claim (un)installs: sessions consumed from
        # concurrent threads must not interleave plan_epoch runs.
        self._lock = threading.RLock()
        self._sessions: "dict[object, JobSession]" = {}
        # Plans are cached per epoch (pure functions of (session, epoch), so
        # re-runs reuse them); only the newest few epochs are kept.
        self._epoch_plans: "dict[int, dict[object, EpochPlan]]" = {}
        self._active_epoch: "dict[object, int]" = {}
        # Admission bookkeeping: predicted bytes/s per admitted job, and a
        # condition close_session notifies so queued opens can re-check.
        self._admitted_rates: "dict[object, float]" = {}
        self._admission_cv = threading.Condition()
        self.last_plan_time_s = 0.0

    # ------------------------------------------------------------- sessions
    def open_session(
        self,
        job_id,
        spec: "SessionSpec | None" = None,
        *,
        resume_from: "str | Path | None" = None,
        **kwargs,
    ) -> JobSession:
        """Open a job session with its own protocol state and RNG stream.

        ``spec`` is the :class:`~repro.core.spec.SessionSpec` describing the
        session — the same object a standalone
        ``RedoxLoader.from_spec(spec, store)`` accepts and the transport
        wire protocol carries; a single-session service run is
        byte-identical to that solo run (``tests/test_service.py``).

        The legacy keyword spelling (``policy=``, ``seed=``,
        ``batch_per_node=``, ... plus the ``use_planner`` alias) is kept as
        a deprecation shim: keywords are folded into a SessionSpec via
        :meth:`SessionSpec.from_kwargs`.

        ``resume_from`` re-opens a session suspended by
        :meth:`DataService.suspend`: the cluster is restored from the saved
        snapshot (every protocol argument is taken from the files, not from
        ``spec``) and the session's next epoch continues at the saved step.
        """
        if spec is None:
            spec = SessionSpec.from_kwargs(**kwargs)  # deprecation shim
        elif kwargs:
            raise TypeError(
                "pass either a SessionSpec or the legacy keyword form, not "
                f"both (got spec and {sorted(kwargs)})"
            )
        with self._lock:
            if job_id in self._sessions:
                raise ValueError(f"job {job_id!r} already has an open session")
        if resume_from is not None:
            # Same restore path as a standalone loader — only the store
            # differs (reads route through the shared residency).
            loader = RedoxLoader.resume(resume_from, _SessionStore(self, job_id))
        else:
            loader = RedoxLoader.from_spec(spec, _SessionStore(self, job_id))
        if self.admission is not None:
            self._admit(job_id, loader)  # raises AdmissionRejected
        session = JobSession(
            self, job_id, loader.cluster, loader.sampler, loader
        )
        if self.co_refill:
            self._install_refill_filter(session)
        with self._lock:
            if job_id in self._sessions:
                raise ValueError(f"job {job_id!r} already has an open session")
            # Copy-on-write: the residency's liveness callback iterates the
            # session map from reader threads WITHOUT the service lock
            # (taking it there would invert the residency/service lock
            # order) — so mutations swap in a fresh dict instead.
            self._sessions = {**self._sessions, job_id: session}
        self.residency.job_stats(job_id)  # materialise the per-job counters
        return session

    # ------------------------------------------------------------ admission
    def _session_rate(self, loader) -> float:
        """Predicted steady read demand of one session, bytes/s: the dataset
        read exactly once per epoch (the Redox invariant), spread over the
        session's steps at the admission policy's per-step compute time."""
        steps = loader.steps_per_epoch(0)
        if steps <= 0:
            return 0.0
        epoch_bytes = float(np.asarray(self.plan.chunk_bytes).sum())
        return epoch_bytes / (steps * self.admission.compute_per_step_s)

    def _admit(self, job_id, loader) -> None:
        adm = self.admission
        rate = self._session_rate(loader)
        deadline = time.monotonic() + adm.queue_timeout_s
        with self._admission_cv:
            while True:
                admitted = sum(self._admitted_rates.values())
                if admitted + rate <= adm.bandwidth_bytes_per_s:
                    self._admitted_rates[job_id] = rate
                    trace.instant(
                        "service.admit", "service", job=str(job_id),
                        rate=rate, admitted=admitted + rate,
                    )
                    return
                detail = (
                    f"job {job_id!r} needs {rate / 1e6:.1f} MB/s; "
                    f"{admitted / 1e6:.1f} MB/s of the "
                    f"{adm.bandwidth_bytes_per_s / 1e6:.1f} MB/s storage "
                    f"budget is already committed to "
                    f"{len(self._admitted_rates)} job(s)"
                )
                remaining = deadline - time.monotonic()
                if adm.mode == "reject" or remaining <= 0:
                    trace.instant(
                        "service.admission_rejected", "service",
                        job=str(job_id), rate=rate, admitted=admitted,
                    )
                    queued = "" if adm.mode == "reject" else (
                        f" (queued {adm.queue_timeout_s:.0f}s without "
                        f"capacity freeing)"
                    )
                    raise AdmissionRejected(detail + queued)
                self._admission_cv.wait(timeout=min(remaining, 0.5))

    def admission_report(self) -> "dict | None":
        """The admission plane's live view (None when admission is off)."""
        if self.admission is None:
            return None
        with self._admission_cv:
            rates = dict(self._admitted_rates)
        return {
            "bandwidth_bytes_per_s": self.admission.bandwidth_bytes_per_s,
            "compute_per_step_s": self.admission.compute_per_step_s,
            "mode": self.admission.mode,
            "admitted_bytes_per_s": sum(rates.values()),
            "per_job_bytes_per_s": {str(j): r for j, r in rates.items()},
        }

    def close_session(self, job_id) -> None:
        """Close a session (mid-epoch kills included): its outstanding claim
        refcounts are unwound so other jobs' residency is unaffected, and
        the job id becomes reusable (a restarted job reopens under the same
        id with fresh protocol state; its ServiceStats keep accumulating)."""
        with self._lock:
            session = self._sessions.get(job_id)
            if session is None or session.closed:
                return
            remaining = dict(self._sessions)
            del remaining[job_id]
            self._sessions = remaining  # copy-on-write, see open_session
            session.closed = True
            self._active_epoch.pop(job_id, None)
            for plans in self._epoch_plans.values():
                plans.pop(job_id, None)
            self.residency.drop_claims(job_id)
        with self._admission_cv:
            if self._admitted_rates.pop(job_id, None) is not None:
                self._admission_cv.notify_all()  # wake queued open_sessions

    @property
    def sessions(self) -> "list[JobSession]":
        return [s for s in self._sessions.values() if not s.closed]

    def session(self, job_id) -> JobSession:
        try:
            return self._sessions[job_id]
        except KeyError:
            raise KeyError(
                f"no open session for job {job_id!r} (open sessions: "
                f"{sorted(map(repr, self._sessions)) or 'none'}); "
                "open_session() it first — a closed job's id is reusable"
            ) from None

    def close(self) -> None:
        """Close every session. Idempotent: a second close() (or a close()
        racing individual close_session calls) is a no-op."""
        for job_id in list(self._sessions):
            self.close_session(job_id)
        self.residency.end_epoch()

    # ------------------------------------------------------ suspend/resume
    def suspend(self, out_dir: "str | Path") -> Path:
        """Atomically checkpoint every open session's data-plane state.

        Call with no stream mid-flight (the pump abandoned or between
        epochs): each session writes its loader suspend files
        (``RedoxLoader.suspend`` — a derived shadow snapshot for replay
        sessions, the live cluster state otherwise) under one directory,
        plus a service manifest. Shared-residency claims are *not*
        serialized — they are a pure function of the per-session plans and
        cursors, and :meth:`resume`'s plan_epoch reinstalls exactly the
        remaining claim counts.
        """
        with self._lock:
            assert not self._active_epoch, (
                "suspend() with a session stream mid-flight; abandon the "
                "pump (or finish the epoch) first"
            )
            sessions = self.sessions
        if self.co_refill and any(s.engine == "replay" for s in sessions):
            # A replay session's snapshot is derived on a filter-less solo
            # shadow (EpochPlanner.state_at); under co-refill the executed
            # prefix followed the jointly-planned tie-breaks instead, so the
            # derived state would not match what was actually consumed —
            # refuse rather than resume a diverging stream. Live-engine
            # co-refill sessions snapshot their real state and are fine.
            raise NotImplementedError(
                "suspend() of a co_refill service with replay sessions is "
                "not supported: their snapshots are derived by solo shadow "
                "simulation, which diverges from the jointly-planned "
                "co-refill prefix; use co_refill=False or live engines"
            )
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        jobs = []
        for i, s in enumerate(sessions):
            sub = f"session_{i:03d}"
            s.loader.suspend(out_dir / sub)
            jobs.append({"job_id": s.job_id, "dir": sub})
        (out_dir / SERVICE_MANIFEST).write_text(json.dumps(dict(
            co_refill=self.co_refill,
            cache_limit_bytes=self.residency.cache_limit_bytes,
            jobs=jobs,
        )))
        return out_dir

    @classmethod
    def resume(cls, in_dir: "str | Path", store, **overrides) -> "DataService":
        """Rebuild a suspended service — sessions, protocol state, and the
        exact remaining residency claims — from :meth:`suspend` files in a
        fresh process holding only the re-opened ChunkStore."""
        in_dir = Path(in_dir)
        mf = json.loads((in_dir / SERVICE_MANIFEST).read_text())
        svc = cls(
            store,
            cache_limit_bytes=overrides.pop(
                "cache_limit_bytes", mf.get("cache_limit_bytes")
            ),
            co_refill=overrides.pop("co_refill", mf.get("co_refill", False)),
        )
        for job in mf["jobs"]:
            svc.open_session(job["job_id"], resume_from=in_dir / job["dir"])
        return svc

    # ------------------------------------------------------------- planning
    _PLAN_EPOCHS_KEPT = 4  # newest epochs whose plans/claims stay cached

    def plan_epoch(self, epoch: int) -> "dict[object, EpochPlan]":
        """Plan every replay session's epoch and fuse the I/O schedules.

        Runs :class:`EpochPlanner` per session (jointly, on interleaved
        shadow clusters, when co-refill is on — the hook's preferences are
        themselves part of the plan), installs each session's exact claim
        refcounts on the residency (keyed per (job, epoch) — jobs running
        different epochs concurrently never disturb each other), and hands
        the merged deduplicated physical read order to the storage backend.
        Plans are cached; re-planning an epoch only fills sessions that do
        not have a plan yet (e.g. opened later). Live-engine sessions are
        skipped: their reads are not knowable up front and use liveness
        retention instead.
        """
        t0 = time.perf_counter()
        with self._lock:
            sessions = [s for s in self.sessions if s.engine == "replay"]
            if not sessions:
                return {}
            plans = self._epoch_plans.setdefault(epoch, {})
            missing = [s for s in sessions if s.job_id not in plans]
            if missing:
                # Sessions resumed mid-epoch get *suffix* plans cut from
                # their snapshots — their claim counts are exactly the
                # remaining reads, so the shared residency stays exact
                # across a suspend/resume of the whole service.
                resumed = {
                    s.job_id: s.loader._resume
                    for s in missing
                    if s.loader._resume is not None
                    and s.loader._resume["epoch"] == epoch
                }
                if self.co_refill and len(missing) > 1 and not resumed:
                    fresh = self._joint_plan(missing, epoch)
                else:
                    fresh = {}
                    for s in missing:
                        rp = resumed.get(s.job_id)
                        if rp is not None:
                            fresh[s.job_id] = EpochPlanner(s.cluster).plan_from(
                                rp["snapshot"]
                            )
                        else:
                            fresh[s.job_id] = EpochPlanner(s.cluster).plan(
                                s.sampler, epoch, s.loader.batch_per_node,
                                stepping="floor_tail",
                            )
                plans.update(fresh)
            claims = merge_read_schedules(
                [_per_step_chunks(plans[s.job_id]) for s in sessions
                 if s.job_id in plans]
            )
            # The same merged order, duplicates included, is the Belady
            # next-use index: the residency drains it claim by claim and
            # always knows each resident chunk's next planned use.
            self.residency.install_schedule(epoch, claims)
            for s in sessions:
                if s.job_id in plans:
                    self.residency.install_claims(
                        s.job_id, epoch,
                        Counter(plans[s.job_id].load_chunk.tolist()),
                    )
            # Installing a schedule REPLACES the backend's current one
            # (discarding its in-flight readahead), so only do it while no
            # session is mid-stream — a late planner (job opened/advancing
            # while others run) must not clobber their exact readahead.
            if not self._active_epoch:
                self.store.schedule_reads(first_read_order(claims))
            self._prune_plans_locked()
            self.last_plan_time_s = time.perf_counter() - t0
            return dict(plans)

    def _prune_plans_locked(self) -> None:
        while len(self._epoch_plans) > self._PLAN_EPOCHS_KEPT:
            oldest = min(self._epoch_plans)
            for job_id in self._epoch_plans.pop(oldest):
                # never-started pools of the pruned epoch must not pin bytes
                if self._active_epoch.get(job_id) != oldest:
                    self.residency.drop_claims(job_id, oldest)

    def _plan_for(self, session: JobSession, epoch: int):
        """The session's plan for ``epoch``, planning the service's epoch on
        first touch — independently consumed sessions (``JobSession.epoch``)
        share bytes without the caller invoking :meth:`plan_epoch` by hand."""
        if session.engine != "replay":
            return None
        with self._lock:
            plan = self._epoch_plans.get(epoch, {}).get(session.job_id)
            if plan is None:
                plan = self.plan_epoch(epoch).get(session.job_id)
            return plan

    def _read_chunk(self, job_id, chunk: int, fidelity: "int | None" = None):
        """Session-store read path: claims land in the pool of the epoch the
        job is currently consuming."""
        return self.residency.read_chunk(
            job_id, chunk,
            epoch=self._active_epoch.get(job_id), fidelity=fidelity,
        )

    def _joint_plan(self, sessions, epoch):
        """Interleaved co-refill planning: every session's shadow cluster is
        advanced one step per round (the pump's lockstep), with refill hooks
        consulting a simulated shared cache and the other shadows' exact
        liveness — so the plans already contain the co-refill decisions."""
        shadows = [s.cluster.planning_clone() for s in sessions]
        sim_cached: "set[int]" = set()

        def shadow_needs(i: int, chunk: int) -> bool:
            return session_still_needs(shadows[i], chunk)

        def on_load(chunk: int) -> None:
            # Retention re-check mirrors SharedResidency: cached while any
            # shadow (including the loader, pre-release) still needs it.
            if any(shadow_needs(i, chunk) for i in range(len(shadows))):
                sim_cached.add(chunk)
            else:
                sim_cached.discard(chunk)

        def make_filter(me: int):
            def filt(group, chunk_ids):
                return self._preferred_chunks(
                    chunk_ids,
                    cached=lambda k: k in sim_cached,
                    wanted=lambda k: any(
                        shadow_needs(i, k) for i in range(len(shadows)) if i != me
                    ),
                    job=sessions[me].job_id,
                )
            return filt

        recs = []
        for i, shadow in enumerate(shadows):
            rec = _JointRecorder(on_load)
            recs.append(rec)
            for node in shadow.nodes:
                node.refill_filter = make_filter(i)
        gens = [
            shadow.epoch_stream(
                s.sampler, epoch, s.loader.batch_per_node,
                stepping="floor_tail", recorder=recs[i],
            )
            for i, (s, shadow) in enumerate(zip(sessions, shadows))
        ]
        steps = [0] * len(sessions)
        done = [False] * len(sessions)
        while not all(done):
            for i, gen in enumerate(gens):
                if done[i]:
                    continue
                try:
                    step, _, _, _ = next(gen)
                    steps[i] = step + 1
                except StopIteration:
                    done[i] = True
        plans = {}
        for i, s in enumerate(sessions):
            plan = EpochPlan.from_recorder(
                recs[i],
                epoch=epoch,
                batch_per_node=s.loader.batch_per_node,
                num_nodes=shadows[i].num_nodes,
                stepping="floor_tail",
                num_steps=steps[i],
                node_stats=[n.stats for n in shadows[i].nodes],
            )
            plans[s.job_id] = plan
        return plans

    # ------------------------------------------------------------ co-refill
    def _install_refill_filter(self, session: JobSession) -> None:
        def filt(group, chunk_ids, _job=session.job_id):
            return self._preferred_chunks(
                chunk_ids,
                cached=self.residency.is_cached,
                wanted=lambda k: any(
                    session_still_needs(s.cluster, k)
                    for s in self.sessions
                    if s.job_id != _job and s.engine != "replay"
                ),
                job=_job,
            )
        for node in session.cluster.nodes:
            node.refill_filter = filt

    def _preferred_chunks(self, chunk_ids, *, cached, wanted, job):
        """Co-refill preference over the protocol's tie-break pool.

        Only chunks some OTHER session still needs are ever preferred — the
        preference is a function of the other jobs' (independent) states,
        never of the choosing job's own history, which is what keeps each
        job's stream a uniform shuffle (DESIGN.md §9) and makes a solo
        session's co-refill a no-op (byte-identical to its solo run).
        Among the other-needed candidates, ones whose bytes are already
        shared-cache resident come first (consume before produce).
        """
        ids = [int(k) for k in np.asarray(chunk_ids).tolist()]
        shareable = [k for k in ids if wanted(k)]
        chosen = [k for k in shareable if cached(k)] or shareable
        if not chosen or len(chosen) == len(ids):
            return None  # no narrowing: tie-break stays untouched
        self.residency.job_stats(job).co_refill_hits += 1
        return np.asarray(chosen, dtype=np.int64)

    def _live_sessions_need(self, chunk: int) -> bool:
        """Residency liveness: some live-engine session still needs ``chunk``.
        Replay sessions are excluded — their cluster state does not evolve
        during replay; planned claim refcounts cover them exactly."""
        return any(
            session_still_needs(s.cluster, chunk)
            for s in self.sessions
            if s.engine != "replay"
        )

    # -------------------------------------------------------------- serving
    def co_epoch(
        self,
        epoch: int,
        *,
        ready=None,
        admit=None,
        idle=None,
        on_done=None,
        raw: bool = False,
    ):
        """THE shared serving loop: round-robin pump over all open sessions.

        Yields ``(job_id, GlobalBatch)``; each session advances one training
        step per round, so co-scheduled jobs stay in lockstep and the claim
        order matches the merged plan order (maximal schedule hits).
        Sessions closed mid-epoch (``close_session``) are detached at the
        next round; the survivors' streams are unaffected.

        Rounds are cursor-aware: a pump abandoned mid-round (suspend) left
        some sessions one step ahead, so the resumed pump serves the lagging
        sessions first — the combined (job, step) stream continues exactly
        where the suspended one stopped.

        The transport server hooks (all default-off; in-process behaviour is
        unchanged without them):

        * ``ready(session) -> bool`` — per-session backpressure: a session
          that is not ready (its shared-memory ring is full) is *skipped*
          this round instead of served; its cursor does not advance, so
          lockstep degrades gracefully and snaps back once it drains.
          Per-job streams stay exact under skipping — sharing rides the
          planned claim refcounts, not the serving order (only backend
          schedule hit-rate can degrade). Pass ``idle`` too: a round where
          no session is ready calls ``idle()`` (sleep / abort check)
          instead of busy-spinning.
        * ``admit() -> iterable[JobSession]`` — dynamic membership: called
          each round; returned sessions not yet in the pump join it
          mid-epoch (planned on entry, claims installed, cursor-aware for
          resumed sessions). When ``admit`` is given the pump STARTS EMPTY
          and ends once every admitted session finished and ``admit``
          returns nothing new.
        * ``on_done(session)`` — fires when a session's epoch completes
          (the server writes its end-of-epoch sentinel there).
        * ``raw=True`` — yield ``(session, (payloads, step, io, returned))``
          instead of assembled batches (the server encodes frames straight
          from the raw step, so token bytes are copied once into the ring).
        """
        gens, cursors = {}, {}
        live: "list[JobSession]" = []

        def _attach(s):
            gens[s.job_id] = s._produce_guarded(epoch)
            cursors[s.job_id] = (
                s.loader._resume["start_step"]
                if s.loader._resume is not None
                and s.loader._resume["epoch"] == epoch
                else 0
            )
            # Pin every loader's suspend cursor up front: a pump abandoned
            # before reaching some session must still be able to suspend it
            # (at the point it would have continued from).
            s.loader._progress = (epoch, cursors[s.job_id])
            live.append(s)

        def _admit():
            fresh = [
                s for s in admit()
                if s.job_id not in gens and not s.closed
            ]
            if any(s.engine == "replay" for s in fresh):
                # plan_epoch only fills sessions without a cached plan, so
                # late joiners plan without disturbing running sessions.
                self.plan_epoch(epoch)
            for s in fresh:
                _attach(s)

        if admit is None:
            sessions = self.sessions
            if any(s.engine == "replay" for s in sessions):
                self.plan_epoch(epoch)  # cached plans reused; claims reinstalled
            for s in sessions:
                _attach(s)
        try:
            while True:
                if admit is not None:
                    _admit()
                for s in list(live):  # detach sessions closed between rounds
                    if s.closed:
                        live.remove(s)
                        gens[s.job_id].close()
                if not live:
                    break
                candidates = (
                    live if ready is None else [s for s in live if ready(s)]
                )
                if not candidates:
                    if idle is not None:
                        idle()
                    continue
                round_ = min(cursors[s.job_id] for s in candidates)
                for s in list(live):
                    if s.closed:
                        live.remove(s)
                        gens[s.job_id].close()
                        continue
                    if s not in candidates or cursors[s.job_id] != round_:
                        continue
                    try:
                        with trace.span(
                            "service.pump", "service",
                            job=str(s.job_id), round=round_,
                        ):
                            item = next(gens[s.job_id])
                    except StopIteration:
                        live.remove(s)
                        if on_done is not None:
                            on_done(s)
                        continue
                    cursors[s.job_id] = int(item[1]) + 1
                    if raw:
                        yield s, item
                    else:
                        yield s.job_id, s.loader._assemble(*item)
        finally:
            for s in live:  # consumer abandoned the pump mid-epoch
                gens[s.job_id].close()
                # close() on a never-started generator does not run its
                # body, so _end_epoch never fires for sessions the pump
                # had not reached — retire their plan-time claims here
                # (a no-op for sessions whose generator did clean up).
                self.residency.drop_claims(s.job_id, epoch)
            self.residency.end_epoch()

    # ---------------------------------------------------------------- stats
    def aggregate_stats(self) -> ServiceStats:
        """Whole-service counters. Evictions and cache bypasses are
        attributed to the claiming job at the point of decision (the insert
        that forced them), so the per-job merge sums to the service totals —
        no global overwrite, no K-fold double count when a consumer sums the
        per-job reports. ``peak_cache_bytes`` is the one genuinely
        service-global quantity (cache residency is shared), so it comes
        from the residency, not from max-ing per-job copies (which are 0)."""
        out = ServiceStats()
        for st in self.residency.per_job_stats.values():
            out = out.merge(st)
        out.peak_cache_bytes = self.residency.peak_cache_bytes
        return out

    def stats_report(self) -> dict:
        """Per-job and aggregate counters (the BENCH/CLI-facing view).

        ``per_job`` holds only what each job caused (its evictions are the
        ones *its* inserts forced); cache-wide state lives in the distinct
        ``service`` record, so summing per-job rows never double-counts
        cache pressure.
        """
        per_job = self.residency.per_job_stats
        agg = self.aggregate_stats()
        res = self.residency
        report = {
            "per_job": {str(j): st.to_dict() for j, st in per_job.items()},
            "bytes_per_job": {
                str(j): st.physical_bytes + st.shared_bytes
                for j, st in per_job.items()
            },
            # dup_loads_avoided is a derived @property, so it rides on top
            # of the round-trippable field dict.
            "aggregate": {
                **agg.to_dict(), "dup_loads_avoided": agg.dup_loads_avoided,
            },
            "service": {
                "eviction": res.eviction,
                "evictions": res.evictions,
                "cache_bypass": res.cache_bypass,
                "cache_bytes": res.cache_bytes,
                "peak_cache_bytes": res.peak_cache_bytes,
                "cache_limit_bytes": res.cache_limit_bytes,
            },
        }
        admission = self.admission_report()
        if admission is not None:
            report["admission"] = admission
        return report


class _JointRecorder(PlanRecorder):
    """PlanRecorder that also reports each load to the joint-planning sim."""

    def __init__(self, on_load_cb):
        super().__init__()
        self._cb = on_load_cb

    def on_load(self, owner, chunk, fill_rate, files):
        super().on_load(owner, chunk, fill_rate, files)
        self._cb(int(chunk))


def _per_step_chunks(plan: EpochPlan) -> "list[list[int]]":
    """Plan loads bucketed by step (tail pseudo-step included)."""
    depth = plan.num_steps + (1 if plan.has_tail else 0)
    return [
        plan.load_chunk[slice(*plan.load_range(step))].tolist()
        for step in range(depth)
    ]
