"""Multi-job data service: one shared chunk cache serving N training jobs.

See :mod:`repro.service.service` for the architecture. Quick tour::

    store = ChunkStore.open(root)
    svc = DataService(store, co_refill=True)
    for j in range(3):
        svc.open_session(f"job{j}", SessionSpec(seed=j, batch_per_node=16))
    for job_id, batch in svc.co_epoch(epoch=0):
        ...  # each job's stream is its own uniform exactly-once shuffle
    print(svc.stats_report()["aggregate"])  # shared_hits, dup_loads_avoided

Out-of-process serving (:mod:`repro.service.transport`)::

    DataServiceServer(svc, sock_path).start()      # server process
    client = RedoxClient(sock_path, spec, job_id="job0")   # trainer process
    for batch in client.epoch(0): ...              # byte-identical stream
"""

from .residency import SharedResidency, session_still_needs
from .service import (
    AdmissionControl,
    AdmissionRejected,
    DataService,
    JobSession,
)
from .transport import (
    DataServiceServer,
    RedoxClient,
    ServiceSuspended,
    SessionClosed,
    TransportError,
)

__all__ = [
    "AdmissionControl",
    "AdmissionRejected",
    "DataService",
    "DataServiceServer",
    "JobSession",
    "RedoxClient",
    "ServiceSuspended",
    "SessionClosed",
    "SharedResidency",
    "TransportError",
    "session_still_needs",
]
