"""Multi-job data service: one shared chunk cache serving N training jobs.

See :mod:`repro.service.service` for the architecture. Quick tour::

    store = ChunkStore.open(root)
    svc = DataService(store, co_refill=True)
    for j in range(3):
        svc.open_session(f"job{j}", seed=j, batch_per_node=16, seq_len=128)
    for job_id, batch in svc.co_epoch(epoch=0):
        ...  # each job's stream is its own uniform exactly-once shuffle
    print(svc.stats_report()["aggregate"])  # shared_hits, dup_loads_avoided
"""

from .residency import SharedResidency, session_still_needs
from .service import DataService, JobSession

__all__ = ["DataService", "JobSession", "SharedResidency", "session_still_needs"]
