"""JSON-lines control plane over a Unix domain socket (DESIGN.md §11).

Control traffic is tiny and rare (session lifecycle, epoch boundaries,
heartbeats) so it rides newline-delimited JSON: one request object per
line, one response object per line, strictly request/response (the client
holds a lock, so at most one RPC is in flight per connection). Batch
payloads never touch the socket — they flow through the per-session
shared-memory ring (:mod:`.ring`).

Requests look like ``{"op": "begin_epoch", "epoch": 3}``; responses are
``{"ok": true, ...}`` or ``{"ok": false, "error": "...", "kind": "..."}``
where ``kind`` names the exception class the client should raise.
"""

from __future__ import annotations

import json
import socket
import time

__all__ = [
    "JsonChannel",
    "TransportError",
    "ServiceSuspended",
    "SessionClosed",
    "connect_unix",
    "error_response",
    "raise_for",
]


class TransportError(RuntimeError):
    """Control-plane failure: server gone, protocol error, or a server-side
    exception relayed over the wire."""


class ServiceSuspended(TransportError):
    """The data service suspended itself (checkpointed); reconnect to a
    resumed server with ``RedoxClient(..., resume_from=...)``."""


class SessionClosed(TransportError):
    """The server closed this session (explicit close, or the client was
    declared dead and reaped)."""


def _admission_rejected():
    # Deferred: wire.py is imported while repro.service's own __init__ is
    # still executing; by the time an error is folded or raised the service
    # module is fully loaded.
    from ..service import AdmissionRejected

    return AdmissionRejected


_KINDS = {
    "TransportError": TransportError,
    "ServiceSuspended": ServiceSuspended,
    "SessionClosed": SessionClosed,
    "AdmissionRejected": _admission_rejected,
    "ValueError": ValueError,
    "KeyError": KeyError,
    "TypeError": TypeError,
    "RuntimeError": RuntimeError,
}


def _kind_class(kind):
    cls = _KINDS.get(kind, TransportError)
    return cls() if not isinstance(cls, type) else cls


def error_response(exc: BaseException) -> dict:
    """Server side: fold an exception into a wire error object."""
    kind = type(exc).__name__
    if kind not in _KINDS:
        kind = "TransportError"
    return {"ok": False, "error": str(exc), "kind": kind}


def raise_for(resp: dict):
    """Client side: raise the exception a ``{"ok": false}`` response names."""
    raise _kind_class(resp.get("kind"))(
        resp.get("error", "unknown server error")
    )


class JsonChannel:
    """One connected socket speaking newline-delimited JSON."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._rfile = sock.makefile("rb")

    def send(self, obj: dict) -> None:
        self.sock.sendall(json.dumps(obj).encode() + b"\n")

    def recv(self) -> "dict | None":
        """Next message, or None on EOF (peer gone)."""
        line = self._rfile.readline()
        if not line:
            return None
        return json.loads(line)

    def close(self) -> None:
        # Shutdown first: it unblocks a thread mid-recv on this channel
        # (closing the buffered reader while another thread holds its lock
        # in readinto() would deadlock).
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        for closer in (self.sock.close, self._rfile.close):
            try:
                closer()
            except (OSError, ValueError):
                pass


def connect_unix(path, *, timeout: float = 10.0, poll: float = 0.05) -> JsonChannel:
    """Connect to the server's UDS, retrying until ``timeout`` — covers the
    two-terminal quickstart where the trainer starts before the server has
    bound its socket."""
    deadline = time.monotonic() + timeout
    while True:
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        try:
            sock.connect(str(path))
            return JsonChannel(sock)
        except (FileNotFoundError, ConnectionRefusedError):
            sock.close()
            if time.monotonic() > deadline:
                raise TransportError(
                    f"no data server listening on {path} after {timeout}s"
                ) from None
            time.sleep(poll)
