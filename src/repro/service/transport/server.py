"""DataServiceServer: serve a :class:`~repro.service.DataService` over a
Unix socket + per-session shared-memory rings (DESIGN.md §11).

Thread layout::

    accept thread    -- one per server: accepts connections, spawns handlers
    handler threads  -- one per client connection: JSON-lines RPC dispatch
    pump thread      -- THE producer: runs ``DataService.co_epoch`` with the
                        transport hooks (ready/admit/idle/on_done) and writes
                        batch frames into session rings
    monitor thread   -- reaps dead clients (EOF is caught by the handler;
                        this catches *frozen* ones: no heartbeat AND no ring
                        drain within ``heartbeat_timeout``)

Only the pump thread touches session streams and ring tails, so the
single-producer side of every ring is honoured by construction. Handler
threads touch the service only through its own lock-protected API
(``open_session`` / ``close_session`` / ``suspend``).

Liveness: a client is alive while it either heartbeats (any RPC counts) or
drains its ring (head advance counts — a trainer blocked in a long step
sends no RPCs but keeps consuming). A dead client's session is closed
through the ordinary ``close_session`` path, so its outstanding planned
claims are unwound and the survivors' streams are untouched — the same
guarantee the elastic tests pin for in-process kills.
"""

from __future__ import annotations

import json
import socket
import threading
import time
from pathlib import Path

from repro.obs import MetricsRegistry
from repro.obs import tracer as trace

from ..service import SERVICE_MANIFEST, DataService
from .ring import (
    FRAME_BATCH,
    FRAME_EOE,
    FRAME_ERROR,
    STATE_CLOSED,
    STATE_SUSPENDED,
    BatchRing,
    encode_step_frame,
    frame_budget,
)
from .wire import JsonChannel, ServiceSuspended, error_response

__all__ = ["DataServiceServer", "service_metrics"]


class _PumpAbort(Exception):
    """Raised inside the pump to unwind co_epoch at a step boundary
    (server stop or suspend request)."""


class _Endpoint:
    """One connected client session: its ring, pending epochs, liveness."""

    def __init__(self, job_id, session, ring: BatchRing, budget: int, chan):
        self.job_id = job_id
        self.session = session
        self.ring = ring
        self.budget = budget
        self.chan = chan
        self.pending: "set[int]" = set()   # epochs begun but not EOE'd
        self.last_alive = time.monotonic()
        self._last_head = ring.head

    def touch(self) -> None:
        self.last_alive = time.monotonic()

    def alive_within(self, timeout: float) -> bool:
        head = self.ring.head
        if head != self._last_head:  # draining the ring counts as liveness
            self._last_head = head
            self.touch()
        return time.monotonic() - self.last_alive <= timeout


class DataServiceServer:
    """Out-of-process front end for one :class:`DataService`."""

    def __init__(
        self,
        service: DataService,
        socket_path: "str | Path",
        *,
        heartbeat_timeout: float = 15.0,
        poll_interval: float = 0.002,
    ):
        self.service = service
        self.socket_path = Path(socket_path)
        self.heartbeat_timeout = heartbeat_timeout
        self.poll_interval = poll_interval
        self._lock = threading.RLock()
        self._endpoints: "dict[object, _Endpoint]" = {}
        self._retired: "list[BatchRing]" = []  # closed rings; mmap freed at stop
        self._threads: "list[threading.Thread]" = []
        self._stop = threading.Event()
        self._suspended = False
        # Pending suspend request: (out_dir, done_event, result_box).
        self._suspend_req: "tuple[Path, threading.Event, list] | None" = None
        self._listener: "socket.socket | None" = None
        self._ring_seq = 0
        self.metrics = service_metrics(service)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "DataServiceServer":
        self.socket_path.parent.mkdir(parents=True, exist_ok=True)
        self.socket_path.unlink(missing_ok=True)
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(str(self.socket_path))
        self._listener.listen(64)
        # A blocked accept() does not wake when another thread closes the
        # fd (Linux); poll with a timeout so stop() can join this thread.
        self._listener.settimeout(0.2)
        for target in (self._accept_loop, self._pump_loop, self._monitor_loop):
            t = threading.Thread(target=target, daemon=True, name=target.__name__)
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        """Stop serving: abort any running pump at its next step boundary,
        close every session, mark rings closed, release the socket."""
        if self._stop.is_set():
            return
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()  # unblocks accept()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=30.0)
        with self._lock:
            endpoints = list(self._endpoints.values())
            self._endpoints = {}
        for ep in endpoints:
            self._detach(ep, state=STATE_CLOSED)
        for ring in self._retired:
            ring.close()
        self._retired.clear()
        self.service.close()
        self.socket_path.unlink(missing_ok=True)

    def __enter__(self) -> "DataServiceServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def serve_forever(self) -> None:
        """Block until :meth:`stop` (used by the ``--serve`` launcher)."""
        if self._listener is None:
            self.start()
        try:
            while not self._stop.is_set():
                time.sleep(0.2)
        finally:
            self.stop()

    # -------------------------------------------------------------- endpoints
    def _detach(self, ep: _Endpoint, *, state: int, close_chan: bool = True) -> None:
        """Tear an endpoint down: mark+unlink its ring (the client's mmap
        stays valid until it closes), retire the server-side map, close the
        session through the ordinary claim-unwinding path."""
        ep.ring.mark_state(state)
        ep.ring.unlink()
        self._retired.append(ep.ring)  # pump may still hold it this round
        ep.pending.clear()
        self.service.close_session(ep.job_id)
        if close_chan and ep.chan is not None:
            ep.chan.close()

    def _reap(self, job_id, why: str, *, close_chan: bool = True) -> None:
        with self._lock:
            ep = self._endpoints.pop(job_id, None)
        if ep is None:
            return
        self._detach(ep, state=STATE_CLOSED, close_chan=close_chan)

    def _endpoint_for(self, session) -> "_Endpoint | None":
        return self._endpoints.get(session.job_id)

    # ------------------------------------------------------------ pump thread
    def _check_abort(self) -> None:
        if self._stop.is_set() or self._suspend_req is not None:
            raise _PumpAbort

    def _pump_loop(self) -> None:
        while not self._stop.is_set():
            if self._suspend_req is not None:
                self._do_suspend()
                continue
            if self._suspended:
                time.sleep(self.poll_interval)
                continue
            with self._lock:
                epochs = sorted(
                    {e for ep in self._endpoints.values() for e in ep.pending}
                )
            if not epochs:
                time.sleep(self.poll_interval)
                continue
            try:
                self._run_pump(epochs[0])
            except _PumpAbort:
                continue  # loop re-checks stop/suspend
            except Exception as exc:  # server-side failure: tell the clients
                self._broadcast_error(exc)

    def _run_pump(self, epoch: int) -> None:
        svc = self.service

        def admit():
            with self._lock:
                return [
                    ep.session for ep in self._endpoints.values()
                    if epoch in ep.pending
                ]

        def ready(session) -> bool:
            ep = self._endpoint_for(session)
            return ep is not None and ep.ring.writable(ep.budget)

        def idle():
            self._check_abort()
            time.sleep(self.poll_interval)

        def on_done(session):
            ep = self._endpoint_for(session)
            if ep is not None:
                # ready() held when this session's stream raised
                # StopIteration, so one budget is free — the tiny EOE
                # sentinel always fits.
                ep.ring.write(FRAME_EOE, [json.dumps({"epoch": epoch}).encode()])
                ep.pending.discard(epoch)

        pump = svc.co_epoch(
            epoch, ready=ready, admit=admit, idle=idle, on_done=on_done, raw=True
        )
        try:
            for session, item in pump:
                ep = self._endpoint_for(session)
                if ep is not None:
                    ep.ring.write(
                        FRAME_BATCH,
                        encode_step_frame(
                            item, session.loader.seq_len, session.loader.pad_id
                        ),
                    )
                self._check_abort()
        finally:
            pump.close()

    def _broadcast_error(self, exc: BaseException) -> None:
        msg = json.dumps({"error": f"{type(exc).__name__}: {exc}"}).encode()
        with self._lock:
            endpoints = list(self._endpoints.values())
            self._endpoints = {}
        for ep in endpoints:
            ep.ring.try_write(FRAME_ERROR, [msg])
            self._detach(ep, state=STATE_CLOSED)

    def _do_suspend(self) -> None:
        out_dir, done, box = self._suspend_req
        try:
            # The pump aborted (or never ran) before we got here, so no
            # session stream is mid-flight — exactly what suspend() needs.
            path = self.service.suspend(out_dir)
            box.append({"ok": True, "dir": str(path)})
            self._suspended = True
            with self._lock:
                endpoints = list(self._endpoints.values())
                self._endpoints = {}
            for ep in endpoints:
                ep.ring.mark_state(STATE_SUSPENDED)
                ep.ring.unlink()
                self._retired.append(ep.ring)
                ep.pending.clear()
        except Exception as exc:
            box.append(error_response(exc))
        finally:
            self._suspend_req = None
            done.set()

    # --------------------------------------------------------- monitor thread
    def _monitor_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                endpoints = list(self._endpoints.items())
            for job_id, ep in endpoints:
                if not ep.alive_within(self.heartbeat_timeout):
                    self._reap(job_id, "heartbeat timeout")
            time.sleep(min(0.05, self.heartbeat_timeout / 4))

    # ---------------------------------------------------------- accept thread
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed by stop()
            conn.settimeout(None)  # accepted sockets inherit the timeout
            chan = JsonChannel(conn)
            t = threading.Thread(
                target=self._handle_conn, args=(chan,), daemon=True
            )
            t.start()

    def _handle_conn(self, chan: JsonChannel) -> None:
        """One client connection: dispatch RPCs until EOF, then reap any
        session it opened (a SIGKILL'd client closes its socket — the fast
        path for dead-client detection)."""
        job_id = None
        try:
            while not self._stop.is_set():
                msg = chan.recv()
                if msg is None:
                    break  # EOF: client gone
                try:
                    resp, job_id = self._dispatch(msg, chan, job_id)
                except Exception as exc:
                    resp = error_response(exc)
                try:
                    chan.send(resp)
                except OSError:
                    break
        except (OSError, ValueError):
            pass  # torn connection mid-message
        finally:
            if job_id is not None:
                self._reap(job_id, "connection closed")
            chan.close()

    # ----------------------------------------------------------- op dispatch
    def _dispatch(self, msg: dict, chan: JsonChannel, job_id):
        op = msg.get("op")
        with self._lock:
            ep = self._endpoints.get(job_id)
        if ep is not None:
            ep.touch()
        if op == "open_session":
            return self._op_open_session(msg, chan)
        if op == "heartbeat":
            return {"ok": True}, job_id
        if op == "begin_epoch":
            if self._suspended:
                raise _suspended_error()
            if ep is None:
                raise KeyError(f"no session on this connection (job {job_id!r})")
            ep.pending.add(int(msg["epoch"]))
            return {"ok": True}, job_id
        if op == "steps_per_epoch":
            if ep is None:
                raise KeyError(f"no session on this connection (job {job_id!r})")
            n = ep.session.steps_per_epoch(int(msg.get("epoch", 0)))
            return {"ok": True, "steps": n}, job_id
        if op == "plan_epoch":
            plans = self.service.plan_epoch(int(msg["epoch"]))
            return {"ok": True, "planned": len(plans)}, job_id
        if op == "stats":
            return {"ok": True, "stats": self.service.stats_report()}, job_id
        if op == "admission":
            return {
                "ok": True, "admission": self.service.admission_report(),
            }, job_id
        if op == "metrics":
            return self._op_metrics(), job_id
        if op == "trace_dump":
            return self._op_trace_dump(msg), job_id
        if op == "close_session":
            if job_id is not None:
                # Leave the channel open: the ok-response still has to go
                # out on it; the client closes its end right after.
                self._reap(job_id, "client close", close_chan=False)
            return {"ok": True}, None
        if op == "suspend":
            return self._op_suspend(msg), job_id
        if op == "shutdown":
            self._stop.set()
            return {"ok": True}, job_id
        raise ValueError(f"unknown transport op {op!r}")

    def _op_open_session(self, msg: dict, chan: JsonChannel):
        from ...core.spec import SessionSpec

        if self._suspended:
            raise _suspended_error()
        job_id = msg["job_id"]
        resume_from = msg.get("resume_from")
        svc = self.service
        with self._lock:
            if job_id in self._endpoints:
                raise ValueError(
                    f"job {job_id!r} already has a connected client"
                )
        existing = svc._sessions.get(job_id)
        if existing is not None and not existing.closed:
            # A pre-resumed session (DataService.resume stood the whole
            # service back up): the reconnecting client just attaches.
            if msg.get("spec") is not None:
                raise ValueError(
                    f"job {job_id!r} already has a server-side session "
                    "(resumed); reconnect without a spec to attach"
                )
            session = existing
        elif resume_from is not None:
            session = svc.open_session(
                job_id, resume_from=_resolve_resume_dir(resume_from, job_id)
            )
        else:
            spec = SessionSpec.from_json(msg.get("spec") or {})
            session = svc.open_session(job_id, spec)
        spec = session.spec
        budget = frame_budget(spec.global_batch, spec.seq_len, spec.num_nodes)
        capacity = budget * (max(2, spec.queue_depth) + 1)
        with self._lock:
            self._ring_seq += 1
            ring_path = self.socket_path.with_name(
                f"{self.socket_path.name}.ring{self._ring_seq:04d}"
            )
        ring = BatchRing.create(ring_path, capacity)
        ep = _Endpoint(job_id, session, ring, budget, chan)
        with self._lock:
            self._endpoints[job_id] = ep
        rp = session.loader.resume_point
        store_spec = getattr(svc.store, "spec", None)
        return {
            "ok": True,
            "ring": str(ring_path),
            "budget": budget,
            "spec": spec.to_json(),
            # The served store's StoreSpec (DESIGN.md §15), so remote
            # trainers resolve the codec/bands without guessing.
            "store": store_spec.to_json() if store_spec is not None else None,
            "resume_point": list(rp) if rp is not None else None,
        }, job_id

    def _op_metrics(self) -> dict:
        """Live scrape: flat snapshot + Prometheus text. Per-job providers
        are (re-)registered on every scrape, so jobs opened after the
        server started are always covered."""
        for j, st in self.service.residency.per_job_stats.items():
            self.metrics.register_stats(
                "service", lambda st=st: st, labels={"job": str(j)}
            )
        return {
            "ok": True,
            "metrics": self.metrics.collect(),
            "text": self.metrics.exposition(),
        }

    def _op_trace_dump(self, msg: dict) -> dict:
        """Export the server process's trace ring. With ``path`` the Chrome
        JSON is written server-side (the trace can be large); otherwise it
        is returned inline."""
        tracer = trace.get()
        if tracer is None:
            return {"ok": True, "trace": None, "events": 0}
        path = msg.get("path")
        if path is not None:
            tracer.dump(path)
            return {"ok": True, "path": str(path), "events": len(tracer)}
        return {
            "ok": True, "trace": tracer.to_chrome(), "events": len(tracer)
        }

    def _op_suspend(self, msg: dict) -> dict:
        out_dir = Path(msg["dir"])
        done = threading.Event()
        box: list = []
        with self._lock:
            if self._suspend_req is not None:
                raise RuntimeError("a suspend is already in progress")
            self._suspend_req = (out_dir, done, box)
        # The pump thread performs the suspend (it owns the streams); this
        # handler just waits for it.
        if not done.wait(timeout=120.0):
            raise RuntimeError("suspend timed out waiting for the pump")
        return box[0]


def service_metrics(service: DataService) -> MetricsRegistry:
    """A registry wired to a :class:`DataService`'s live stats objects:
    the aggregate ServiceStats, the storage BackendStats, and the shared
    residency's cache gauges (per-job stats join at scrape time — see
    ``DataServiceServer._op_metrics``)."""
    reg = MetricsRegistry()
    reg.register_stats("service", service.aggregate_stats)
    reg.register_stats("backend", lambda: service.store.backend_stats)
    reg.register_stats("residency", lambda: {
        "cache_bytes": service.residency.cache_bytes,
        "peak_cache_bytes": service.residency.peak_cache_bytes,
        "evictions": service.residency.evictions,
        "cache_bypass": service.residency.cache_bypass,
        "open_sessions": len(service.sessions),
    })
    return reg


def _suspended_error() -> ServiceSuspended:
    return ServiceSuspended(
        "data service is suspended (checkpointed); start a resumed server "
        "and reconnect"
    )


def _resolve_resume_dir(path, job_id) -> Path:
    """Accept either a session suspend dir or the whole-service suspend dir
    (in which case the job's subdir is resolved via the manifest)."""
    path = Path(path)
    manifest = path / SERVICE_MANIFEST
    if manifest.exists():
        mf = json.loads(manifest.read_text())
        for job in mf["jobs"]:
            if job["job_id"] == job_id:
                return path / job["dir"]
        raise KeyError(
            f"job {job_id!r} not found in {manifest} "
            f"(jobs: {[j['job_id'] for j in mf['jobs']]})"
        )
    return path
