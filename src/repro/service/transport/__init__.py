"""Out-of-process transport for the multi-job data service (DESIGN.md §11).

Control plane: newline-delimited JSON over a Unix domain socket
(:mod:`.wire`). Data plane: one mmap-backed shared-memory ring per session
(:mod:`.ring`) — batch tokens are copied once into the ring by the server
and reconstructed as ndarray views by the client, never pickled.
:class:`DataServiceServer` fronts a :class:`~repro.service.DataService`;
:class:`RedoxClient` is the trainer-side drop-in loader.
"""

from .client import RedoxClient
from .ring import BatchRing, RingClosed, decode_batch_frame, encode_step_frame, frame_budget
from .server import DataServiceServer
from .wire import ServiceSuspended, SessionClosed, TransportError

__all__ = [
    "BatchRing",
    "DataServiceServer",
    "RedoxClient",
    "RingClosed",
    "ServiceSuspended",
    "SessionClosed",
    "TransportError",
    "decode_batch_frame",
    "encode_step_frame",
    "frame_budget",
]
