"""RedoxClient: the trainer-side drop-in for a RedoxLoader (DESIGN.md §11).

A trainer in a *separate OS process* swaps::

    loader = RedoxLoader.from_spec(spec, store)
    for batch in loader.epoch_async(epoch): ...

for::

    client = RedoxClient(socket_path, spec, job_id="job0")
    for batch in client.epoch(epoch): ...

and receives the exact same ``GlobalBatch`` stream — tokens arrive through
the session's shared-memory ring (one copy out, zero pickling), control
through the JSON socket. A background thread heartbeats so a frozen
trainer is eventually reaped server-side; a SIGKILL'd one is reaped
immediately via socket EOF.
"""

from __future__ import annotations

import json
import threading
from pathlib import Path

from ...core.spec import SessionSpec, StoreSpec
from .ring import (
    FRAME_BATCH,
    FRAME_EOE,
    FRAME_ERROR,
    STATE_SUSPENDED,
    BatchRing,
    RingClosed,
    decode_batch_frame,
)
from .wire import (
    ServiceSuspended,
    SessionClosed,
    TransportError,
    connect_unix,
    raise_for,
)

__all__ = ["RedoxClient"]


class RedoxClient:
    """One job's remote data session over a :class:`DataServiceServer`.

    ``spec=None`` attaches to a server-side session that already exists
    under ``job_id`` (the reconnect-after-resume flow); ``resume_from``
    asks the server to restore the session from suspend files first (the
    path may be a whole-service suspend dir — the server resolves this
    job's subdir through the service manifest).
    """

    def __init__(
        self,
        socket_path: "str | Path",
        spec: "SessionSpec | None" = None,
        *,
        job_id="job0",
        resume_from: "str | Path | None" = None,
        heartbeat_interval: float = 2.0,
        frame_timeout: float = 120.0,
        connect_timeout: float = 10.0,
    ):
        self.socket_path = Path(socket_path)
        self.job_id = job_id
        self.frame_timeout = frame_timeout
        self._chan = connect_unix(self.socket_path, timeout=connect_timeout)
        self._rpc_lock = threading.Lock()
        self._closed = threading.Event()
        msg = {"op": "open_session", "job_id": job_id}
        if spec is not None:
            msg["spec"] = spec.to_json()
        if resume_from is not None:
            msg["resume_from"] = str(resume_from)
        resp = self._rpc(msg)
        self.spec = SessionSpec.from_json(resp["spec"])
        store = resp.get("store")
        #: The served store's frozen StoreSpec — codec, level, bands — so
        #: the trainer knows the byte representation without guessing
        #: (None when talking to a store double or an older server).
        self.store_spec = StoreSpec.from_json(store) if store else None
        rp = resp.get("resume_point")
        #: (epoch, next_step) the server will continue from, if resumed.
        self.resume_point = tuple(rp) if rp else None
        self._ring = BatchRing.attach(resp["ring"])
        self._beat = threading.Thread(
            target=self._heartbeat_loop,
            args=(heartbeat_interval,),
            daemon=True,
        )
        if heartbeat_interval > 0:
            self._beat.start()

    # ------------------------------------------------------------------- rpc
    def _rpc(self, msg: dict) -> dict:
        with self._rpc_lock:
            if self._closed.is_set():
                raise SessionClosed(f"client for job {self.job_id!r} is closed")
            try:
                self._chan.send(msg)
                resp = self._chan.recv()
            except OSError as exc:
                raise TransportError(f"data server connection lost: {exc}") from exc
        if resp is None:
            raise TransportError("data server closed the connection")
        if not resp.get("ok"):
            raise_for(resp)
        return resp

    def _heartbeat_loop(self, interval: float) -> None:
        while not self._closed.wait(interval):
            try:
                self._rpc({"op": "heartbeat"})
            except (TransportError, SessionClosed):
                return

    # ---------------------------------------------------------------- epochs
    def steps_per_epoch(self, epoch: int = 0) -> int:
        return self._rpc({"op": "steps_per_epoch", "epoch": epoch})["steps"]

    def epoch(self, epoch: int):
        """Yield this job's GlobalBatches for ``epoch``, exactly as the
        in-process loader would produce them.

        If the service suspends mid-epoch, every batch the server produced
        before the suspend point is still drained from the ring, then
        :class:`ServiceSuspended` is raised — so the trainer's consumed
        stream matches the server-side suspend cursor exactly.
        """
        self._rpc({"op": "begin_epoch", "epoch": epoch})
        while True:
            try:
                kind, payload = self._ring.read(timeout=self.frame_timeout)
            except RingClosed as exc:
                if exc.state == STATE_SUSPENDED:
                    raise ServiceSuspended(
                        f"data service suspended during epoch {epoch}"
                    ) from None
                raise SessionClosed(
                    f"server closed session {self.job_id!r} during epoch {epoch}"
                ) from None
            if kind == FRAME_BATCH:
                yield decode_batch_frame(payload)
            elif kind == FRAME_EOE:
                eoe = json.loads(payload)
                assert eoe.get("epoch") == epoch, (
                    f"out-of-order end-of-epoch: got {eoe} during epoch {epoch}"
                )
                return
            elif kind == FRAME_ERROR:
                raise TransportError(json.loads(payload)["error"])
            else:
                raise TransportError(f"unknown frame kind {kind}")

    # The in-process loader's async spelling; remotely every epoch is
    # already pipelined through the ring, so they are the same thing.
    epoch_async = epoch

    def epoch_device(self, epoch: int, stager=None):
        """Device-resident batches over the ring (DESIGN.md §12): frames
        are decoded and double-buffered onto the device by a
        :class:`~repro.core.device.DeviceStager` while the trainer's
        previous step computes.

        Ring frames ship pre-assembled grids, so this is the staging half
        only — the Pallas gather path needs the host-side slot packs and
        is reserved for in-process loaders (``RedoxLoader.epoch_device``).
        """
        from ...core.device import DeviceStager  # deferred: pulls in jax

        if stager is None:
            stager = DeviceStager(use_kernel=False)
        return stager.stream(self.epoch(epoch))

    # ------------------------------------------------------------- lifecycle
    def suspend(self, out_dir: "str | Path") -> Path:
        """Ask the service to checkpoint its whole data plane (all jobs)."""
        resp = self._rpc({"op": "suspend", "dir": str(out_dir)})
        return Path(resp["dir"])

    def stats(self) -> dict:
        return self._rpc({"op": "stats"})["stats"]

    def admission(self) -> "dict | None":
        """The server's admission-control view (None when admission is off).
        An over-budget ``open_session`` raises
        :class:`repro.service.AdmissionRejected` typed on this side."""
        return self._rpc({"op": "admission"})["admission"]

    def metrics(self) -> dict:
        """Scrape the live server: ``{"metrics": flat snapshot,
        "text": Prometheus exposition}`` (see ``repro.obs.MetricsRegistry``)."""
        resp = self._rpc({"op": "metrics"})
        return {"metrics": resp["metrics"], "text": resp["text"]}

    def trace_dump(self, path: "str | Path | None" = None):
        """Export the server process's trace. With ``path`` the server
        writes the Chrome JSON to that (server-local) file and the number
        of events is returned; without it the trace object itself comes
        back inline (None when server-side tracing is off)."""
        msg: dict = {"op": "trace_dump"}
        if path is not None:
            msg["path"] = str(path)
        resp = self._rpc(msg)
        return resp.get("path", resp.get("trace")), resp["events"]

    def close(self) -> None:
        if self._closed.is_set():
            return
        try:
            self._rpc({"op": "close_session"})
        except (TransportError, SessionClosed):
            pass  # server already gone
        self._closed.set()
        self._chan.close()
        self._ring.close()

    def __enter__(self) -> "RedoxClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
