"""Shared-memory batch rings: the transport's data plane (DESIGN.md §11).

One ring per session, memory-mapped (``MAP_SHARED``) by server and client
from a file the server creates next to its socket. The control plane
(:mod:`.wire`) only ever carries small JSON messages; batch payloads flow
through the ring as raw array bytes — the server copies each step's token
grid ONCE into the ring (straight from ``_to_grid`` output, never
pickled), and the client reconstructs ndarray views over one copy out.

Layout (little-endian)::

    [64-byte header][capacity bytes of frame data, circular]

    header:  magic "RDX1" | u32 version | u64 capacity
             | u64 head (consumer-owned) | u64 tail (producer-owned)
             | u32 state (0 open / 1 closed / 2 suspended)

``head``/``tail`` are monotonically increasing byte counters (positions
are taken mod capacity), so ``tail - head`` is exactly the unread bytes
and a full ring is unambiguous. Single-producer/single-consumer: the
server only writes ``tail``+``state``, the client only writes ``head`` —
no locks. Frames are written payload-first, counter-last; on x86-64's
total store order (and under CPython's byte-wise memcpy into an aligned
mmap) the consumer can never observe a counter ahead of its payload.

Frames: ``u32 payload_len | u8 kind | payload`` (payloads wrap around the
ring edge). Kinds: BATCH (one GlobalBatch, see :func:`encode_step_frame`),
EOE (end-of-epoch sentinel, JSON), ERROR and SUSPENDED (JSON; the client
raises). The server sizes each ring to ``queue_depth + 1`` worst-case
batch frames (:func:`frame_budget`) and skips a session whose ring has
less than one budget free — that skip IS the per-session backpressure.
"""

from __future__ import annotations

import json
import mmap
import struct
import time
from pathlib import Path

import numpy as np

from repro.obs import tracer as trace

from ...core.loader import GlobalBatch, _to_grid
from ...core.stats import StepIO
from ...data.tokens import decode_record

__all__ = [
    "BatchRing",
    "RingClosed",
    "FRAME_BATCH",
    "FRAME_EOE",
    "FRAME_ERROR",
    "FRAME_SUSPENDED",
    "STATE_OPEN",
    "STATE_CLOSED",
    "STATE_SUSPENDED",
    "frame_budget",
    "encode_step_frame",
    "decode_batch_frame",
]

MAGIC = b"RDX1"
VERSION = 1
HEADER = 64
_OFF_CAPACITY = 8
_OFF_HEAD = 16
_OFF_TAIL = 24
_OFF_STATE = 32
FRAME_OVERHEAD = 5  # u32 length + u8 kind

FRAME_BATCH = 1
FRAME_EOE = 2
FRAME_ERROR = 3
FRAME_SUSPENDED = 4

STATE_OPEN = 0
STATE_CLOSED = 1
STATE_SUSPENDED = 2


class RingClosed(ConnectionError):
    """The producer marked the ring closed/suspended and no frames remain."""

    def __init__(self, state: int):
        self.state = state
        word = "suspended" if state == STATE_SUSPENDED else "closed"
        super().__init__(f"batch ring {word} by the data service")


class BatchRing:
    """SPSC byte ring over an mmap'd file; see the module docstring."""

    def __init__(self, path: Path, file, mm: mmap.mmap, capacity: int):
        self.path = Path(path)
        self._file = file
        self._mm = mm
        self.capacity = capacity

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, path: "str | Path", capacity: int) -> "BatchRing":
        """Server side: create the backing file and initialise the header."""
        capacity = max(int(capacity), 4096)
        path = Path(path)
        with open(path, "wb") as f:
            f.truncate(HEADER + capacity)
        file = open(path, "r+b")
        mm = mmap.mmap(file.fileno(), HEADER + capacity)
        mm[0:4] = MAGIC
        struct.pack_into("<I", mm, 4, VERSION)
        struct.pack_into("<Q", mm, _OFF_CAPACITY, capacity)
        struct.pack_into("<Q", mm, _OFF_HEAD, 0)
        struct.pack_into("<Q", mm, _OFF_TAIL, 0)
        struct.pack_into("<I", mm, _OFF_STATE, STATE_OPEN)
        return cls(path, file, mm, capacity)

    @classmethod
    def attach(cls, path: "str | Path") -> "BatchRing":
        """Client side: map an existing ring (validates magic/version)."""
        path = Path(path)
        file = open(path, "r+b")
        head = file.read(HEADER)
        if head[0:4] != MAGIC:
            file.close()
            raise ValueError(f"{path} is not a Redox batch ring")
        version = struct.unpack_from("<I", head, 4)[0]
        if version != VERSION:
            file.close()
            raise ValueError(f"ring version {version} != {VERSION}")
        capacity = struct.unpack_from("<Q", head, _OFF_CAPACITY)[0]
        mm = mmap.mmap(file.fileno(), HEADER + capacity)
        return cls(path, file, mm, capacity)

    def close(self) -> None:
        try:
            self._mm.close()
        except (BufferError, ValueError):
            pass  # an ndarray view may still pin the map; dropped with it
        try:
            self._file.close()
        except OSError:
            pass

    def unlink(self) -> None:
        self.path.unlink(missing_ok=True)

    # -------------------------------------------------------------- header
    @property
    def head(self) -> int:
        return struct.unpack_from("<Q", self._mm, _OFF_HEAD)[0]

    @property
    def tail(self) -> int:
        return struct.unpack_from("<Q", self._mm, _OFF_TAIL)[0]

    @property
    def state(self) -> int:
        return struct.unpack_from("<I", self._mm, _OFF_STATE)[0]

    def mark_state(self, state: int) -> None:
        """Producer side: closed/suspended. Wakes a polling consumer."""
        struct.pack_into("<I", self._mm, _OFF_STATE, state)

    @property
    def used_bytes(self) -> int:
        return self.tail - self.head

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.used_bytes

    def writable(self, budget: int) -> bool:
        """Producer-side backpressure probe: room for one budget'd frame?"""
        return self.state == STATE_OPEN and self.free_bytes >= budget

    # ------------------------------------------------------------- producer
    def _copy_in(self, pos: int, data) -> int:
        """Copy ``data`` into the circular data area at byte counter ``pos``."""
        view = memoryview(data)
        if view.format != "B":
            view = view.cast("B")
        n = view.nbytes
        off = pos % self.capacity
        first = min(n, self.capacity - off)
        self._mm[HEADER + off:HEADER + off + first] = view[:first]
        if first < n:
            self._mm[HEADER:HEADER + n - first] = view[first:]
        return n

    def try_write(self, kind: int, parts) -> bool:
        """Write one frame from buffer ``parts`` iff it fits; False if not.

        ``parts`` may be bytes or C-contiguous ndarrays — each is copied
        exactly once, directly into the mapped ring.
        """
        views = [p if isinstance(p, (bytes, bytearray, memoryview))
                 else memoryview(p).cast("B") for p in parts]
        total = sum(memoryview(v).nbytes for v in views)
        if self.free_bytes < FRAME_OVERHEAD + total:
            return False
        with trace.span("ring.write", "ring", kind=kind, nbytes=total):
            pos = self.tail
            self._copy_in(pos, struct.pack("<IB", total, kind))
            pos += FRAME_OVERHEAD
            for v in views:
                pos += self._copy_in(pos, v)
            # counter-last: the frame only becomes visible once fully copied
            struct.pack_into("<Q", self._mm, _OFF_TAIL, pos)
        return True

    def write(self, kind: int, parts) -> None:
        """Write a frame the producer already knows fits (backpressure was
        checked via :meth:`writable`); a full ring here is a logic error."""
        if not self.try_write(kind, parts):
            raise BufferError(
                f"ring overflow: {self.free_bytes} bytes free (backpressure "
                "probe should have skipped this session)"
            )

    # ------------------------------------------------------------- consumer
    def _copy_out(self, pos: int, n: int) -> bytes:
        off = pos % self.capacity
        first = min(n, self.capacity - off)
        out = self._mm[HEADER + off:HEADER + off + first]
        if first < n:
            out += self._mm[HEADER:HEADER + n - first]
        return out

    def try_read(self) -> "tuple[int, bytes] | None":
        """Pop the next frame as ``(kind, payload)``; None if none pending."""
        head, tail = self.head, self.tail
        if tail - head < FRAME_OVERHEAD:
            return None
        length, kind = struct.unpack("<IB", self._copy_out(head, FRAME_OVERHEAD))
        payload = self._copy_out(head + FRAME_OVERHEAD, length)
        struct.pack_into("<Q", self._mm, _OFF_HEAD, head + FRAME_OVERHEAD + length)
        return kind, payload

    def read(self, *, timeout: float = 60.0, poll: float = 0.0005):
        """Blocking pop: poll until a frame arrives, the producer marks the
        ring closed/suspended (-> :class:`RingClosed`), or ``timeout``."""
        deadline = time.monotonic() + timeout
        # The span covers poll-wait + copy-out: consumer-visible ring time.
        with trace.span("ring.read", "ring"):
            while True:
                frame = self.try_read()
                if frame is not None:
                    return frame
                state = self.state
                if state != STATE_OPEN:
                    raise RingClosed(state)
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"no frame within {timeout}s (server stalled or gone)"
                    )
                time.sleep(poll)


# ------------------------------------------------------------ batch frames
def frame_budget(global_batch: int, seq_len: int, num_nodes: int) -> int:
    """Worst-case BATCH frame bytes for one step of a session.

    grid+mask are ``(B, seq_len+1)`` int32/float32, returned ids int64, and
    the JSON meta (step + per-node StepIO counters) is generously bounded.
    """
    b, s1 = int(global_batch), int(seq_len) + 1
    meta = 1024 + 512 * int(num_nodes)
    raw = FRAME_OVERHEAD + 4 + meta + 8 * b * s1 + 8 * b
    return -(-raw // 1024) * 1024  # round up to 1 KiB


def encode_step_frame(item, seq_len: int, pad_id: int) -> list:
    """Serialize one raw pump step (``co_epoch(raw=True)`` item) to frame
    parts. Token decode + grid assembly happen here, server-side, and the
    contiguous grid goes straight into the ring — one copy, no pickle."""
    payloads, step, io_by_node, returned = item
    with trace.span("ring.encode", "decode", step=int(step)):
        flat = [decode_record(p) for p in payloads]
        grid, mask = _to_grid(flat, seq_len + 1, pad_id)
    ret = (
        np.concatenate(returned)
        if returned is not None and len(returned)
        else np.empty(0, dtype=np.int64)
    ).astype(np.int64, copy=False)
    if not ret.flags.c_contiguous:
        ret = np.ascontiguousarray(ret)
    meta = json.dumps({
        "step": int(step),
        "shape": [int(grid.shape[0]), int(grid.shape[1])],
        "nret": int(ret.size),
        "io": {
            str(int(r)): io.to_dict()
            for r, io in (io_by_node or {}).items()
        },
    }).encode()
    return [struct.pack("<I", len(meta)), meta, grid, mask, ret]


def decode_batch_frame(payload: bytes) -> GlobalBatch:
    """Rebuild the GlobalBatch a co-located loader's ``_assemble`` would
    have produced (arrays are read-only views over the one copied-out
    buffer)."""
    (meta_len,) = struct.unpack_from("<I", payload)
    meta = json.loads(payload[4:4 + meta_len])
    off = 4 + meta_len
    b, s1 = meta["shape"]
    grid = np.frombuffer(payload, np.int32, b * s1, off).reshape(b, s1)
    off += 4 * b * s1
    mask = np.frombuffer(payload, np.float32, b * s1, off).reshape(b, s1)
    off += 4 * b * s1
    returned = np.frombuffer(payload, np.int64, meta["nret"], off)
    return GlobalBatch(
        tokens=grid[:, :-1],
        targets=grid[:, 1:],
        loss_mask=mask[:, 1:],
        step=meta["step"],
        io_by_node={int(r): StepIO.from_dict(v) for r, v in meta["io"].items()},
        returned=returned,
    )
