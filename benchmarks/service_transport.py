"""Out-of-process transport overhead: K trainers on one served data plane.

The tentpole claim of the transport subsystem (DESIGN.md §11) is that
moving the data plane out of the trainer's process costs latency, not
correctness — batches cross a process boundary through a shared-memory
ring instead of a Python queue. This benchmark puts a number on that
cost: one :class:`~repro.service.transport.DataServiceServer` on a real
unix socket, K :class:`~repro.service.transport.RedoxClient` consumers
(threads here, so one process hosts the timer — the wire format and ring
protocol are identical for separate processes), each draining a full
epoch. Reported per row:

* ``agg_mb_s`` — aggregate payload bytes through the rings / wall time;
* ``p50_ms``/``p99_ms`` — per-batch client-side latency (time blocked in
  ``ring.read`` + decode until the next GlobalBatch is ready);
* ``fairness`` — slowest client wall / fastest client wall over the same
  step count (the round-robin pump should keep this near 1).
"""

from __future__ import annotations

import argparse
import tempfile
import threading
import time
from pathlib import Path

from repro.core import SessionSpec
from repro.data import SyntheticTokenDataset
from repro.service import DataService
from repro.service.transport import DataServiceServer, RedoxClient


def _build_store(root: Path, *, num_docs: int, chunk_size: int, groups: int,
                 mean_len: int, seed: int):
    ds = SyntheticTokenDataset(num_docs, vocab_size=32000, mean_len=mean_len, seed=seed)
    return ds.build_store(
        root, chunk_size, num_slots=groups * chunk_size, seed=seed + 1
    )


def _percentile(sorted_vals: "list[float]", q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[i]


def run_transport(
    clients: int = 3,
    *,
    num_docs: int = 512,
    chunk_size: int = 8,
    groups: int = 8,
    mean_len: int = 64,
    batch: int = 16,
    seq_len: int = 64,
    epochs: int = 1,
    seed: int = 0,
) -> dict:
    """K clients drain ``epochs`` epochs over the real socket+ring path.
    Returns one BENCH row."""
    with tempfile.TemporaryDirectory(prefix="redox_transport_") as tmp:
        root = Path(tmp) / "chunks"
        store = _build_store(root, num_docs=num_docs, chunk_size=chunk_size,
                             groups=groups, mean_len=mean_len, seed=seed)
        sock = Path(tmp) / "svc.sock"
        svc = DataService(store, co_refill=True)
        per_client: "list[dict]" = [None] * clients  # type: ignore[list-item]

        def worker(j: int) -> None:
            spec = SessionSpec(seed=seed + 10 * j + 1, batch_per_node=batch,
                               seq_len=seq_len)
            client = RedoxClient(sock, spec, job_id=f"job{j}",
                                 heartbeat_interval=0)
            lat: "list[float]" = []
            nbytes = steps = 0
            t0 = time.perf_counter()
            try:
                for epoch in range(epochs):
                    it = client.epoch(epoch)
                    while True:
                        t = time.perf_counter()
                        try:
                            b = next(it)
                        except StopIteration:
                            break
                        lat.append(time.perf_counter() - t)
                        steps += 1
                        nbytes += (b["tokens"].nbytes + b["targets"].nbytes
                                   + b["loss_mask"].nbytes)
            finally:
                client.close()
            per_client[j] = dict(
                steps=steps, bytes=nbytes,
                wall=time.perf_counter() - t0, lat=lat,
            )

        with DataServiceServer(svc, sock, poll_interval=0.001):
            threads = [threading.Thread(target=worker, args=(j,))
                       for j in range(clients)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
        store.close()

    assert all(c is not None for c in per_client)
    steps = [c["steps"] for c in per_client]
    walls = [c["wall"] for c in per_client]
    lats = sorted(x for c in per_client for x in c["lat"])
    total_bytes = sum(c["bytes"] for c in per_client)
    return dict(
        clients=clients,
        epochs=epochs,
        steps=sum(steps),
        ring_mb=total_bytes / 1e6,
        agg_mb_s=total_bytes / 1e6 / wall,
        batches_s=sum(steps) / wall,
        p50_ms=_percentile(lats, 0.50) * 1e3,
        p99_ms=_percentile(lats, 0.99) * 1e3,
        fairness=max(walls) / max(min(walls), 1e-9),
        wall_s=wall,
    )


def print_table(rows: "list[dict]") -> None:
    print(
        f"{'clients':>7s} {'steps':>6s} {'ring_MB':>8s} {'MB/s':>7s} "
        f"{'batch/s':>8s} {'p50_ms':>7s} {'p99_ms':>7s} {'fair':>6s} "
        f"{'wall_s':>7s}"
    )
    for r in rows:
        print(
            f"{r['clients']:7d} {r['steps']:6d} {r['ring_mb']:8.1f} "
            f"{r['agg_mb_s']:7.1f} {r['batches_s']:8.1f} {r['p50_ms']:7.2f} "
            f"{r['p99_ms']:7.2f} {r['fairness']:5.2f}x {r['wall_s']:7.2f}"
        )


def main(quick: bool = False) -> "list[dict]":
    kw = dict(num_docs=256, mean_len=48) if quick else {}
    rows = [run_transport(1, **kw), run_transport(3, **kw)]
    if not quick:
        rows.append(run_transport(5))
    print_table(rows)
    for r in rows:
        # Every client must see the full epoch stream — the pump serves
        # sessions round-robin, so steps divide evenly by construction.
        assert r["steps"] % r["clients"] == 0, r
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.clients == 3 and args.epochs == 1:
        main(quick=args.quick)
    else:
        print_table([run_transport(args.clients, epochs=args.epochs)])
