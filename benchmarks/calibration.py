"""Calibration of the timing model to the paper's setups (Table 1-3).

Hardware profiles mirror Table 2; per-sample compute times are
reverse-engineered from Table 1's No-I/O residuals (epoch − I/O overhead).
Datasets are scaled down by ``SCALE`` (default 20x: 1.2M files -> 61k) with
memory budgets scaled identically, which preserves every ratio the paper
reports (hit rates, fill rates, speedups) while keeping the protocol
simulation wall-time tractable on one CPU; ``--full`` restores 1x.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ChunkingPlan, PipelineTimeModel
from repro.data.synthetic import paper_like_sizes

SCALE = 20

# --- storage/network profiles (paper Table 2) -------------------------------
# NAS small-file random reads: ~8 ms head overhead per op; SEQUENTIAL
# streaming is far faster (enterprise NAS ≥ 500 MB/s; Lustre ≥ 1.5 GB/s) —
# this asymmetry is exactly what the paper's batched chunk reads exploit.
# Calibrated so (a) PyTorch-loader I/O on ImageNet-1k/P100 reproduces
# Table 1's overhead ordering and (b) Fig 13's I/O-throughput-vs-chunk-size
# curve shape matches.
TIME_MODELS = {
    "A10": PipelineTimeModel(
        disk_bw=500e6, file_overhead=8e-3, chunk_overhead=8e-3,
        net_bw=0.38e9, net_latency=1e-3,
    ),
    "P100": PipelineTimeModel(
        disk_bw=500e6, file_overhead=8e-3, chunk_overhead=8e-3,
        net_bw=0.38e9, net_latency=1e-3,
    ),
    "A100": PipelineTimeModel(
        disk_bw=1.5e9, file_overhead=4e-3, chunk_overhead=4e-3,
        net_bw=3e9, net_latency=5e-4,
    ),
}

MEMORY_PER_NODE = {"A10": 12e9, "P100": 56e9, "A100": 240e9}  # usable for data

# --- datasets (Table 3) ------------------------------------------------------
DATASETS = {
    "imagenet1k": dict(num_files=1_200_000, profile="imagenet1k"),
    "imagenet21k": dict(num_files=13_000_000, profile="imagenet21k"),
    "librispeech": dict(num_files=280_000, profile="librispeech"),
}

# --- per-sample GPU compute (s), from Table 1 No-I/O residuals ---------------
MODEL_COMPUTE = {
    ("squeezenet", "A10"): 0.40e-3,
    ("mobilenetv3", "A10"): 0.85e-3,
    ("resnet50", "A10"): 1.6e-3,
    ("squeezenet", "P100"): 1.1e-3,   # (1.40-1.27)h over 1.28M samples x3 nodes
    ("mobilenetv3", "P100"): 2.4e-3,  # (1.53-1.25)h
    ("resnet50", "P100"): 4.9e-3,     # (1.65-1.07)h
    ("wav2vec2", "A10"): 6.0e-3,
    ("densenet121", "A100"): 0.9e-3,
    ("vgg16", "A100"): 1.2e-3,
}

BATCH = {
    "squeezenet": 512, "mobilenetv3": 256, "resnet50": 128,
    "wav2vec2": 64, "densenet121": 256, "vgg16": 256,
}


@dataclasses.dataclass
class Scenario:
    dataset: str
    hw: str
    model: str
    nodes: int
    scale: int = SCALE
    chunk_size: int = 64
    remote_limit: float = 1.5e9
    seed: int = 0

    @property
    def num_files(self) -> int:
        return DATASETS[self.dataset]["num_files"] // self.scale

    def sizes(self) -> np.ndarray:
        return paper_like_sizes(
            DATASETS[self.dataset]["profile"], self.num_files, seed=self.seed
        )

    def plan(self, memory_bytes: float | None = None) -> ChunkingPlan:
        mem = (memory_bytes or MEMORY_PER_NODE[self.hw]) / self.scale
        return ChunkingPlan.create(
            self.sizes(), self.chunk_size,
            memory_bytes=int(mem * self.nodes), seed=self.seed,
        )

    @property
    def node_memory(self) -> float:
        return MEMORY_PER_NODE[self.hw] / self.scale

    @property
    def remote_limit_scaled(self) -> float:
        return self.remote_limit / self.scale

    @property
    def compute_per_step(self) -> float:
        return MODEL_COMPUTE[(self.model, self.hw)] * BATCH[self.model]

    @property
    def batch(self) -> int:
        return BATCH[self.model]

    @property
    def time_model(self) -> PipelineTimeModel:
        return TIME_MODELS[self.hw]
