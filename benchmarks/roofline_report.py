"""Roofline report: renders artifacts/dryrun.jsonl into the §Roofline table.

(The dry-run itself needs 512 emulated devices and is run separately via
``python -m repro.launch.dryrun``; this benchmark consumes its artifacts so
``python -m benchmarks.run`` stays runnable in a default process.)
"""

from __future__ import annotations

from pathlib import Path

ART = Path("artifacts/dryrun.jsonl")


def main():
    if not ART.exists():
        print("roofline: no artifacts/dryrun.jsonl yet — run repro.launch.dryrun first")
        return
    from repro.launch.roofline import analyze, load_rows, to_markdown

    an = analyze(load_rows(ART))
    ok = [a for a in an if a["status"] == "ok"]
    print(f"roofline: {len(ok)} compiled cells, {len(an) - len(ok)} skips")
    print(to_markdown(an, None))


if __name__ == "__main__":
    main()
