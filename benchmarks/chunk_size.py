"""Paper Figs. 13+14: chunk-size sensitivity (SqueezeNet, 3 A10 nodes).

Sweeps chunk_size 2..256 (+1 = the PyTorch per-file baseline) and reports
I/O throughput, mean times each chunk is loaded per epoch, and epoch time.
Paper: throughput rises monotonically with chunk size, but re-loads rise
too; epoch time bottoms out at chunk_size = 64.
"""

from __future__ import annotations

from repro.core import EpochSampler, PyTorchStyleLoader, run_baseline_epoch

from .calibration import Scenario
from .common import epoch_time, redox_epoch

CHUNK_SIZES = [2, 8, 16, 32, 64, 128, 256]


def run() -> list[dict]:
    rows = []
    base = Scenario("imagenet1k", "A10", "squeezenet", nodes=3)
    # chunk_size = 1 -> native per-file loader
    plan = base.plan()
    loader = PyTorchStyleLoader(plan, base.nodes, int(base.node_memory))
    sampler = EpochSampler(plan.num_files, base.nodes, seed=base.seed + 1)
    stats, io = run_baseline_epoch(loader, sampler, 0, base.batch)
    t = epoch_time(base, io)
    io_s = sum(base.time_model.io_time(s) for steps in io for s in steps)
    rows.append(
        dict(chunk=1, epoch_s=t, throughput_mb_s=stats.disk_bytes / 1e6 / max(io_s, 1e-9),
             loads_per_chunk=1.0, wasted_gb=0.0)
    )
    for c in CHUNK_SIZES:
        scn = Scenario("imagenet1k", "A10", "squeezenet", nodes=3, chunk_size=c)
        res, t = redox_epoch(scn)
        s = res.stats
        io_s = sum(
            scn.time_model.io_time(x) for steps in res.per_node_step_io for x in steps
        )
        plan_c = scn.plan()
        rows.append(
            dict(
                chunk=c, epoch_s=t,
                throughput_mb_s=s.disk_bytes / 1e6 / max(io_s, 1e-9),
                loads_per_chunk=s.chunk_loads / plan_c.num_chunks,
                wasted_gb=s.wasted_bytes / 1e9,
            )
        )
    return rows


def main():
    print("Figs 13+14 — chunk-size sensitivity (SqueezeNet, ImageNet-1k-scaled, 3xA10)")
    print(f"{'chunk':>5s} {'epoch_s':>8s} {'IO MB/s':>8s} {'loads/chunk':>11s} {'wasted_GB':>9s}")
    for r in run():
        print(
            f"{r['chunk']:5d} {r['epoch_s']:8.1f} {r['throughput_mb_s']:8.1f} "
            f"{r['loads_per_chunk']:11.2f} {r['wasted_gb']:9.2f}"
        )


if __name__ == "__main__":
    main()
