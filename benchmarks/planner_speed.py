"""Planner vs per-access epoch throughput on the `overall` scenario grid.

For each scenario the same simulation-mode epoch is executed twice —
through the reference per-access walk (``engine="per_access"``) and through
the vectorized batched engine the clairvoyant planner runs on
(``engine="step"``) — and the wall times are compared. Both engines are
byte-identical (``tests/test_planner.py``), so the speedup is pure
mechanics: id-space NumPy batching vs the per-file Python hot loop.
"""

from __future__ import annotations

import time

from repro.core import Cluster, EpochSampler

from .calibration import Scenario
from .overall import SCENARIOS


def _epoch_wall(scn: Scenario, engine: str) -> tuple[float, int]:
    plan = scn.plan()
    cluster = Cluster(
        plan,
        scn.nodes,
        remote_memory_limit_bytes=int(scn.remote_limit_scaled),
        prefetch_window=512,
        seed=scn.seed,
    )
    sampler = EpochSampler(plan.num_files, scn.nodes, seed=scn.seed + 1)
    t0 = time.perf_counter()
    res = cluster.run_epoch(
        sampler, 0, scn.batch, collect_returned=False, engine=engine
    )
    return time.perf_counter() - t0, res.stats.accesses


def run(quick: bool = False) -> list[dict]:
    rows = []
    scenarios = SCENARIOS[:4] if quick else SCENARIOS
    for fig, ds, hw, model, nodes in scenarios:
        scale = 100 if ds == "imagenet21k" else 20
        scn = Scenario(ds, hw, model, nodes=nodes, scale=scale)
        t_step, accesses = _epoch_wall(scn, "step")
        t_pa, _ = _epoch_wall(scn, "per_access")
        rows.append(
            dict(
                fig=fig, dataset=ds, hw=hw, model=model, nodes=nodes,
                accesses=accesses,
                per_access_s=t_pa, planner_s=t_step,
                per_access_kacc_s=accesses / t_pa / 1e3,
                planner_kacc_s=accesses / t_step / 1e3,
                speedup=t_pa / t_step,
            )
        )
    total_pa = sum(r["per_access_s"] for r in rows)
    total_step = sum(r["planner_s"] for r in rows)
    rows.append(
        dict(
            fig="grid", dataset="aggregate", hw="-", model="-", nodes=0,
            accesses=sum(r["accesses"] for r in rows),
            per_access_s=total_pa, planner_s=total_step,
            per_access_kacc_s=0.0, planner_kacc_s=0.0,
            speedup=total_pa / total_step,
        )
    )
    return rows


def main(quick: bool = False) -> list[dict]:
    print("Planner (batched id-space) vs per-access epoch walk — overall grid")
    print(
        f"{'fig':7s} {'model':12s} {'hw':5s} {'n':>2s} {'per_acc_s':>9s} "
        f"{'planner_s':>9s} {'kacc/s pa':>9s} {'kacc/s pl':>9s} {'speedup':>7s}"
    )
    rows = run(quick)
    for r in rows:
        print(
            f"{r['fig']:7s} {r['model']:12s} {r['hw']:5s} {r['nodes']:2d} "
            f"{r['per_access_s']:9.2f} {r['planner_s']:9.2f} "
            f"{r['per_access_kacc_s']:9.1f} {r['planner_kacc_s']:9.1f} "
            f"{r['speedup']:6.2f}x"
        )
    return rows


if __name__ == "__main__":
    import argparse

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--quick", action="store_true")
    main(quick=_ap.parse_args().quick)
