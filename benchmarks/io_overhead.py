"""Paper Table 1 + per-backend chunk-read throughput.

Default mode reproduces the motivating measurement: train three CV models
on ImageNet-1k (P100 profile, 3 nodes) with the native per-file loader and
report epoch time, I/O-only time, and overhead percentage.

``--backend {vfs,mmap,parallel,all}`` instead runs a *real-bytes* epoch
(an actual on-disk chunk store served through ``RedoxLoader.epoch_async``)
once per storage backend and reports observed chunk-read throughput —
bytes batched in per second the protocol spent blocked on storage. The
parallel backend's readahead overlaps chunk reads with decode/assembly,
so it beats vfs on any multi-chunk epoch with real storage latency
(``--latency-ms`` emulates the NAS per-op head time of calibration.py).

    PYTHONPATH=src python benchmarks/io_overhead.py --backend all
"""

from __future__ import annotations

import argparse

try:
    from .calibration import Scenario
    from .common import (
        BACKEND_NAMES,
        backend_report,
        expand_backends,
        print_backend_table,
        run_scenario,
    )
except ImportError:  # executed as a script: python benchmarks/io_overhead.py
    import sys
    from pathlib import Path

    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT), str(_ROOT / "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    from benchmarks.calibration import Scenario
    from benchmarks.common import (
        BACKEND_NAMES,
        backend_report,
        expand_backends,
        print_backend_table,
        run_scenario,
    )

PAPER = {"squeezenet": 91, "mobilenetv3": 82, "resnet50": 65}

BACKEND_CHOICES = BACKEND_NAMES + ("all",)


def run() -> list[tuple]:
    rows = []
    for model, paper_pct in PAPER.items():
        scn = Scenario("imagenet1k", "P100", model, nodes=3)
        res = run_scenario(scn, loaders=("pytorch", "no_io"))
        t_total = res["pytorch"][0]
        t_compute = res["no_io"][0]
        io_pct = 100.0 * (t_total - t_compute) / t_total
        rows.append(
            ("table1/io_overhead", model, t_total, t_compute, io_pct, paper_pct)
        )
    return rows


def run_backends(backend: str, latency_ms: float = 2.0) -> list[dict]:
    return backend_report(expand_backends(backend), latency_ms=latency_ms)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--backend", choices=BACKEND_CHOICES, default=None,
        help="run the real-bytes per-backend throughput benchmark instead",
    )
    ap.add_argument(
        "--latency-ms", type=float, default=2.0,
        help="emulated per-chunk-read storage head latency (NAS profile)",
    )
    args = ap.parse_args(argv)
    if args.backend:
        print(
            f"Per-backend chunk-read throughput (real bytes, epoch_async, "
            f"latency={args.latency_ms:g} ms/op)"
        )
        print_backend_table(run_backends(args.backend, args.latency_ms))
        return
    print("Table 1 — I/O overhead (PyTorch loader, ImageNet-1k-scaled, 3xP100)")
    print(f"{'model':14s} {'epoch_s':>9s} {'compute_s':>9s} {'io_pct':>7s} {'paper':>6s}")
    for _, model, t, c, pct, paper in run():
        print(f"{model:14s} {t:9.1f} {c:9.1f} {pct:6.1f}% {paper:5d}%")


if __name__ == "__main__":
    main()
