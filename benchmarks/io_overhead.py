"""Paper Table 1: I/O overhead percentage of epoch time (PyTorch loader).

Reproduces the motivating measurement: train three CV models on
ImageNet-1k (P100 profile, 3 nodes) with the native per-file loader and
report epoch time, I/O-only time, and overhead percentage.
"""

from __future__ import annotations

from .calibration import Scenario
from .common import run_scenario

PAPER = {"squeezenet": 91, "mobilenetv3": 82, "resnet50": 65}


def run() -> list[tuple]:
    rows = []
    for model, paper_pct in PAPER.items():
        scn = Scenario("imagenet1k", "P100", model, nodes=3)
        res = run_scenario(scn, loaders=("pytorch", "no_io"))
        t_total = res["pytorch"][0]
        t_compute = res["no_io"][0]
        io_pct = 100.0 * (t_total - t_compute) / t_total
        rows.append(
            ("table1/io_overhead", model, t_total, t_compute, io_pct, paper_pct)
        )
    return rows


def main():
    print("Table 1 — I/O overhead (PyTorch loader, ImageNet-1k-scaled, 3xP100)")
    print(f"{'model':14s} {'epoch_s':>9s} {'compute_s':>9s} {'io_pct':>7s} {'paper':>6s}")
    for _, model, t, c, pct, paper in run():
        print(f"{model:14s} {t:9.1f} {c:9.1f} {pct:6.1f}% {paper:5d}%")


if __name__ == "__main__":
    main()
