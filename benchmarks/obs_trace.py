"""Traced multi-job epoch: per-stage attribution + metrics snapshot.

Runs one small K-job service epoch under the span tracer (DESIGN.md §13),
folds the trace into the overlap-aware per-stage attribution report, and
snapshots the service metrics registry. ``run.py --json`` saves the raw
Chrome trace (``BENCH_trace.json`` — drop it on ui.perfetto.dev) and the
Prometheus text (``BENCH_metrics.txt``) next to the perf record; CI
uploads both as artifacts so any PR's pipeline shape can be inspected
without rerunning the bench.

The section also pins the report's defining identity on real traffic:
``sum(exclusive_s) + idle_s`` must land within 10% of the measured epoch
wall (it is exact up to float error — the sweep-line attributes every
instant to exactly one stage).
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

from repro.core import ChunkStore, VFSBackend
from repro.data import SyntheticTokenDataset
from repro.obs import attribution, format_report, tracing
from repro.service import DataService
from repro.service.transport.server import service_metrics


def run_traced(
    jobs: int = 2,
    *,
    num_docs: int = 384,
    chunk_size: int = 8,
    groups: int = 8,
    mean_len: int = 64,
    batch: int = 16,
    seq_len: int = 64,
    latency_ms: float = 0.2,
    seed: int = 0,
) -> dict:
    """One traced K-job co-scheduled epoch. Returns the BENCH row plus the
    raw ``chrome`` trace object and ``metrics_text`` exposition."""
    with tempfile.TemporaryDirectory(prefix="redox_obs_") as tmp:
        root = Path(tmp) / "chunks"
        ds = SyntheticTokenDataset(
            num_docs, vocab_size=32000, mean_len=mean_len, seed=seed
        )
        ds.build_store(
            root, chunk_size, num_slots=groups * chunk_size, seed=seed + 1
        )
        store = ChunkStore.open(
            root, backend=VFSBackend(latency_s=latency_ms / 1e3)
        )
        svc = DataService(store)
        for j in range(jobs):
            svc.open_session(
                f"job{j}", seed=seed + 100 * j + 7,
                batch_per_node=batch, seq_len=seq_len,
            )
        with tracing(capacity=1 << 18) as tracer:
            t0 = time.perf_counter()
            steps = sum(1 for _ in svc.co_epoch(0))
            wall = time.perf_counter() - t0
        att = attribution(tracer.events(), wall_s=wall)
        reg = service_metrics(svc)
        for j, st in svc.residency.per_job_stats.items():
            reg.register_stats("service", lambda st=st: st, labels={"job": str(j)})
        row = dict(
            jobs=jobs,
            steps=steps,
            wall_s=wall,
            events=len(tracer),
            dropped=tracer.dropped,
            attribution=att,
            chrome=tracer.to_chrome(),
            metrics_text=reg.exposition(),
        )
        svc.close()
        store.close()
    return row


def main(quick: bool = False) -> dict:
    kw = dict(num_docs=256, latency_ms=0.1) if quick else {}
    res = run_traced(2, **kw)
    print(
        f"traced {res['jobs']}-job epoch: {res['steps']} steps, "
        f"{res['events']} events ({res['dropped']} dropped)"
    )
    print(format_report(res["attribution"], measured_wall_s=res["wall_s"]))
    att = res["attribution"]
    covered = sum(att["exclusive_s"].values()) + att["idle_s"]
    assert abs(covered - res["wall_s"]) <= 0.1 * res["wall_s"], (
        "attribution does not sum to the measured wall: "
        f"{covered:.3f}s vs {res['wall_s']:.3f}s"
    )
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trace", type=Path, default=None, metavar="FILE",
                    help="also write the Chrome trace JSON here")
    args = ap.parse_args()
    if args.jobs == 2:
        out = main(quick=args.quick)
    else:
        out = run_traced(args.jobs)
        print(format_report(out["attribution"], measured_wall_s=out["wall_s"]))
    if args.trace is not None:
        import json

        args.trace.write_text(json.dumps(out["chrome"]))
        print(f"trace -> {args.trace}")
