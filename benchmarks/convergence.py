"""Paper Fig. 15 + Table 7: convergence parity — REAL end-to-end training.

The paper trains ResNet50/ImageNet-1k twice (Redox vs PyTorch) and shows
matching accuracy curves. Here we train a small LM on the synthetic corpus
twice with IDENTICAL init and hyperparameters, differing only in the data
path: (a) Redox loader (redirected, chunk-batched, 3 logical nodes, tiny
memory budget) vs (b) an exact-shuffle in-memory loader. Redox's §4.1
guarantee says both consume uniformly random exactly-once epochs, so the
loss curves must statistically match; Table 7's memory sweep maps to
different slot-count plans (mappings), which must not change convergence.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

import jax

from repro.configs import ARCHS, RunConfig, reduced
from repro.core import Cluster, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.train_step import build_train_step, init_train_state

NUM_DOCS = 1536
VOCAB = 211
BATCH = 24
SEQ = 96


def _train(batches, steps):
    import dataclasses

    cfg = dataclasses.replace(
        reduced(ARCHS["tinyllama-1.1b"]), vocab_size=VOCAB, num_layers=2
    )
    model = build_model(cfg)
    run = RunConfig(optimizer="adamw", learning_rate=3e-3)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, seed=7)
    step_fn = jax.jit(build_train_step(model, run, opt), donate_argnums=0)
    losses = []
    import jax.numpy as jnp

    for i, b in zip(range(steps), batches):
        state, m = step_fn(
            state,
            {
                "tokens": jnp.asarray(b["tokens"]),
                "targets": jnp.asarray(b["targets"]),
                "loss_mask": jnp.asarray(b["loss_mask"]),
            },
        )
        losses.append(float(m["loss"]))
    return losses


def _redox_batches(tmp, epochs, memory_slots):
    ds = SyntheticTokenDataset(NUM_DOCS, VOCAB, mean_len=72, seed=3)
    store = ds.build_store(
        Path(tmp) / f"chunks_{memory_slots}", 8, num_slots=memory_slots, seed=1
    )
    cluster = Cluster(store.plan, 3, store=store, seed=2,
                      remote_memory_limit_bytes=64_000)
    sampler = EpochSampler(NUM_DOCS, 3, seed=11)
    loader = RedoxLoader(cluster, sampler, batch_per_node=BATCH // 3, seq_len=SEQ)
    for e in range(epochs):
        yield from loader.epoch(e)


def _exact_shuffle_batches(epochs):
    """The PyTorch-equivalent baseline: exact global shuffle, same records."""
    ds = SyntheticTokenDataset(NUM_DOCS, VOCAB, mean_len=72, seed=3)
    sampler = EpochSampler(NUM_DOCS, 1, seed=11)
    from repro.core.loader import _to_grid

    for e in range(epochs):
        seq = sampler.global_sequence(e)
        for i in range(len(seq) // BATCH):
            recs = [ds.record_tokens(int(f)) for f in seq[i * BATCH : (i + 1) * BATCH]]
            tokens, mask = _to_grid(recs, SEQ + 1, 0)
            yield dict(
                tokens=tokens[:, :-1], targets=tokens[:, 1:], loss_mask=mask[:, 1:]
            )


def run(steps=120, epochs=3):
    with tempfile.TemporaryDirectory() as tmp:
        redox = _train(_redox_batches(tmp, epochs, memory_slots=96), steps)
        exact = _train(_exact_shuffle_batches(epochs), steps)
        # Table 7 analogue: a different memory capacity -> different mapping
        redox_small = _train(_redox_batches(tmp, epochs, memory_slots=32), steps)
    return redox, exact, redox_small


def main(steps=120):
    redox, exact, redox_small = run(steps)
    k = max(len(redox) // 6, 1)

    def tail(xs):
        return float(np.mean(xs[-2 * k :]))

    print("Fig 15 + Table 7 — convergence parity (real LM training, same init)")
    print(f"{'step':>5s} {'redox':>8s} {'exact_shuffle':>13s} {'redox_small_mem':>15s}")
    for i in range(0, min(len(redox), len(exact)), k):
        print(f"{i:5d} {redox[i]:8.4f} {exact[i]:13.4f} {redox_small[i]:15.4f}")
    t_r, t_e, t_s = tail(redox), tail(exact), tail(redox_small)
    print(f"tail-mean loss: redox={t_r:.4f} exact={t_e:.4f} redox_small={t_s:.4f}")
    assert abs(t_r - t_e) < 0.15, "convergence parity violated"
    assert abs(t_s - t_e) < 0.15, "memory-capacity mapping affected convergence"
    print("convergence parity: OK (paper Fig. 15 / Table 7 reproduced)")


if __name__ == "__main__":
    main()
