"""Paper Table 6 + Fig. 12: remote-abstract-memory limit sweep.

SqueezeNet, ImageNet-1k, 3 A10 nodes; limits 50 MB .. 3 GB (scaled).
Paper: usage saturates ~1.5 GB; epoch time is best there (0.63 h) and
regresses slightly beyond (memory stolen from the local abstract memory).
"""

from __future__ import annotations

from .calibration import Scenario
from .common import redox_epoch

LIMITS = [50e6, 500e6, 1e9, 1.5e9, 2e9, 3e9]


def run() -> list[dict]:
    rows = []
    for limit in LIMITS:
        scn = Scenario("imagenet1k", "A10", "squeezenet", nodes=3)
        res, t = redox_epoch(scn, remote_limit=limit / scn.scale)
        peak = max(s.peak_remote_bytes for s in res.node_stats)
        rows.append(
            dict(
                limit_gb=limit / 1e9,
                epoch_s=t,
                peak_remote_gb=peak * scn.scale / 1e9,  # unscaled equivalent
                prefetch_hits=res.stats.remote_prefetch_hits,
                remote_requests=res.stats.remote_requests,
            )
        )
    return rows


def main():
    print("Table 6 + Fig 12 — remote abstract memory limit sweep (SqueezeNet, 3xA10)")
    print(f"{'limit_GB':>8s} {'epoch_s':>8s} {'peak_GB':>8s} {'pf_hits':>8s} {'remote_req':>10s}")
    for r in run():
        print(
            f"{r['limit_gb']:8.2f} {r['epoch_s']:8.1f} {r['peak_remote_gb']:8.2f} "
            f"{r['prefetch_hits']:8d} {r['remote_requests']:10d}"
        )


if __name__ == "__main__":
    main()
