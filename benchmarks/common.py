"""Shared benchmark driver: run one epoch of each loader under a scenario."""

from __future__ import annotations

import time

from repro.core import (
    Cluster,
    CoorDLLoader,
    EpochSampler,
    NoIOLoader,
    PyTorchStyleLoader,
    run_baseline_epoch,
)

from .calibration import Scenario

__all__ = ["run_scenario", "epoch_time", "redox_epoch"]


def epoch_time(scn: Scenario, per_node_step_io) -> float:
    return scn.time_model.epoch_time(per_node_step_io, scn.compute_per_step)


def redox_epoch(
    scn: Scenario,
    *,
    policy: str = "max_fill",
    prefetch: bool = True,
    epoch: int = 0,
    chunk_size: int | None = None,
    remote_limit: float | None = None,
):
    plan = scn.plan() if chunk_size is None else Scenario(
        **{**scn.__dict__, "chunk_size": chunk_size}
    ).plan()
    cluster = Cluster(
        plan,
        scn.nodes,
        remote_memory_limit_bytes=int(remote_limit or scn.remote_limit_scaled),
        # Deep lookahead so remote-memory usage is limit-bound, not
        # window-bound (paper Fig. 12 saturates at ~1.5 GB of prefetches).
        prefetch_window=512,
        policy=policy,
        prefetch=prefetch,
        seed=scn.seed,
    )
    sampler = EpochSampler(plan.num_files, scn.nodes, seed=scn.seed + 1)
    res = cluster.run_epoch(sampler, epoch, scn.batch, collect_returned=False)
    return res, epoch_time(scn, res.per_node_step_io)


def run_scenario(scn: Scenario, loaders=("pytorch", "coordl", "redox", "no_io")):
    """Returns {loader: (epoch_time_s, stats)} for one scenario."""
    plan = scn.plan()
    sampler = EpochSampler(plan.num_files, scn.nodes, seed=scn.seed + 1)
    out = {}
    for name in loaders:
        t0 = time.time()
        if name == "redox":
            res, t = redox_epoch(scn)
            out[name] = (t, res.stats)
        else:
            loader = {
                "pytorch": lambda: PyTorchStyleLoader(plan, scn.nodes, int(scn.node_memory)),
                "coordl": lambda: CoorDLLoader(plan, scn.nodes, int(scn.node_memory)),
                "no_io": lambda: NoIOLoader(plan, scn.nodes),
            }[name]()
            stats, io = run_baseline_epoch(loader, sampler, 0, scn.batch)
            out[name] = (epoch_time(scn, io), stats)
    return out
