"""Shared benchmark driver: run one epoch of each loader under a scenario.

Two modes:

* *Simulated* (:func:`run_scenario`): protocol-exact counters priced by the
  calibrated :class:`PipelineTimeModel` — reproduces the paper's tables.
* *Real-bytes* (:func:`backend_report`): an actual on-disk chunk store is
  built and an epoch is served through ``RedoxLoader.epoch_async``, once
  per storage backend — measures observed chunk-read throughput (bytes
  batched in per second the protocol spent blocked on storage).
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import (
    ChunkingPlan,
    ChunkStore,
    Cluster,
    CoorDLLoader,
    EpochSampler,
    MmapBackend,
    NoIOLoader,
    ParallelBackend,
    PyTorchStyleLoader,
    RedoxLoader,
    VFSBackend,
    run_baseline_epoch,
)

from .calibration import Scenario

__all__ = [
    "BACKEND_NAMES",
    "backend_report",
    "epoch_time",
    "expand_backends",
    "print_backend_table",
    "redox_epoch",
    "run_scenario",
]

BACKEND_NAMES = ("vfs", "mmap", "parallel")


def expand_backends(selection: str) -> tuple:
    """CLI helper: ``"all"`` -> every backend, else the one named."""
    return BACKEND_NAMES if selection == "all" else (selection,)


def epoch_time(scn: Scenario, per_node_step_io) -> float:
    return scn.time_model.epoch_time(per_node_step_io, scn.compute_per_step)


def redox_epoch(
    scn: Scenario,
    *,
    policy: str = "max_fill",
    prefetch: bool = True,
    epoch: int = 0,
    chunk_size: int | None = None,
    remote_limit: float | None = None,
):
    plan = scn.plan() if chunk_size is None else Scenario(
        **{**scn.__dict__, "chunk_size": chunk_size}
    ).plan()
    cluster = Cluster(
        plan,
        scn.nodes,
        remote_memory_limit_bytes=int(remote_limit or scn.remote_limit_scaled),
        # Deep lookahead so remote-memory usage is limit-bound, not
        # window-bound (paper Fig. 12 saturates at ~1.5 GB of prefetches).
        prefetch_window=512,
        policy=policy,
        prefetch=prefetch,
        seed=scn.seed,
    )
    sampler = EpochSampler(plan.num_files, scn.nodes, seed=scn.seed + 1)
    res = cluster.run_epoch(sampler, epoch, scn.batch, collect_returned=False)
    return res, epoch_time(scn, res.per_node_step_io)


class _UniformTokenRecords:
    """Deterministic random int32-token records, generated vectorised."""

    def __init__(self, lengths: np.ndarray, vocab: int, seed: int):
        self.lengths = lengths
        self.vocab = vocab
        self.seed = seed

    def __getitem__(self, i: int) -> bytes:
        rng = np.random.default_rng((self.seed, 29, i))
        n = int(self.lengths[i])
        return rng.integers(0, self.vocab, n, dtype=np.int32).tobytes()


def _build_bench_store(
    root: Path, *, num_docs: int, mean_tokens: int, chunk_size: int,
    groups: int, seed: int,
) -> ChunkStore:
    rng = np.random.default_rng((seed, 31))
    lengths = rng.integers(mean_tokens // 2, 3 * mean_tokens // 2, num_docs)
    records = _UniformTokenRecords(lengths.astype(np.int64), vocab=32000, seed=seed)
    plan = ChunkingPlan.create(
        lengths.astype(np.int64) * 4, chunk_size,
        num_slots=groups * chunk_size, seed=seed,
    )
    return ChunkStore.build(root, plan, records)


def _bench_backend(name: str, latency_s: float):
    """Backend instances for the benchmark, sharing storage characteristics.

    vfs and parallel read through the same VFS profile (incl. the emulated
    per-op NAS head latency — see ``VFSBackend``), so their comparison
    isolates the overlap the parallel pipeline buys. mmap models the
    zero-copy page-cache path (no per-op syscall to pay latency on).
    """
    if name == "vfs":
        return VFSBackend(latency_s=latency_s)
    if name == "mmap":
        return MmapBackend()
    if name == "parallel":
        return ParallelBackend(
            VFSBackend(latency_s=latency_s), workers=4, readahead=24
        )
    raise ValueError(f"unknown benchmark backend {name!r}")


def backend_report(
    backends=("vfs", "mmap", "parallel"),
    *,
    num_docs: int = 2048,
    mean_tokens: int = 4096,
    chunk_size: int = 32,
    groups: int = 8,
    nodes: int = 1,
    batch_per_node: int = 32,
    seq_len: int = 512,
    queue_depth: int = 4,
    latency_ms: float = 2.0,
    seed: int = 0,
) -> list[dict]:
    """One real-bytes ``epoch_async`` per backend over the same chunk store.

    Returns one row per backend with wall time, the protocol's blocked
    read-wait, delivered chunk bytes, the derived chunk-read throughput,
    and the parallel backend's readahead counters. ``latency_ms`` is the
    emulated per-chunk-read storage head time (NAS profile; 0 to disable —
    but then local page-cached reads are memcpys and there is no storage
    stall left for any backend to hide).
    """
    rows = []
    with tempfile.TemporaryDirectory(prefix="redox_bench_") as tmp:
        root = Path(tmp) / "chunks"
        _build_bench_store(
            root, num_docs=num_docs, mean_tokens=mean_tokens,
            chunk_size=chunk_size, groups=groups, seed=seed,
        )
        for name in backends:
            store = ChunkStore.open(
                root, backend=_bench_backend(name, latency_ms / 1e3)
            )
            cluster = Cluster(store.plan, nodes, store=store, seed=seed + 2)
            sampler = EpochSampler(store.plan.num_files, nodes, seed=seed + 3)
            loader = RedoxLoader(
                cluster, sampler, batch_per_node=batch_per_node,
                seq_len=seq_len, queue_depth=queue_depth,
            )
            t0 = time.perf_counter()
            steps = sum(1 for _ in loader.epoch_async(0))
            wall = time.perf_counter() - t0
            agg = cluster.nodes[0].stats
            for n in cluster.nodes[1:]:
                agg = agg.merge(n.stats)
            b = store.backend_stats
            rows.append(dict(
                backend=name, steps=steps, wall_s=wall,
                read_wait_s=agg.read_wait_s,
                disk_mb=agg.disk_bytes / 1e6,
                throughput_mbs=agg.read_throughput / 1e6,
                chunk_loads=agg.chunk_loads,
                # loader epochs are planner-driven: readahead hits come from
                # the exact schedule; heuristic hints are the fallback
                sched_hits=b.scheduled_hits,
                prefetch_hits=b.prefetch_hits,
                # blocked time split: readahead futures not done in time vs
                # inline cold-miss reads (sync backends: all in future_wait)
                future_wait_s=b.wait_seconds,
                miss_read_s=b.miss_read_seconds,
                peak_inflight=b.peak_inflight,
            ))
            store.close()
    return rows


def print_backend_table(rows: list[dict]) -> None:
    print(
        f"{'backend':9s} {'steps':>5s} {'wall_s':>7s} {'read_wait_s':>11s} "
        f"{'miss_s':>7s} {'disk_MB':>8s} {'MB/s':>8s} {'loads':>6s} "
        f"{'sched':>6s} {'ra_hits':>7s} {'inflight':>8s}"
    )
    for r in rows:
        print(
            f"{r['backend']:9s} {r['steps']:5d} {r['wall_s']:7.2f} "
            f"{r['read_wait_s']:11.4f} {r['miss_read_s']:7.4f} "
            f"{r['disk_mb']:8.1f} "
            f"{r['throughput_mbs']:8.1f} {r['chunk_loads']:6d} "
            f"{r['sched_hits']:6d} {r['prefetch_hits']:7d} {r['peak_inflight']:8d}"
        )


def run_scenario(scn: Scenario, loaders=("pytorch", "coordl", "redox", "no_io")):
    """Returns {loader: (epoch_time_s, stats)} for one scenario."""
    plan = scn.plan()
    sampler = EpochSampler(plan.num_files, scn.nodes, seed=scn.seed + 1)
    out = {}
    for name in loaders:
        t0 = time.time()
        if name == "redox":
            res, t = redox_epoch(scn)
            out[name] = (t, res.stats)
        else:
            loader = {
                "pytorch": lambda: PyTorchStyleLoader(plan, scn.nodes, int(scn.node_memory)),
                "coordl": lambda: CoorDLLoader(plan, scn.nodes, int(scn.node_memory)),
                "no_io": lambda: NoIOLoader(plan, scn.nodes),
            }[name]()
            stats, io = run_baseline_epoch(loader, sampler, 0, scn.batch)
            out[name] = (epoch_time(scn, io), stats)
    return out
