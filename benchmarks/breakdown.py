"""Paper Tables 4+5: ablation breakdown (ResNet50, ImageNet-1k, 3 A10 nodes).

Variants:
  Redox-no-optimization  = random refill selection, no prefetch
  Redox-no-prefetching   = max-fill selection,     no prefetch
  Redox-random-selection = random refill selection, prefetch
  Redox (full)           = max-fill selection,      prefetch
Paper ordering: 0.93 > 0.87 > 0.76 > 0.71 h epoch; prefetching collapses
remote requests (8.54e5 -> 0.46e5) and both optimizations cut misses.
"""

from __future__ import annotations

from .calibration import Scenario
from .common import redox_epoch

VARIANTS = [
    ("no_optimization", "random", False),
    ("no_prefetching", "max_fill", False),
    ("random_selection", "random", True),
    ("full", "max_fill", True),
]


def run() -> list[dict]:
    scn = Scenario("imagenet1k", "A10", "resnet50", nodes=3)
    rows = []
    for name, policy, prefetch in VARIANTS:
        res, t = redox_epoch(scn, policy=policy, prefetch=prefetch)
        s = res.stats
        rows.append(
            dict(
                variant=name, epoch_s=t,
                memory_misses=s.memory_misses,
                remote_requests=s.remote_requests,
                prefetch_hits=s.remote_prefetch_hits,
                mean_fill_rate=s.mean_fill_rate,
                wasted_gb=s.wasted_bytes / 1e9,
            )
        )
    return rows


def main():
    print("Tables 4+5 — ablation breakdown (ResNet50, ImageNet-1k-scaled, 3xA10)")
    print(
        f"{'variant':18s} {'epoch_s':>8s} {'misses':>8s} {'remote_req':>10s} "
        f"{'pf_hits':>8s} {'fill_rate':>9s} {'wasted_GB':>9s}"
    )
    for r in run():
        print(
            f"{r['variant']:18s} {r['epoch_s']:8.1f} {r['memory_misses']:8d} "
            f"{r['remote_requests']:10d} {r['prefetch_hits']:8d} "
            f"{r['mean_fill_rate']:9.3f} {r['wasted_gb']:9.2f}"
        )


if __name__ == "__main__":
    main()
