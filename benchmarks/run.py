"""Benchmark harness entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
Prints per-benchmark tables plus a machine-readable `name,value,derived`
CSV summary at the end.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced scenario grid")
    args = ap.parse_args()

    from . import breakdown, chunk_size, convergence, io_overhead, overall, roofline_report

    csv_rows: list[tuple] = []

    def section(title, fn):
        print("\n" + "=" * 78)
        print(title)
        print("=" * 78)
        t0 = time.time()
        fn()
        csv_rows.append((title.split(" ")[0], f"{time.time()-t0:.1f}s"))

    section("Table 1: I/O overhead", lambda: io_overhead.main([]))
    section(
        "Storage backends: chunk-read throughput",
        lambda: io_overhead.main(["--backend", "all"]),
    )
    section("Figs 9-11: overall speedups", lambda: overall.main(quick=args.quick))
    section("Tables 4+5: ablation breakdown", breakdown.main)
    if not args.quick:
        from . import remote_memory

        section("Table 6 + Fig 12: remote memory sweep", remote_memory.main)
    section("Figs 13+14: chunk-size sensitivity", chunk_size.main)
    section("Fig 15 + Table 7: convergence parity", convergence.main)
    section("Roofline (from dry-run artifacts)", roofline_report.main)

    print("\nname,us_per_call,derived")
    for name, t in csv_rows:
        print(f"{name},{t},see section above")


if __name__ == "__main__":
    main()
