"""Benchmark harness entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--json BENCH_run.json]

Prints per-benchmark tables plus a machine-readable `name,value,derived`
CSV summary at the end. ``--json`` additionally writes a structured perf
record — per-section wall time, planner vs per-access epoch throughput,
per-backend chunk-read MB/s, and the device data path's kernel parity +
end-to-end tokens/sec (naive vs staged vs gather, with overlap fraction)
— so the perf trajectory is tracked across PRs (CI uploads it as an
artifact).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced scenario grid")
    ap.add_argument(
        "--json", type=Path, default=None, metavar="BENCH_run.json",
        help="write a machine-readable perf record to this path",
    )
    args = ap.parse_args()

    from . import (
        breakdown,
        chunk_size,
        compression,
        convergence,
        device_path,
        eviction,
        io_overhead,
        multi_job,
        obs_trace,
        overall,
        planner_speed,
        roofline_report,
        service_transport,
    )

    csv_rows: list[tuple] = []
    record: dict = {
        "quick": args.quick,
        "python": platform.python_version(),
        "sections": [],
    }

    def section(title, fn, key=None):
        print("\n" + "=" * 78)
        print(title)
        print("=" * 78)
        t0 = time.time()
        rows = fn()
        wall = time.time() - t0
        csv_rows.append((title.split(" ")[0], f"{wall:.1f}s"))
        record["sections"].append({"title": title, "wall_s": round(wall, 3)})
        if key is not None and rows is not None:
            record[key] = rows

    def backends_section():
        rows = io_overhead.run_backends("all")
        io_overhead.print_backend_table(rows)
        return rows

    def overall_section():
        rows = overall.run(quick=args.quick)
        overall.print_table(rows)
        return rows

    def obs_section():
        res = obs_trace.main(quick=args.quick)
        chrome = res.pop("chrome")
        metrics_text = res.pop("metrics_text")
        if args.json is not None:
            trace_path = args.json.with_name("BENCH_trace.json")
            trace_path.write_text(json.dumps(chrome))
            metrics_path = args.json.with_name("BENCH_metrics.txt")
            metrics_path.write_text(metrics_text)
            print(f"trace -> {trace_path}; metrics -> {metrics_path}")
        return res

    section("Table 1: I/O overhead", lambda: io_overhead.main([]))
    section(
        "Storage backends: chunk-read throughput (MB/s)",
        backends_section,
        key="backends",
    )
    section(
        "Planner vs per-access epoch throughput",
        lambda: planner_speed.main(quick=args.quick),
        key="planner",
    )
    section(
        "Multi-job data service: shared-cache aggregate throughput",
        lambda: multi_job.main(quick=args.quick),
        key="multi_job",
    )
    section(
        "Belady vs LRU eviction under shared-cache byte caps",
        lambda: eviction.main(quick=args.quick),
        key="eviction",
    )
    section(
        "Compressed & progressive storage: physical bytes vs epoch parity",
        lambda: compression.main(quick=args.quick),
        key="compression",
    )
    section(
        "Out-of-process transport: ring throughput + batch latency",
        lambda: service_transport.main(quick=args.quick),
        key="transport",
    )
    section(
        "Device data path: kernel parity + staged vs naive tokens/sec",
        lambda: device_path.main(quick=args.quick),
        key="device_path",
    )
    section(
        "Observability: traced epoch attribution (DESIGN.md §13)",
        obs_section,
        key="obs",
    )
    section("Figs 9-11: overall speedups", overall_section, key="overall")
    section("Tables 4+5: ablation breakdown", breakdown.main)
    if not args.quick:
        from . import remote_memory

        section("Table 6 + Fig 12: remote memory sweep", remote_memory.main)
    section("Figs 13+14: chunk-size sensitivity", chunk_size.main)
    section("Fig 15 + Table 7: convergence parity", convergence.main)
    section("Roofline (from dry-run artifacts)", roofline_report.main)

    print("\nname,us_per_call,derived")
    for name, t in csv_rows:
        print(f"{name},{t},see section above")

    if args.json is not None:
        args.json.write_text(json.dumps(record, indent=2, default=float))
        print(f"\nperf record written to {args.json}")


if __name__ == "__main__":
    main()
