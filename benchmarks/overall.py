"""Paper Figs. 9/10/11: overall epoch time — Redox vs PyTorch/CoorDL/No-I/O.

Scenarios mirror the paper's evaluation matrix:
  Fig. 9  — wav2vec2 on LibriSpeech, 1/3/5 A10 nodes
  Fig. 10 — squeezenet/mobilenetv3/resnet50 on ImageNet-1k, 3+5 A10 and
            1+3 P100 nodes
  Fig. 11 — densenet121/vgg16 on ImageNet-21k, 2+3 A100 nodes
Paper headline: Redox up to 4.57x vs PyTorch, up to 1.96x vs CoorDL.
"""

from __future__ import annotations

import argparse

from .calibration import Scenario
from .common import (
    BACKEND_NAMES,
    backend_report,
    expand_backends,
    print_backend_table,
    run_scenario,
)

SCENARIOS = [
    # (figure, dataset, hw, model, nodes)
    ("fig9", "librispeech", "A10", "wav2vec2", 1),
    ("fig9", "librispeech", "A10", "wav2vec2", 3),
    ("fig9", "librispeech", "A10", "wav2vec2", 5),
    ("fig10a", "imagenet1k", "A10", "squeezenet", 3),
    ("fig10a", "imagenet1k", "A10", "resnet50", 3),
    ("fig10b", "imagenet1k", "A10", "squeezenet", 5),
    ("fig10c", "imagenet1k", "P100", "squeezenet", 1),
    ("fig10c", "imagenet1k", "P100", "resnet50", 1),
    ("fig10d", "imagenet1k", "P100", "squeezenet", 3),  # paper's 4.57x headline cell
    ("fig10d", "imagenet1k", "P100", "resnet50", 3),
    ("fig11", "imagenet21k", "A100", "densenet121", 3),
    ("fig11", "imagenet21k", "A100", "vgg16", 3),
]


def run(quick: bool = False) -> list[dict]:
    rows = []
    scenarios = SCENARIOS if not quick else SCENARIOS[:4]
    for fig, ds, hw, model, nodes in scenarios:
        scale = 100 if ds == "imagenet21k" else 20
        scn = Scenario(ds, hw, model, nodes=nodes, scale=scale)
        res = run_scenario(scn)
        t = {k: v[0] for k, v in res.items()}
        rows.append(
            dict(
                fig=fig, dataset=ds, hw=hw, model=model, nodes=nodes,
                pytorch_s=t["pytorch"], coordl_s=t["coordl"],
                redox_s=t["redox"], no_io_s=t["no_io"],
                speedup_vs_pytorch=t["pytorch"] / t["redox"],
                speedup_vs_coordl=t["coordl"] / t["redox"],
            )
        )
    return rows


def print_table(rows: list[dict]) -> None:
    print("Figs 9-11 — overall epoch time (scaled datasets; ratios comparable to paper)")
    hdr = f"{'fig':7s} {'model':12s} {'hw':5s} {'n':>2s} {'pytorch':>9s} {'coordl':>9s} {'redox':>9s} {'no_io':>9s} {'xPT':>6s} {'xCDL':>6s}"
    print(hdr)
    for r in rows:
        print(
            f"{r['fig']:7s} {r['model']:12s} {r['hw']:5s} {r['nodes']:2d} "
            f"{r['pytorch_s']:9.1f} {r['coordl_s']:9.1f} {r['redox_s']:9.1f} "
            f"{r['no_io_s']:9.1f} {r['speedup_vs_pytorch']:6.2f} {r['speedup_vs_coordl']:6.2f}"
        )


def main(quick: bool = False, backend: str | None = None):
    print_table(run(quick))
    if backend:
        print("\nPer-backend chunk-read throughput (real bytes, epoch_async)")
        print_backend_table(backend_report(expand_backends(backend)))


if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--quick", action="store_true")
    _ap.add_argument("--backend", choices=BACKEND_NAMES + ("all",), default=None)
    _args = _ap.parse_args()
    main(quick=_args.quick, backend=_args.backend)
