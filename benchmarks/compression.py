"""Compressed & progressive chunk storage: physical bytes vs epoch parity.

Builds the SAME dataset three ways — raw, zlib-framed, lz4-framed — and
serves one full-fidelity ``RedoxLoader`` epoch per storage backend from
each. Two claims ride on every row pair (DESIGN.md §15):

* **strictly fewer physical bytes**: the backend's ``bytes_read`` on a
  compressed store (frames straight off disk; decode happens above the
  backend or on its worker pool) is below the raw store's, per backend;
* **byte-identical stream**: at full fidelity the token/returned stream
  the trainer consumes is exactly the raw store's — compression is a
  byte-representation choice, never a semantics one.

A final set of rows reads the zlib store at ``fidelity=1`` — the
truncated-prefix mode the autotuner picks for I/O-bound jobs — reporting
how far the *logical* bytes drop below full fidelity.

The advisory CI check rides on ``main()``'s asserts.
"""

from __future__ import annotations

import argparse
import hashlib
import tempfile
import time
from pathlib import Path

from repro.core import ChunkStore, RedoxLoader, SessionSpec
from repro.data import SyntheticTokenDataset

from .common import BACKEND_NAMES

#: (label, build kwargs) — raw first: it is the parity reference.
VARIANTS = (
    ("raw", {}),
    ("zlib", {"codec": "zlib", "bands": 2}),
    ("lz4", {"codec": "lz4", "bands": 2}),
)


def _build_variants(base: Path, *, num_docs: int, mean_len: int,
                    seed: int) -> "dict[str, Path]":
    ds = SyntheticTokenDataset(num_docs, vocab_size=512, mean_len=mean_len,
                               seed=seed)
    roots = {}
    for label, kwargs in VARIANTS:
        root = base / label
        ds.build_store(root, 4, num_slots=16, seed=seed + 1, **kwargs).close()
        roots[label] = root
    return roots


def _epoch(root: Path, backend: str, spec: SessionSpec) -> dict:
    """One epoch; returns the stream digest + physical/logical byte rows."""
    store = ChunkStore.open(root, backend=backend)
    loader = RedoxLoader.from_spec(spec, store)
    digest = hashlib.sha256()
    logical = 0
    t0 = time.perf_counter()
    for batch in loader.epoch_async(0):
        digest.update(batch["tokens"].tobytes())
        digest.update(batch["returned"].tobytes())
        logical += int(batch["loss_mask"].sum()) * 4
    wall = time.perf_counter() - t0
    st = store.backend_stats
    disk = sum(
        store.chunk_path(k).stat().st_size for k in range(store.plan.num_chunks)
    )
    row = dict(
        physical_mb=st.bytes_read / 1e6,
        disk_mb=disk / 1e6,
        logical_mb=logical / 1e6,
        decode_s=st.decode_seconds,
        wall_s=wall,
        digest=digest.hexdigest(),
    )
    store.close()
    return row


def run_grid(*, num_docs: int = 384, mean_len: int = 48,
             seed: int = 5) -> "list[dict]":
    """One row per (variant, backend) at full fidelity, plus a
    ``fidelity=1`` row per backend on the zlib store."""
    spec = SessionSpec(seed=2, sampler_seed=4, batch_per_node=16, seq_len=64)
    rows: "list[dict]" = []
    with tempfile.TemporaryDirectory(prefix="redox_compress_") as tmp:
        roots = _build_variants(Path(tmp), num_docs=num_docs,
                                mean_len=mean_len, seed=seed)
        for label, _ in VARIANTS:
            for backend in BACKEND_NAMES:
                r = _epoch(roots[label], backend, spec)
                r.update(variant=label, backend=backend, fidelity="full")
                rows.append(r)
        lo = SessionSpec(seed=2, sampler_seed=4, batch_per_node=16,
                         seq_len=64, fidelity=1)
        for backend in BACKEND_NAMES:
            r = _epoch(roots["zlib"], backend, lo)
            r.update(variant="zlib", backend=backend, fidelity="1/2")
            rows.append(r)
    return rows


def print_table(rows: "list[dict]") -> None:
    print(
        f"{'variant':>8s} {'backend':>8s} {'fid':>4s} {'disk_MB':>8s} "
        f"{'phys_MB':>8s} {'logic_MB':>8s} {'decode_s':>8s} {'wall_s':>7s}"
    )
    for r in rows:
        print(
            f"{r['variant']:>8s} {r['backend']:>8s} {r['fidelity']:>4s} "
            f"{r['disk_mb']:8.2f} {r['physical_mb']:8.2f} "
            f"{r['logical_mb']:8.2f} {r['decode_s']:8.3f} {r['wall_s']:7.2f}"
        )


def main(quick: bool = False) -> "list[dict]":
    rows = run_grid(num_docs=192 if quick else 384)
    print_table(rows)
    ref = {
        r["backend"]: r for r in rows
        if r["variant"] == "raw" and r["fidelity"] == "full"
    }
    for r in rows:
        if r["fidelity"] != "full":
            continue
        base = ref[r["backend"]]
        assert r["digest"] == base["digest"], (
            f"{r['variant']}/{r['backend']}: full-fidelity stream is NOT "
            f"byte-identical to raw"
        )
        if r["variant"] != "raw":
            assert r["physical_mb"] < base["physical_mb"], (
                f"{r['variant']}/{r['backend']}: compressed read "
                f"{r['physical_mb']:.2f}MB, raw read "
                f"{base['physical_mb']:.2f}MB — no physical saving"
            )
    for r in rows:
        if r["fidelity"] == "full":
            continue
        assert r["logical_mb"] < ref[r["backend"]]["logical_mb"], (
            f"truncated fidelity served no fewer logical bytes on "
            f"{r['backend']}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
