"""Device data path: kernel parity/throughput + staged vs naive tokens/sec.

Three tables (DESIGN.md §12):

1. **Kernel parity** — every kernel in the ``repro.kernels.parity``
   registry against its pure-jnp oracle, per (shape, dtype), with the
   per-dtype tolerance it must meet. The same grid ``tests/
   test_kernel_parity.py`` enforces, printed with the observed errors.
2. **Kernel throughput** — best-of-N wall time kernel vs oracle and
   delivered output MB/s. On this CPU container the Pallas kernels run in
   interpret mode, so absolute numbers only rank shapes; on a TPU the
   same table reads as real bandwidth.
3. **End-to-end device path** — the ``examples/train_lm.py --preset
   small`` data plane (real chunk store on disk, redirection protocol,
   2 nodes) feeding an *emulated accelerator step* (a fixed sleep, so the
   host pipeline — not XLA-on-CPU — is what is measured, as on a real
   accelerator where the step runs on the device). ``naive`` pays decode
   + grid assembly + per-step ``jnp.asarray`` copies on the critical
   path, exactly like the historical train loop; ``stage`` double-buffers
   that tail onto the DeviceStager's staging thread; ``gather`` ships
   slot packs and assembles on-device via ``chunk_gather_train``. The
   headline is tokens/sec per mode plus the stager's overlap fraction.

Usage: PYTHONPATH=src python -m benchmarks.device_path [--quick]
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

from repro.core import Cluster, DeviceStats, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset
from repro.kernels import parity

__all__ = ["main", "run_end_to_end", "run_parity", "run_throughput"]

_WARMUP = 2  # batches consumed before the clock starts (jit/compile)


# ----------------------------------------------------------------- kernels
def run_parity(quick: bool = False) -> list[dict]:
    return [
        parity.check_case(case) for case in parity.iter_cases(quick=quick)
    ]


def run_throughput(quick: bool = False) -> list[dict]:
    return [
        parity.measure_case(case, iters=3 if quick else 5)
        for case in parity.iter_cases(quick=quick)
    ]


def print_kernel_tables(parity_rows, tput_rows) -> None:
    w = max(len(r["case"]) for r in parity_rows)
    print(f"{'case':<{w}}  {'max_err':>10}  {'tol':>8}  ok")
    for r in parity_rows:
        print(f"{r['case']:<{w}}  {r['max_err']:>10.2e}  {r['tol']:>8.0e}  "
              f"{'PASS' if r['ok'] else 'FAIL'}")
    print()
    w = max(len(r["case"]) for r in tput_rows)
    print(f"{'case':<{w}}  {'kernel_us':>10}  {'ref_us':>10}  {'out_MB/s':>9}")
    for r in tput_rows:
        print(f"{r['case']:<{w}}  {r['kernel_us']:>10.0f}  "
              f"{r['ref_us']:>10.0f}  {r['mb_per_s']:>9.1f}")


# -------------------------------------------------------------- end-to-end
def _build_loader(tmp: Path, *, batch: int, seq: int, steps: int, nodes: int):
    """The train_lm small-preset data plane, sized to cover ``steps``."""
    num_docs = max(batch * (steps + _WARMUP + 2), 256)
    ds = SyntheticTokenDataset(num_docs, 2048, mean_len=seq // 2, seed=5)
    store = ds.build_store(tmp / "chunks", chunk_size=16,
                           memory_bytes=int(ds.sizes_bytes.sum() // 4), seed=1)
    cluster = Cluster(store.plan, nodes, store=store, seed=2,
                      remote_memory_limit_bytes=1_000_000)
    sampler = EpochSampler(num_docs, nodes, seed=3)
    loader = RedoxLoader(cluster, sampler,
                         batch_per_node=max(batch // nodes, 1), seq_len=seq)
    return store, loader


def _run_mode(mode: str, *, batch: int, seq: int, steps: int,
              compute_s: float, nodes: int = 2) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.device import DeviceStager

    with tempfile.TemporaryDirectory(prefix="redox_devbench_") as td:
        store, loader = _build_loader(Path(td), batch=batch, seq=seq,
                                      steps=steps, nodes=nodes)
        stager = None
        if mode == "naive":
            it = loader.epoch_async(0)
        elif mode == "stage":
            stager = DeviceStager()
            it = stager.stream(loader.epoch_async(0))
        elif mode == "gather":
            stager = DeviceStager(use_kernel=True)
            it = loader.epoch_device(0, stager)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        n = 0
        t0 = elapsed = None
        try:
            for b in it:
                if mode == "naive":
                    arrs = (jnp.asarray(b["tokens"]), jnp.asarray(b["targets"]),
                            jnp.asarray(b["loss_mask"]))
                else:
                    arrs = (b["tokens"], b["targets"], b["loss_mask"])
                jax.block_until_ready(arrs)  # the accelerator "consumes" it
                time.sleep(compute_s)  # emulated on-device train step
                n += 1
                if n == _WARMUP:
                    t0 = time.perf_counter()
                    if stager is not None:
                        stager.stats = DeviceStats()  # clean post-compile view
                if n >= steps + _WARMUP:
                    elapsed = time.perf_counter() - t0
                    break
        finally:
            del it  # abandon mid-epoch: exercises the teardown path
            if stager is not None:
                stager.close()
            store.close()
        assert elapsed is not None, (
            f"epoch too short for {steps + _WARMUP} steps in mode {mode!r}"
        )
        d = stager.stats if stager is not None else None
        timed = n - _WARMUP
        return dict(
            mode=mode,
            steps=timed,
            tokens_per_s=timed * batch * seq / elapsed,
            ms_per_step=elapsed / timed * 1e3,
            overlap_fraction=(
                round(d.overlap_fraction, 3) if d is not None else None
            ),
            mb_to_device=(
                round(d.bytes_to_device / 1e6, 3) if d is not None else None
            ),
            live_buffers_after=(
                stager.live_buffers if stager is not None else None
            ),
            # Raw round-trippable snapshot for the --json record (StatsDict):
            # downstream tooling reads this instead of re-picking fields.
            device_stats=d.to_dict() if d is not None else None,
        )


def run_end_to_end(quick: bool = False, *, compute_ms: float = 3.0) -> list[dict]:
    scenarios = [("small-preset", 8, 128, 32 if quick else 96)]
    if not quick:
        # Wider grids make the host-side tail (decode + assembly + copy)
        # a visible fraction of a fixed-length step.
        scenarios.append(("wide b32 s512", 32, 512, 24))
    rows = []
    for name, batch, seq, steps in scenarios:
        for mode in ("naive", "stage", "gather"):
            r = _run_mode(mode, batch=batch, seq=seq, steps=steps,
                          compute_s=compute_ms / 1e3)
            r["scenario"] = name
            rows.append(r)
    return rows


def print_end_to_end(rows, *, compute_ms: float) -> None:
    print(f"emulated accelerator step: {compute_ms:.1f} ms "
          f"(host pipeline is what differs between modes)")
    print(f"{'scenario':<14} {'mode':<7} {'steps':>5} {'tokens/s':>10} "
          f"{'ms/step':>8} {'overlap':>8} {'MB H2D':>7}")
    base: dict = {}
    for r in rows:
        if r["mode"] == "naive":
            base[r["scenario"]] = r["tokens_per_s"]
        ov = "-" if r["overlap_fraction"] is None else f"{r['overlap_fraction']:.2f}"
        mb = "-" if r["mb_to_device"] is None else f"{r['mb_to_device']:.2f}"
        speed = r["tokens_per_s"] / base[r["scenario"]]
        print(f"{r['scenario']:<14} {r['mode']:<7} {r['steps']:>5} "
              f"{r['tokens_per_s']:>10,.0f} {r['ms_per_step']:>8.2f} "
              f"{ov:>8} {mb:>7}  ({speed:.2f}x vs naive)")


# --------------------------------------------------------------------- main
def main(quick: bool = False, *, compute_ms: float = 3.0) -> dict:
    parity_rows = run_parity(quick=quick)
    tput_rows = run_throughput(quick=quick)
    print_kernel_tables(parity_rows, tput_rows)
    print()
    e2e = run_end_to_end(quick=quick, compute_ms=compute_ms)
    print_end_to_end(e2e, compute_ms=compute_ms)
    n_fail = sum(not r["ok"] for r in parity_rows)
    if n_fail:
        print(f"\nWARNING: {n_fail} parity case(s) FAILED")
    return dict(
        compute_ms=compute_ms,
        parity=parity_rows,
        throughput=tput_rows,
        end_to_end=e2e,
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compute-ms", type=float, default=3.0,
                    help="emulated accelerator step time for the "
                         "end-to-end table")
    a = ap.parse_args()
    main(quick=a.quick, compute_ms=a.compute_ms)
