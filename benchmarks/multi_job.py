"""Multi-job aggregate throughput: K jobs on one DataService vs K loaders.

The paper's chunk layout is built once and "re-used to train different
models"; this benchmark measures what that sharing is worth. K jobs (own
seeds, own shuffles) run one real-bytes epoch each over the SAME chunk
store, two ways:

* **independent** — K separate ``RedoxLoader`` stacks, each opening the
  store itself: storage sees ~K x the dataset in chunk reads;
* **service** — one :class:`repro.service.DataService`, K sessions on the
  shared round-robin pump: the shared residency serves every duplicate
  chunk claim from cache, so storage sees ~1 x the dataset regardless of K
  (strictly below K x the single-job bytes — the BENCH acceptance check).

``--co-refill`` additionally steers refill tie-breaks toward shareable
chunks. Reads go through a VFS backend with an emulated per-read NAS
latency (this box page-caches everything; see ``io_overhead.py``), so wall
times reflect storage work honestly.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

from repro.core import ChunkStore, Cluster, EpochSampler, RedoxLoader, VFSBackend
from repro.data import SyntheticTokenDataset
from repro.service import DataService


def _build_store(root: Path, *, num_docs: int, chunk_size: int, groups: int,
                 mean_len: int, seed: int) -> ChunkStore:
    ds = SyntheticTokenDataset(num_docs, vocab_size=32000, mean_len=mean_len, seed=seed)
    return ds.build_store(
        root, chunk_size, num_slots=groups * chunk_size, seed=seed + 1
    )


def _job_seed(seed: int, j: int) -> int:
    return seed + 100 * j + 7


def run_multi_job(
    jobs: int = 3,
    *,
    num_docs: int = 768,
    chunk_size: int = 8,
    groups: int = 8,
    mean_len: int = 96,
    batch: int = 16,
    seq_len: int = 64,
    latency_ms: float = 0.5,
    co_refill: bool = False,
    seed: int = 0,
) -> dict:
    """One epoch, K jobs, independent vs service. Returns one BENCH row."""
    with tempfile.TemporaryDirectory(prefix="redox_multijob_") as tmp:
        root = Path(tmp) / "chunks"
        _build_store(root, num_docs=num_docs, chunk_size=chunk_size,
                     groups=groups, mean_len=mean_len, seed=seed)

        def open_store():
            return ChunkStore.open(root, backend=VFSBackend(latency_s=latency_ms / 1e3))

        # --- K independent loaders (and job 0 doubles as the 1-job baseline)
        indep_bytes, indep_reads, single_bytes = 0, 0, 0
        t0 = time.perf_counter()
        for j in range(jobs):
            store = open_store()
            cluster = Cluster(store.plan, 1, store=store, seed=_job_seed(seed, j))
            sampler = EpochSampler(store.plan.num_files, 1, seed=_job_seed(seed, j) + 1)
            loader = RedoxLoader(cluster, sampler, batch_per_node=batch, seq_len=seq_len)
            for _ in loader.epoch(0):
                pass
            b = store.backend_stats
            indep_bytes += b.bytes_read
            indep_reads += b.chunk_reads
            if j == 0:
                single_bytes = b.bytes_read
            store.close()
        indep_wall = time.perf_counter() - t0

        # --- one service, K co-scheduled sessions
        store = open_store()
        svc = DataService(store, co_refill=co_refill)
        for j in range(jobs):
            svc.open_session(
                f"job{j}", seed=_job_seed(seed, j), batch_per_node=batch,
                seq_len=seq_len,
            )
        t0 = time.perf_counter()
        steps = sum(1 for _ in svc.co_epoch(0))
        svc_wall = time.perf_counter() - t0
        agg = svc.stats_report()["aggregate"]
        svc_bytes = store.backend_stats.bytes_read
        svc_reads = store.backend_stats.chunk_reads
        svc.close()
        store.close()

    return dict(
        jobs=jobs,
        co_refill=co_refill,
        steps=steps,
        single_mb=single_bytes / 1e6,
        indep_mb=indep_bytes / 1e6,
        service_mb=svc_bytes / 1e6,
        saving_x=indep_bytes / max(svc_bytes, 1),
        indep_reads=indep_reads,
        service_reads=svc_reads,
        dup_loads_avoided=agg["dup_loads_avoided"],
        co_refill_hits=agg["co_refill_hits"],
        peak_cache_mb=agg["peak_cache_bytes"] / 1e6,
        indep_wall_s=indep_wall,
        service_wall_s=svc_wall,
    )


def print_table(rows: "list[dict]") -> None:
    print(
        f"{'jobs':>4s} {'co_refill':>9s} {'single_MB':>9s} {'K_indep_MB':>10s} "
        f"{'service_MB':>10s} {'saving':>7s} {'dup_avoid':>9s} {'co_hits':>7s} "
        f"{'indep_s':>8s} {'svc_s':>7s}"
    )
    for r in rows:
        print(
            f"{r['jobs']:4d} {str(r['co_refill']):>9s} {r['single_mb']:9.1f} "
            f"{r['indep_mb']:10.1f} {r['service_mb']:10.1f} "
            f"{r['saving_x']:6.1f}x {r['dup_loads_avoided']:9d} "
            f"{r['co_refill_hits']:7d} {r['indep_wall_s']:8.2f} "
            f"{r['service_wall_s']:7.2f}"
        )


def main(quick: bool = False) -> "list[dict]":
    kw = dict(num_docs=384, mean_len=64) if quick else {}
    rows = [run_multi_job(3, co_refill=False, **kw),
            run_multi_job(3, co_refill=True, **kw)]
    if not quick:
        rows.append(run_multi_job(5, co_refill=True))
    print_table(rows)
    for r in rows:
        k, single = r["jobs"], r["single_mb"]
        assert r["service_mb"] < k * single, (
            "shared residency failed to deduplicate reads: "
            f"{r['service_mb']:.1f}MB !< {k} x {single:.1f}MB"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--co-refill", action="store_true")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.jobs == 3 and not args.co_refill:
        main(quick=args.quick)
    else:
        print_table([run_multi_job(args.jobs, co_refill=args.co_refill)])
