"""Belady vs LRU eviction under shared-cache byte caps.

The shared residency serves K co-scheduled jobs out of one cache; under a
byte cap the eviction policy decides which resident chunk to drop when a
cold miss lands. This benchmark sweeps the cap as a fraction of the
working set and runs the SAME 3-job co-scheduled epoch twice per point:

* **lru** — least-recently-claimed among the provably-still-needed
  entries (the pre-Belady behaviour);
* **belady** — clairvoyant MIN over the merged claim schedule: evict the
  resident chunk whose next planned use is farthest (or absent), and
  refuse to cache an incoming chunk needed later than every resident.

Physical reads/bytes, evictions, and admission-gate bypasses are reported
per point. The advisory CI check rides on ``main()``'s asserts: at every
cap Belady's physical bytes must not exceed LRU's, and at a cap <= 50% of
the working set it must be strictly fewer (the paper's claim that exact
next-use knowledge — which the claim schedule gives us for free — beats
recency). Reads go through a VFS backend with an emulated per-read NAS
latency so wall times reflect the saved storage work honestly.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import ChunkStore, SessionSpec, VFSBackend
from repro.data import SyntheticTokenDataset
from repro.service import DataService


def _build_store(root: Path, *, num_docs: int, chunk_size: int,
                 num_slots: int, mean_len: int, seed: int) -> None:
    ds = SyntheticTokenDataset(num_docs, vocab_size=512, mean_len=mean_len,
                               seed=seed)
    ds.build_store(root, chunk_size, num_slots=num_slots, seed=seed + 1).close()


def _run_policy(root: Path, cap: "int | None", eviction: str, *,
                jobs: int, latency_ms: float) -> dict:
    store = ChunkStore.open(
        root, backend=VFSBackend(latency_s=latency_ms / 1e3)
    )
    svc = DataService(store, cache_limit_bytes=cap, eviction=eviction)
    for j in range(jobs):
        svc.open_session(
            f"job{j}", SessionSpec(seed=j, batch_per_node=8, seq_len=64)
        )
    t0 = time.perf_counter()
    steps = sum(1 for _ in svc.co_epoch(0))
    wall = time.perf_counter() - t0
    agg = svc.aggregate_stats()
    rec = svc.stats_report()["service"]
    svc.close()
    store.close()
    return dict(
        steps=steps,
        wall_s=wall,
        physical_reads=agg.physical_reads,
        physical_mb=agg.physical_bytes / 1e6,
        evictions=rec["evictions"],
        cache_bypass=rec["cache_bypass"],
        peak_cache_mb=rec["peak_cache_bytes"] / 1e6,
    )


def run_sweep(
    *,
    jobs: int = 3,
    num_docs: int = 384,
    chunk_size: int = 4,
    num_slots: int = 16,
    mean_len: int = 48,
    latency_ms: float = 0.3,
    fracs: "tuple[float, ...]" = (1.0, 0.5, 0.35, 0.25),
    seed: int = 5,
) -> "list[dict]":
    """One row per (cap fraction, policy); fraction 1.0 means uncapped."""
    rows: list[dict] = []
    with tempfile.TemporaryDirectory(prefix="redox_evict_") as tmp:
        root = Path(tmp) / "chunks"
        _build_store(root, num_docs=num_docs, chunk_size=chunk_size,
                     num_slots=num_slots, mean_len=mean_len, seed=seed)
        ws = int(np.asarray(ChunkStore.open(root).plan.chunk_bytes).sum())
        for frac in fracs:
            cap = None if frac >= 1.0 else int(ws * frac)
            for eviction in ("lru", "belady"):
                r = _run_policy(root, cap, eviction,
                                jobs=jobs, latency_ms=latency_ms)
                r.update(cap_frac=frac, eviction=eviction,
                         cap_mb=(ws if cap is None else cap) / 1e6)
                rows.append(r)
                if cap is None:
                    break  # policies are identical with no cap; one row
    return rows


def print_table(rows: "list[dict]") -> None:
    print(
        f"{'cap':>5s} {'policy':>7s} {'reads':>6s} {'phys_MB':>8s} "
        f"{'evict':>6s} {'bypass':>6s} {'peak_MB':>8s} {'wall_s':>7s}"
    )
    for r in rows:
        cap = "none" if r["cap_frac"] >= 1.0 else f"{r['cap_frac']:.0%}"
        print(
            f"{cap:>5s} {r['eviction']:>7s} {r['physical_reads']:6d} "
            f"{r['physical_mb']:8.2f} {r['evictions']:6d} "
            f"{r['cache_bypass']:6d} {r['peak_cache_mb']:8.2f} "
            f"{r['wall_s']:7.2f}"
        )


def main(quick: bool = False) -> "list[dict]":
    kw = dict(num_docs=192, fracs=(1.0, 0.5, 0.25)) if quick else {}
    rows = run_sweep(**kw)
    print_table(rows)
    by_frac: dict = {}
    for r in rows:
        by_frac.setdefault(r["cap_frac"], {})[r["eviction"]] = r
    for frac, pair in sorted(by_frac.items()):
        if "belady" not in pair or "lru" not in pair:
            continue
        bel, lru = pair["belady"], pair["lru"]
        assert bel["physical_mb"] <= lru["physical_mb"], (
            f"Belady read MORE than LRU at cap {frac:.0%}: "
            f"{bel['physical_mb']:.2f}MB > {lru['physical_mb']:.2f}MB"
        )
        if frac <= 0.5 and lru["evictions"] > 0:
            assert bel["physical_reads"] < lru["physical_reads"], (
                f"Belady not strictly better at cap {frac:.0%}: "
                f"{bel['physical_reads']} !< {lru['physical_reads']} reads"
            )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
