"""Serving example: batched prefill + autoregressive decode with KV caches.

Loads a small model, prefloods a batch of prompts through prefill (building
sized caches), then decodes tokens greedily — the same ``serve_step`` the
decode_32k/long_500k dry-run cells lower at production shapes. Runs the
hybrid (zamba2-family) reduced config by default to exercise both KV and
SSM state caches (+ the rotating-window buffer).

    PYTHONPATH=src python examples/serve_decode.py --new-tokens 24
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import build_model, split_params
from repro.train.train_step import build_decode_step, build_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    model = build_model(cfg)
    values, _ = split_params(model.init(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.new_tokens

    prefill = jax.jit(build_prefill_step(model, max_len=max_len))
    decode = jax.jit(build_decode_step(model), donate_argnums=1)

    t0 = time.time()
    logits, cache = prefill(values, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    print(f"prefill: {args.batch} prompts x {args.prompt_len} tokens "
          f"in {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for t in range(args.new_tokens - 1):
        logits, cache = decode(values, cache, tok, jnp.int32(args.prompt_len + t))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({args.new_tokens * args.batch / dt:.1f} tok/s on CPU)")
    print("generated token ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
