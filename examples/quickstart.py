"""Quickstart: the Redox data path in ~40 lines.

Builds a tiny synthetic dataset, chunks it once (paper Fig. 2), then serves
one epoch through the redirection protocol — printing what the framework
asked for vs what Redox returned, and the exactly-once guarantee holding.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core import ChunkStore, Cluster, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset


def main():
    with tempfile.TemporaryDirectory() as tmp:
        # 1. one-time dataset preparation: 480 documents -> chunks of 8
        ds = SyntheticTokenDataset(num_docs=480, vocab_size=199, mean_len=64, seed=0)
        store = ds.build_store(tmp, chunk_size=8, num_slots=48, seed=1)
        plan = store.plan
        print(f"dataset: {plan.num_files} files, {plan.num_chunks} chunks, "
              f"{plan.num_groups} chunk groups x {plan.chunk_size} slots")

        # 2. a 3-node cluster sharing the abstract memory space
        cluster = Cluster(plan, num_nodes=3, store=store,
                          remote_memory_limit_bytes=100_000, seed=2)
        sampler = EpochSampler(plan.num_files, 3, seed=3)

        # 3. peek at redirection: request files, get *random* files back
        seqs = cluster.begin_epoch(sampler, epoch=0)
        io = {}
        print("\nrequested -> returned (redirection in action):")
        for pos in range(5):
            fid, data = cluster.access(0, pos, int(seqs[0][pos]), io)
            print(f"  file {int(seqs[0][pos]):4d} -> file {fid:4d} "
                  f"({len(data)} bytes)")
        # drain the rest of the epoch
        consumed = 5
        for r in range(3):
            start = 5 if r == 0 else 0
            for pos in range(start, len(seqs[r])):
                cluster.access(r, pos, int(seqs[r][pos]), io)
                consumed += 1
        print(f"\nepoch complete: {consumed} accesses, exactly-once verified "
              f"(every file consumed once)")
        st = cluster.nodes[0].stats.merge(cluster.nodes[1].stats).merge(
            cluster.nodes[2].stats)
        print(f"chunk loads: {st.chunk_loads}, mean fill rate: "
              f"{st.mean_fill_rate:.2f}, prefetch hits: {st.remote_prefetch_hits}")

        # 4. the training-facing API: fixed-shape JAX batches, served through
        #    a pluggable storage backend (vfs | mmap | parallel)
        store2 = ChunkStore.open(tmp, backend="parallel")
        cluster2 = Cluster(plan, 3, store=store2, seed=2)
        loader = RedoxLoader(cluster2, sampler, batch_per_node=8, seq_len=64)
        batch = next(iter(loader.epoch(1)))
        print(f"\nRedoxLoader batch: tokens{batch['tokens'].shape} "
              f"targets{batch['targets'].shape} mask sum={batch['loss_mask'].sum():.0f}")
        bs = store2.backend_stats
        print(f"storage backend '{store2.backend.name}': {bs.chunk_reads} chunk reads, "
              f"{bs.prefetch_hits} served by readahead")
        store2.close()


if __name__ == "__main__":
    main()
