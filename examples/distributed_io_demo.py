"""Distributed I/O demo: ownership, prefetching, failure, elastic remap.

Walks the paper's Fig. 5/6 machinery on a 4-node cluster with live
narration: remote misses trigger owner reads + opportunistic prefetch;
mid-epoch we kill a node and show the ownership remap preserving the
exactly-once guarantee; finally the epoch-time model prices the run vs the
PyTorch/CoorDL baselines.

    PYTHONPATH=src python examples/distributed_io_demo.py
"""

from repro.core import (
    ChunkingPlan,
    Cluster,
    CoorDLLoader,
    EpochSampler,
    PipelineTimeModel,
    PyTorchStyleLoader,
    run_baseline_epoch,
)
from repro.data.synthetic import paper_like_sizes


def main():
    n, nodes = 8000, 4
    sizes = paper_like_sizes("imagenet1k", n, seed=0)
    plan = ChunkingPlan.create(sizes, chunk_size=16, memory_bytes=int(sizes.sum() // 4), seed=1)
    print(f"dataset: {n} files ({sizes.sum()/1e9:.2f} GB), {plan.num_chunks} chunks, "
          f"{plan.num_groups} groups; global memory = 25% of dataset")

    cluster = Cluster(plan, nodes, remote_memory_limit_bytes=60_000_000, seed=2)
    sampler = EpochSampler(n, nodes, seed=3)
    seqs = cluster.begin_epoch(sampler, 0)

    # --- phase 1: run 60% of the epoch normally ---------------------------
    io = {}
    upto = int(len(seqs[0]) * 0.6)
    consumed = []
    for r in range(nodes):
        for pos in range(upto):
            f, _ = cluster.access(r, pos, int(seqs[r][pos]), io)
            consumed.append(f)
    agg = cluster.nodes[0].stats
    for s in cluster.nodes[1:]:
        agg = agg.merge(s.stats)
    print(f"\n60% mark: hits={agg.local_hits} misses={agg.memory_misses} "
          f"remote_req={agg.remote_requests} prefetch_hits={agg.remote_prefetch_hits} "
          f"fill_rate={agg.mean_fill_rate:.2f}")

    # --- phase 2: node 3 dies; elastic remap ------------------------------
    print("\n!! node 3 fails — remapping ownership, redistributing its tail")
    cluster.fail_node(3, processed_upto=upto)
    for r in range(3):
        seq = cluster.sequences[r]
        for pos in range(upto, len(seq)):
            f, _ = cluster.access(r, pos, int(seq[pos]), io)
            consumed.append(f)
    assert sorted(consumed) == list(range(n))
    print(f"epoch completed by 3 survivors; exactly-once verified over {n} files")

    # --- phase 3: price a clean epoch vs baselines ------------------------
    tm = PipelineTimeModel(disk_bw=200e6, file_overhead=8e-3, chunk_overhead=8e-3,
                           net_bw=0.38e9, net_latency=1e-3)
    compute = 0.2  # s per step (GPU budget)
    batch = 128
    cluster2 = Cluster(plan, nodes, remote_memory_limit_bytes=60_000_000, seed=2)
    res = cluster2.run_epoch(sampler, 1, batch, collect_returned=False)
    t_redox = tm.epoch_time(res.per_node_step_io, compute)
    for name, mk in (
        ("pytorch", lambda: PyTorchStyleLoader(plan, nodes, int(sizes.sum() // 16))),
        ("coordl", lambda: CoorDLLoader(plan, nodes, int(sizes.sum() // 16))),
    ):
        _, io_b = run_baseline_epoch(mk(), sampler, 1, batch)
        t = tm.epoch_time(io_b, compute)
        print(f"epoch time {name:8s}: {t:7.1f}s  (redox speedup {t/t_redox:.2f}x)")
    print(f"epoch time redox   : {t_redox:7.1f}s")


if __name__ == "__main__":
    main()
