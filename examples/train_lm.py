"""End-to-end driver: train a ~100M-param LM with the Redox data path.

Everything is real: the dataset is materialised to chunk files on disk, the
Redox cluster serves redirected batches, the model trains with the full
train_step (AdamW, remat, grad clip), checkpoints are written/restorable,
and per-step I/O demand is logged. The default config is a ~100M-param
tinyllama-family model; a few hundred steps on CPU take a while — use
--steps/--preset small for a fast run.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --preset small
"""

import argparse
import dataclasses
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import ARCHS, RunConfig, reduced
from repro.core import Cluster, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset
from repro.launch.cli import add_device_args, add_storage_args
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.train_step import build_train_step, init_train_state

PRESETS = {
    # ~100M params: d=768, L=12, ff=3072, vocab=32000 (GPT-2-small-ish)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32000, head_dim=64, num_docs=8192,
                 batch=8, seq=512),
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  d_ff=768, vocab_size=2048, head_dim=64, num_docs=1024,
                  batch=8, seq=128),
}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--workdir", default=None)
    add_storage_args(ap)
    add_device_args(ap)
    return ap


def main():
    args = build_parser().parse_args()

    p = PRESETS[args.preset]
    cfg = dataclasses.replace(
        reduced(ARCHS["tinyllama-1.1b"]),
        num_layers=p["num_layers"], d_model=p["d_model"], num_heads=p["num_heads"],
        num_kv_heads=p["num_kv_heads"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        head_dim=p["head_dim"], attn_dense_threshold=p["seq"],
    )
    workdir = Path(args.workdir or tempfile.mkdtemp(prefix="redox_train_"))
    print(f"workdir: {workdir}")

    # --- data: real chunk store on disk, Redox cluster, loader -------------
    ds = SyntheticTokenDataset(p["num_docs"], cfg.vocab_size, mean_len=p["seq"] // 2, seed=5)
    store = ds.build_store(workdir / "chunks", chunk_size=16,
                           memory_bytes=ds.sizes_bytes.sum() // 4, seed=1,
                           backend=args.backend or "vfs",
                           codec=args.codec, bands=args.bands)
    if args.fidelity is not None:
        store.default_fidelity = args.fidelity
    print(f"storage backend: {store.backend.name} "
          f"(codec {store.spec.codec}, {store.spec.bands} band(s))")
    cluster = Cluster(store.plan, args.nodes, store=store, seed=2,
                      remote_memory_limit_bytes=1_000_000)
    sampler = EpochSampler(p["num_docs"], args.nodes, seed=3)
    loader = RedoxLoader(cluster, sampler, batch_per_node=p["batch"] // args.nodes or 1,
                         seq_len=p["seq"])

    # --- model + train step -------------------------------------------------
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-derived, {n_params/1e6:.1f}M params")
    run = RunConfig(optimizer="adamw", learning_rate=3e-4, remat="dots")
    opt = make_optimizer(run)
    state = init_train_state(model, opt, seed=0)
    step_fn = jax.jit(build_train_step(model, run, opt), donate_argnums=0)

    stager = None
    if args.device_path != "naive":
        from repro.core.device import DeviceStager

        stager = DeviceStager(depth=args.stage_depth,
                              use_kernel=(args.device_path == "gather"))
        print(f"device path: {args.device_path} (depth {args.stage_depth})")

    def epoch_batches(epoch):
        if args.device_path == "gather":
            return loader.epoch_device(epoch, stager)
        if args.device_path == "stage":
            return stager.stream(loader.epoch_async(epoch))
        return loader.epoch_async(epoch)

    ckpt = AsyncCheckpointer(workdir / "ckpt", keep=2)
    start = latest_step(workdir / "ckpt")
    if start:
        state = restore_checkpoint(workdir / "ckpt", start, state)
        print(f"resumed from step {start}")

    # --- loop ----------------------------------------------------------------
    step = int(start or 0)
    epoch = 0
    t0 = time.time()
    while step < args.steps:
        for batch in epoch_batches(epoch):
            if step >= args.steps:
                break
            state, metrics = step_fn(
                state,
                {
                    "tokens": jnp.asarray(batch["tokens"]),
                    "targets": jnp.asarray(batch["targets"]),
                    "loss_mask": jnp.asarray(batch["loss_mask"]),
                },
            )
            step += 1
            if step % 20 == 0 or step == 1:
                dt = time.time() - t0
                io = batch["io_by_node"]
                loads = sum(x.chunk_loads for x in io.values())
                print(
                    f"step {step:4d} epoch {epoch} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.2f} "
                    f"({dt/step:.2f}s/step, chunk loads this step: {loads})"
                )
            if step % args.ckpt_every == 0:
                ckpt.save(step, state)
        epoch += 1
    ckpt.wait()
    elapsed = time.time() - t0
    steps_run = step - int(start or 0)
    if stager is not None:
        stager.close()
        d = stager.stats
        print(f"device path {args.device_path}: staged {d.steps} batches "
              f"({d.bytes_to_device / 1e6:.1f} MB to device), "
              f"overlap fraction {d.overlap_fraction:.2f}")
    if steps_run:
        toks = steps_run * p["batch"] * p["seq"]
        print(f"throughput: {toks / max(elapsed, 1e-9):,.0f} tokens/sec "
              f"over {steps_run} step(s)")
    st = cluster.nodes[0].stats
    print(
        f"done: {step} steps; epoch-0 node-0 stats: hits={st.local_hits} "
        f"misses={st.memory_misses} fill_rate={st.mean_fill_rate:.2f}"
    )


if __name__ == "__main__":
    main()
