"""Golden-stream regression fixtures.

The equivalence tests prove the three engines agree with *each other*; a
refactor that changes the shuffle in all of them at once (a reordered RNG
draw, a different tie-break) would still pass those. These fixtures pin the
absolute streams: every (policy, engine) returned-id stream of the tiny
golden scenario must match ``tests/golden/streams.json`` byte for byte.

Intentional changes: regenerate with ``python tests/golden/regen.py`` and
review the diff in the PR.
"""

import json
from pathlib import Path

import pytest

from elastic_harness import GOLDEN_BATCH, GOLDEN_CONFIG, golden_streams

GOLDEN = Path(__file__).parent / "golden" / "streams.json"


@pytest.fixture(scope="module")
def fixture():
    return json.loads(GOLDEN.read_text())


@pytest.fixture(scope="module")
def current():
    return golden_streams()


def test_fixture_matches_generator_config(fixture):
    assert fixture["config"] == dict(GOLDEN_CONFIG, batch=GOLDEN_BATCH), (
        "golden scenario changed; run python tests/golden/regen.py and "
        "review the stream diff"
    )


@pytest.mark.parametrize("policy", ["max_fill", "random"])
@pytest.mark.parametrize("engine", ["step", "per_access", "replay"])
def test_stream_matches_golden(fixture, current, policy, engine):
    want = fixture["streams"][policy][engine]
    got = current["streams"][policy][engine]
    assert got == want, (
        f"{policy}/{engine} stream drifted from tests/golden/streams.json — "
        "if intentional, regenerate via python tests/golden/regen.py"
    )


def test_golden_streams_are_exactly_once(fixture):
    n = fixture["config"]["n"]
    for policy, per_engine in fixture["streams"].items():
        for engine, per_node in per_engine.items():
            flat = sorted(x for node in per_node for x in node)
            assert flat == list(range(n)), (policy, engine)
