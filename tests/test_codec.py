"""Compressed & progressive chunk storage (DESIGN.md §15).

The storage-codec contract under test:

* every registry codec round-trips arbitrary bytes, and the frame
  container (RXF1) round-trips band payloads — pinned by golden fixtures
  in ``tests/golden/frames.json`` (decode stability, not encode
  byte-equality, is the contract: a codec may legitimately produce
  different bytes across library versions as long as old frames decode);
* a compressed store serves the exact same epoch stream as a raw one at
  full fidelity — for every engine — while reading strictly fewer
  physical bytes;
* truncated fidelity returns strict token-prefixes of the full records;
* ``StoreSpec`` is the one source of truth: persisted as ``store.json``
  so ``ChunkStore.open(root)`` needs no flags, refuses a conflicting
  explicit spec, and rejects mixed-codec chunk files at open();
* ``SharedResidency`` caches *compressed* frames: its byte cap counts
  physical bytes, decode happens per-claim.
"""

import base64
import json
import shutil
from pathlib import Path

import numpy as np
import pytest

from repro.core import ChunkStore, RedoxLoader, SessionSpec, StoreSpec
from repro.core.storage import ParallelBackend
from repro.core.storage.codec import (
    CODECS,
    FRAME_MAGIC,
    ChunkFrame,
    band_cuts,
    encode_frame,
    get_codec,
    parse_frame,
    peek_frame,
)
from repro.data import SyntheticTokenDataset
from repro.service import DataService

pytestmark = pytest.mark.backend

NUM_DOCS = 192


def make_dataset():
    return SyntheticTokenDataset(NUM_DOCS, vocab_size=97, mean_len=48, seed=3)


def build(tmp_path, name, **kwargs):
    """Build a store with the shared dataset/plan params; only the byte
    representation (codec/level/bands/spec) varies between stores."""
    ds = make_dataset()
    return ds.build_store(tmp_path / name, 4, num_slots=16, seed=1, **kwargs)


# ----------------------------------------------------------------- codecs
class TestCodecs:
    PAYLOADS = [
        b"",
        b"\x00" * 4096,
        bytes(range(256)) * 7,
        b"abcabcabcabcabc" * 100,
        np.random.default_rng(5).integers(0, 255, 3000, np.uint8).tobytes(),
    ]

    @pytest.mark.parametrize("name", sorted(CODECS))
    def test_round_trip(self, name):
        codec = CODECS[name]
        for payload in self.PAYLOADS:
            enc = codec.encode(payload)
            assert bytes(codec.decode(enc)) == payload

    def test_registry_lookup(self):
        assert get_codec("zlib").name == "zlib"
        with pytest.raises(ValueError, match="unknown codec"):
            get_codec("zstd")

    def test_compressible_data_actually_shrinks(self):
        body = b"the quick brown fox " * 500
        for name in ("zlib", "lz4"):
            assert len(CODECS[name].encode(body)) < len(body)

    def test_band_cuts_partition_and_align(self):
        cuts = band_cuts(1200, 3)  # 4-aligned sizes stay 4-aligned
        assert cuts[0] == 0 and cuts[-1] == 1200
        assert all(c % 4 == 0 for c in cuts)
        assert cuts == sorted(cuts)
        # one band is the whole payload; degenerate sizes still partition
        assert band_cuts(1200, 1) == [0, 1200]
        assert band_cuts(2, 3)[-1] == 2

    def test_frame_round_trip(self):
        bands = [b"aaaa" * 10, b"bbbb" * 5, b"cc"]
        frame = bytes(encode_frame("none", bands))
        assert frame.startswith(FRAME_MAGIC)
        assert peek_frame(frame[:16]) == ("none", 3)
        parsed = parse_frame(frame)
        assert isinstance(parsed, ChunkFrame)
        assert parsed.nbands == 3
        assert [bytes(b) for b in parsed.decode_bands(3)] == bands

    def test_truncated_frame_rejected(self):
        frame = bytes(encode_frame("zlib", [b"x" * 100]))
        with pytest.raises(ValueError):
            parse_frame(frame[:-3])
        assert peek_frame(b"notaframe") is None

    def test_golden_frames_decode(self):
        """Frames written by past versions must keep decoding bit-exactly
        (regenerate deliberately with tests/golden/regen.py)."""
        fixtures = json.loads(
            (Path(__file__).parent / "golden" / "frames.json").read_text()
        )
        assert {f["codec"] for f in fixtures} == set(CODECS)
        for fx in fixtures:
            frame = parse_frame(base64.b64decode(fx["frame"]))
            want = [base64.b64decode(b) for b in fx["bands"]]
            assert frame.codec_name == fx["codec"]
            got = frame.decode_bands(frame.nbands)
            assert [bytes(b) for b in got] == want


# -------------------------------------------------------------- StoreSpec
class TestStoreSpec:
    def test_json_round_trip(self):
        spec = StoreSpec(backend="mmap", codec="zlib", level=6, bands=4,
                         backend_kwargs={"x": 1})
        assert StoreSpec.from_json(spec.to_json()) == spec

    def test_strict_unknown_field(self):
        with pytest.raises((TypeError, ValueError)):
            StoreSpec.from_json({"backend": "vfs", "compression": "zlib"})

    def test_validates(self):
        with pytest.raises(ValueError):
            StoreSpec(codec="zstd")
        with pytest.raises(ValueError):
            StoreSpec(bands=0)

    def test_from_kwargs_shim(self):
        """The historical ``backend="vfs"``/backend-object call sites keep
        working: unknown kwargs land in backend_kwargs."""
        spec = StoreSpec.from_kwargs("parallel", codec="lz4", readahead=4)
        assert spec.backend == "parallel" and spec.codec == "lz4"
        assert spec.backend_kwargs == {"readahead": 4}
        obj = ParallelBackend(workers=1)
        assert StoreSpec.from_kwargs(obj).backend == obj.name
        obj.close()

    def test_framed_property(self):
        assert not StoreSpec().framed
        assert StoreSpec(codec="zlib").framed
        assert StoreSpec(bands=2).framed


# ------------------------------------------------- store.json persistence
class TestOpenRoundTrip:
    def test_open_no_kwargs_round_trips_spec(self, tmp_path):
        spec = StoreSpec(codec="zlib", level=6, bands=3)
        built = build(tmp_path, "c", spec=spec)
        built.close()
        store = ChunkStore.open(tmp_path / "c")  # no flags at all
        assert store.spec == spec
        store.close()

    def test_legacy_store_without_sidecar_opens_raw(self, tmp_path):
        built = build(tmp_path, "raw")
        built.close()
        (tmp_path / "raw" / "store.json").unlink()  # pre-§15 store
        store = ChunkStore.open(tmp_path / "raw")
        assert store.spec == StoreSpec()
        store.close()

    def test_conflicting_explicit_spec_refused(self, tmp_path):
        build(tmp_path, "c", codec="zlib", bands=2).close()
        with pytest.raises(ValueError, match="conflicts"):
            ChunkStore.open(tmp_path / "c", spec=StoreSpec(codec="none"))
        # the exact stored spec is fine to repeat explicitly
        store = ChunkStore.open(
            tmp_path / "c", spec=StoreSpec(codec="zlib", bands=2)
        )
        store.close()

    def test_build_rejects_spec_plus_kwargs(self, tmp_path):
        with pytest.raises(ValueError, match="not both"):
            build(tmp_path, "x", spec=StoreSpec(codec="zlib"), codec="lz4")

    def test_mixed_codec_rejected_at_open(self, tmp_path):
        """A chunk file smuggled in from a store with a different codec
        fails the open()-time frame sweep, not a mid-epoch decode."""
        build(tmp_path, "a", codec="zlib", bands=2).close()
        build(tmp_path, "b", codec="lz4", bands=2).close()
        shutil.copy(
            tmp_path / "b" / "chunk_00000000.bin",
            tmp_path / "a" / "chunk_00000000.bin",
        )
        with pytest.raises(ValueError, match="mixed-codec"):
            ChunkStore.open(tmp_path / "a")


# --------------------------------------------------- read paths & parity
@pytest.mark.parametrize("codec", ["zlib", "lz4"])
class TestCompressedParity:
    def test_chunks_byte_identical_to_raw(self, tmp_path, codec):
        raw = build(tmp_path, "raw")
        comp = build(tmp_path, "comp", codec=codec, bands=3)
        for k in range(raw.plan.num_chunks):
            a, b = raw.read_chunk(k), comp.read_chunk(k)
            assert [f for f, _ in a] == [f for f, _ in b]
            for (_, x), (_, y) in zip(a, b):
                assert bytes(x) == bytes(y)
        # ... while the files on disk are strictly smaller
        nraw = sum(
            raw.chunk_path(k).stat().st_size for k in range(raw.plan.num_chunks)
        )
        ncomp = sum(
            comp.chunk_path(k).stat().st_size
            for k in range(comp.plan.num_chunks)
        )
        assert ncomp < nraw
        raw.close()
        comp.close()

    def test_read_file_on_compressed_store(self, tmp_path, codec):
        """Ranged per-file reads can't seek into compressed frames — the
        store decodes the whole chunk (cached) and slices (regression:
        the first framed implementation returned compressed garbage)."""
        raw = build(tmp_path, "raw")
        comp = build(tmp_path, "comp", codec=codec, bands=2)
        for fid in range(0, raw.plan.num_files, 7):
            assert bytes(comp.read_file(fid)) == bytes(raw.read_file(fid))
        # chunk-and-ranged agreement on the compressed store itself
        for fid, blob in comp.read_chunk(0):
            assert bytes(comp.read_file(fid)) == bytes(blob)
        raw.close()
        comp.close()

    def test_truncated_fidelity_is_strict_prefix(self, tmp_path, codec):
        comp = build(tmp_path, "comp", codec=codec, bands=3)
        full = {k: comp.read_chunk(k, fidelity=3)
                for k in range(comp.plan.num_chunks)}
        for fidelity in (1, 2):
            shorter = 0
            for k, ref in full.items():
                got = comp.read_chunk(k, fidelity=fidelity)
                assert [f for f, _ in got] == [f for f, _ in ref]
                for (_, x), (_, y) in zip(got, ref):
                    x, y = bytes(x), bytes(y)
                    assert y.startswith(x)
                    assert len(x) % 4 == 0 or len(x) == len(y)  # token cut
                    shorter += len(x) < len(y)
            assert shorter > 0  # truncation actually happened
        comp.close()

    def test_parallel_backend_decodes_on_workers(self, tmp_path, codec):
        comp = build(tmp_path, "comp", codec=codec, bands=2)
        comp.close()
        store = ChunkStore.open(
            tmp_path / "comp", backend=ParallelBackend(workers=2)
        )
        store.schedule_reads(list(range(store.plan.num_chunks)))
        logical = 0
        for k in range(store.plan.num_chunks):
            logical += sum(len(b) for _, b in store.read_chunk(k))
        st = store.backend_stats
        physical = sum(
            store.chunk_path(k).stat().st_size
            for k in range(store.plan.num_chunks)
        )
        assert st.scheduled_hits == store.plan.num_chunks  # prefetched...
        assert st.bytes_read == physical       # ...accounting compressed
        assert st.decoded_bytes >= logical     # decode ran inside the pool
        assert st.decode_seconds > 0
        store.close()


# -------------------------------------------------- epoch-stream identity
@pytest.mark.parametrize("engine", ["replay", "step", "per_access"])
def test_epoch_stream_identical_raw_vs_compressed(tmp_path, engine):
    """The acceptance gate: at full fidelity a compressed store yields a
    byte-identical epoch stream through every execution engine, while
    physically reading fewer bytes."""
    spec = SessionSpec(seed=2, sampler_seed=4, batch_per_node=16,
                       seq_len=32, engine=engine)
    streams, physical = {}, {}
    for name, kwargs in (
        ("raw", {}), ("zlib", {"codec": "zlib", "bands": 2})
    ):
        store = build(tmp_path, f"{engine}-{name}", **kwargs)
        loader = RedoxLoader.from_spec(spec, store)
        streams[name] = [
            (batch["tokens"].tobytes(), batch["returned"].tobytes())
            for batch in loader.epoch(0)
        ]
        physical[name] = store.backend_stats.bytes_read
        store.close()
    assert streams["zlib"] == streams["raw"]
    assert 0 < physical["zlib"] < physical["raw"]


# ------------------------------------------------- residency byte account
class TestCompressedResidency:
    def test_cap_counts_compressed_bytes(self, tmp_path):
        """The shared cache holds compressed frames: a cap equal to the
        total *compressed* footprint never evicts even though the logical
        bytes served are far larger, and the stats split the two."""
        build(tmp_path, "c", codec="zlib", bands=2).close()
        store = ChunkStore.open(tmp_path / "c")
        physical_total = sum(
            store.chunk_path(k).stat().st_size
            for k in range(store.plan.num_chunks)
        )
        logical_total = int(np.asarray(store.plan.chunk_bytes).sum())
        assert physical_total < logical_total
        svc = DataService(store, cache_limit_bytes=physical_total)
        for j in range(2):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16,
                             seq_len=32)
        returned = {f"j{j}": [] for j in range(2)}
        for job_id, batch in svc.co_epoch(0):
            returned[job_id].append(batch["returned"])
        for job_id, chunks in returned.items():
            ids = np.concatenate(chunks)
            assert sorted(ids.tolist()) == list(range(NUM_DOCS)), job_id
        res = svc.residency
        assert res.evictions == 0           # cap was measured in frames
        assert res.peak_cache_bytes <= physical_total
        agg = svc.aggregate_stats()
        assert agg.physical_bytes <= physical_total
        assert agg.decode_claims > 0        # every claim decoded its copy
        assert agg.logical_bytes >= logical_total  # both jobs served fully
        assert agg.logical_bytes > agg.physical_bytes + agg.shared_bytes
        svc.close()
        store.close()

    def test_session_fidelity_scopes_to_one_job(self, tmp_path):
        """Per-session fidelity through the service facade: a truncated
        session reads prefixes while a concurrent full-fidelity session
        sees complete records off the same cached frames."""
        build(tmp_path, "c", codec="zlib", bands=2).close()
        store = ChunkStore.open(tmp_path / "c")
        svc = DataService(store)
        kwargs = dict(seed=2, sampler_seed=4, batch_per_node=16, seq_len=64)
        lo = svc.open_session("lo", fidelity=1, **kwargs)
        hi = svc.open_session("hi", **kwargs)
        lo_lens, hi_lens = [], []
        for sess, out in ((lo, lo_lens), (hi, hi_lens)):
            for batch in sess.epoch(0):
                out.append(int(batch["loss_mask"].sum()))
        assert sum(lo_lens) < sum(hi_lens)  # truncation shortened records
        svc.close()
        store.close()
