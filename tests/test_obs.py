"""Observability-plane tests (DESIGN.md §13).

Pins the tentpole contracts of ``repro.obs``:

* the **tracer** records spans/instants into a bounded ring, exports
  Chrome-trace JSON, and — crucially — is *pure* when disabled: zero
  events, zero allocation on the span fast path, and a per-site cost
  small enough that the instrumentation in a small ``epoch_stream`` run
  stays under the 5% overhead budget;
* tracing is *observationally inert*: a traced epoch produces the
  byte-identical :class:`EpochStream` an untraced one does (differential
  harness spot-check);
* the **MetricsRegistry** absorbs every stats dataclass through the
  round-trippable ``to_dict()`` and renders Prometheus text;
* **attribution** folds a trace into per-stage exclusive time with the
  ``sum(exclusive) + idle == wall`` identity the report is built on;
* a live :class:`DataServiceServer` answers the ``metrics`` RPC with
  per-session counters matching the session's final ServiceStats, and
  ``trace_dump`` exports the server-side ring.
"""

import json
import time
from pathlib import Path

import pytest

from elastic_harness import (
    assert_streams_equal,
    record_replay,
    record_uninterrupted,
)
from repro.core import ChunkStore, SessionSpec
from repro.core.stats import (
    DeviceStats,
    NodeStats,
    PlannerStats,
    ServiceStats,
    StepIO,
)
from repro.core.storage.base import BackendStats
from repro.data.synthetic import SyntheticTokenDataset
from repro.core.stats import PipelineTimeModel
from repro.obs import (
    MetricsRegistry,
    STAGES,
    attribution,
    format_report,
    model_columns,
    trace,
    tracing,
)
from repro.obs.tracer import _NULL_SPAN
from repro.service import DataService
from repro.service.transport import DataServiceServer, RedoxClient

pytestmark = pytest.mark.obs

HARNESS_KW = dict(n=192, c=4, slots=24, nodes=2, seed=3)
BATCH = 8


# ------------------------------------------------------------------- tracer
class TestTracer:
    def test_span_instant_and_events(self):
        with tracing() as t:
            with trace.span("outer", "plan", epoch=0):
                with trace.span("inner", "read", chunk=7):
                    pass
            trace.instant("evict", "read", chunk=7)
        events = t.events()
        assert [e[0] for e in events] == ["inner", "outer", "evict"]
        (iname, icat, its, idur, itid, iargs) = events[0]
        (oname, ocat, ots, odur, otid, oargs) = events[1]
        assert icat == "read" and iargs == {"chunk": 7}
        assert ocat == "plan" and oargs == {"epoch": 0}
        # Nesting: the inner span lies inside the outer one.
        assert ots <= its and its + idur <= ots + odur + 1e-9
        assert itid == otid
        # Instants carry a negative duration sentinel.
        assert events[2][3] < 0

    def test_complete_with_external_timing(self):
        with tracing() as t:
            t0 = time.perf_counter()
            t.complete("planner.plan", "plan", t0, 0.25, {"steps": 3})
        ((name, cat, ts, dur, _tid, args),) = t.events()
        assert (name, cat, dur, args) == ("planner.plan", "plan", 0.25,
                                          {"steps": 3})

    def test_ring_overflow_drops_oldest(self):
        with tracing(capacity=4) as t:
            for i in range(10):
                trace.instant(f"e{i}")
        assert len(t) == 4
        assert t.dropped == 6
        assert [e[0] for e in t.events()] == ["e6", "e7", "e8", "e9"]

    def test_chrome_export_shape(self, tmp_path):
        with tracing() as t:
            with trace.span("read_chunk", "read", chunk=3):
                pass
            trace.instant("evict", "read")
        doc = t.to_chrome()
        assert doc["otherData"]["dropped_events"] == 0
        by_ph = {}
        for ev in doc["traceEvents"]:
            by_ph.setdefault(ev["ph"], []).append(ev)
        (meta,) = by_ph["M"]
        assert meta["name"] == "thread_name"
        (x,) = by_ph["X"]
        assert x["name"] == "read_chunk" and x["cat"] == "read"
        assert x["dur"] >= 0 and x["args"] == {"chunk": 3}
        (inst,) = by_ph["i"]
        assert inst["s"] == "t" and "dur" not in inst
        # dump() writes the same JSON and it parses back.
        out = t.dump(tmp_path / "trace.json")
        assert json.loads(out.read_text())["traceEvents"]

    def test_enable_disable_and_nesting_restores(self):
        assert trace.get() is None
        outer = trace.enable()
        assert trace.get() is outer
        with tracing() as inner:
            assert trace.get() is inner
        assert trace.get() is outer
        assert trace.disable() is outer
        assert trace.get() is None


# --------------------------------------------------------- disabled overhead
class TestDisabledPurity:
    def test_disabled_emits_nothing_and_allocates_nothing(self):
        assert trace.get() is None
        # The module span() fast path returns one shared no-op object.
        s1 = trace.span("a", "read", chunk=1)
        s2 = trace.span("b", "stage")
        assert s1 is s2 is _NULL_SPAN
        trace.instant("a", "read", chunk=1)  # no tracer: swallowed
        with tracing() as t:
            pass  # nothing was pending from the disabled period
        assert len(t) == 0 and t.dropped == 0

    def test_disabled_site_cost_within_epoch_budget(self):
        """The <5% overhead budget, measured structurally: (events a traced
        run records) x (disabled per-site cost) must stay under 5% of the
        untraced epoch wall. This is the quantity that matters — a disabled
        site costs one module load + None check regardless of what the
        instrumented code does around it."""
        t0 = time.perf_counter()
        record_uninterrupted(HARNESS_KW, BATCH, engine="step")
        wall = time.perf_counter() - t0

        with tracing(capacity=1 << 18) as tr:
            record_uninterrupted(HARNESS_KW, BATCH, engine="step")
        events = tr._recorded

        n = 100_000
        best = min(
            _time_disabled_sites(n) for _ in range(3)
        )
        per_site = best / n
        added = events * per_site
        assert added < 0.05 * wall, (
            f"{events} sites x {per_site * 1e9:.0f}ns = {added * 1e3:.2f}ms "
            f"exceeds 5% of the {wall * 1e3:.0f}ms epoch"
        )

    def test_traced_epoch_stream_is_byte_identical(self, tmp_path):
        """Tracing must be observationally inert: the differential harness
        compares a traced live walk + traced replay against their untraced
        twins on every observable (returned ids, StepIO grids, load/ship
        event sequences, NodeStats)."""
        ref_live = record_uninterrupted(HARNESS_KW, BATCH, engine="step")
        ref_replay = record_replay(HARNESS_KW, BATCH)
        with tracing(capacity=1 << 18) as t:
            got_live = record_uninterrupted(HARNESS_KW, BATCH, engine="step")
            got_replay = record_replay(HARNESS_KW, BATCH)
        assert len(t) > 0, "instrumented run recorded no spans"
        assert_streams_equal(got_live, ref_live, num_files=HARNESS_KW["n"])
        assert_streams_equal(got_replay, ref_replay, num_files=HARNESS_KW["n"])


def _time_disabled_sites(n: int) -> float:
    span = trace.span
    t0 = time.perf_counter()
    for _ in range(n):
        with span("site", "read"):
            pass
    return time.perf_counter() - t0


# ------------------------------------------------------------------ metrics
class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("batches_total")
        c.inc()
        c.inc(2)
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("cache_bytes")
        g.set(100)
        g.dec(25)
        h = reg.histogram("latency_s", [0.01, 0.1, 1.0])
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        snap = reg.collect()
        assert snap["batches_total"] == 3
        assert snap["cache_bytes"] == 75
        assert snap["latency_s_count"] == 4
        assert snap["latency_s_sum"] == pytest.approx(5.555)
        assert h.cumulative() == [(0.01, 1), (0.1, 2), (1.0, 3)]
        # Same (name, labels) returns the same instrument.
        assert reg.counter("batches_total") is c

    def test_stats_provider_and_labels(self):
        reg = MetricsRegistry()
        st = ServiceStats(physical_reads=4, physical_bytes=1000, shared_hits=2)
        reg.register_stats("service", lambda: st, labels={"job": "a"})
        snap = reg.collect()
        assert snap['service_physical_bytes{job="a"}'] == 1000
        assert snap['service_shared_hits{job="a"}'] == 2
        # Live: the provider re-reads the object at every collect.
        st.shared_hits = 9
        assert reg.collect()['service_shared_hits{job="a"}'] == 9

    def test_reregister_replaces_and_unregister_removes(self):
        reg = MetricsRegistry()
        reg.register_stats("s", lambda: {"v": 1}, labels={"job": "a"})
        reg.register_stats("s", lambda: {"v": 2}, labels={"job": "a"})
        assert reg.collect() == {'s_v{job="a"}': 2}
        reg.unregister("s", labels={"job": "a"})
        assert reg.collect() == {}

    def test_exposition_format(self):
        reg = MetricsRegistry()
        reg.counter("reads_total", labels={"backend": "vfs"}).inc(7)
        reg.histogram("wait_s", [0.5]).observe(0.2)
        reg.register_stats("device", lambda: DeviceStats(steps=3))
        text = reg.exposition()
        assert '# TYPE reads_total counter' in text
        assert 'reads_total{backend="vfs"} 7' in text
        assert 'wait_s_bucket{le="0.5"} 1' in text
        assert 'wait_s_bucket{le="+Inf"} 1' in text
        assert 'wait_s_count 1' in text
        assert 'device_steps 3' in text
        assert text.endswith("\n")


# ------------------------------------------------------- stats round-trips
STATS_SAMPLES = [
    NodeStats(accesses=10, chunk_loads=3, disk_bytes=4096, read_wait_s=0.5,
              fill_rate_num=2.5, peak_local_bytes=99),
    PlannerStats(plan_time_s=0.1, planned_steps=8, planned_chunk_loads=5),
    ServiceStats(physical_reads=2, shared_hits=7, peak_cache_bytes=1 << 20),
    StepIO(chunk_loads=1, disk_bytes=512, stage_s=0.25, stage_wait_s=0.1),
    DeviceStats(steps=4, bytes_to_device=2048, stage_s=1.0, wait_s=0.25),
    BackendStats(chunk_reads=6, bytes_read=9000, wait_seconds=0.75,
                 peak_inflight=3),
]


class TestStatsDict:
    @pytest.mark.parametrize(
        "obj", STATS_SAMPLES, ids=lambda o: type(o).__name__
    )
    def test_round_trip_exact(self, obj):
        d = obj.to_dict()
        assert type(obj).from_dict(d) == obj
        # Fields only — derived @property ratios are not serialized.
        assert "overlap_fraction" not in d
        assert "mean_fill_rate" not in d
        # Unknown keys (e.g. a newer writer) are ignored on the way in.
        assert type(obj).from_dict({**d, "future_field": 1}) == obj
        # JSON-safe end to end.
        assert type(obj).from_dict(json.loads(json.dumps(d))) == obj

    def test_overlap_fraction_zero_denominator(self):
        # Regression: an idle stager used to report a misleading 1.0.
        assert DeviceStats().overlap_fraction == 0.0
        assert DeviceStats(stage_s=2.0, wait_s=0.5).overlap_fraction == 0.75
        assert DeviceStats(stage_s=1.0, wait_s=3.0).overlap_fraction == 0.0

    def test_other_ratio_guards(self):
        assert NodeStats().read_throughput == 0.0
        assert NodeStats().mean_fill_rate == 1.0
        assert BackendStats().throughput() == 0.0


# -------------------------------------------------------------- attribution
def _ev(cat, lo, hi, name=None):
    return (name or cat, cat, lo, hi - lo, 0, None)


class TestAttribution:
    def test_busy_is_interval_union(self):
        att = attribution(
            [_ev("read", 0.0, 1.0), _ev("read", 0.5, 2.0),
             _ev("read", 3.0, 4.0)],
            wall_s=4.0,
        )
        assert att["busy_s"]["read"] == pytest.approx(3.0)
        assert att["spans"] == 3

    def test_exclusive_priority_and_identity(self):
        # compute [0,2] overlaps read [1,3]; proto [2.5,3] sits inside read;
        # [3.5,4] is uncovered idle.
        events = [
            _ev("compute", 0.0, 2.0),
            _ev("read", 1.0, 3.0),
            _ev("proto", 2.5, 3.0),
        ]
        att = attribution(events, wall_s=4.0)
        assert att["exclusive_s"]["compute"] == pytest.approx(2.0)
        # read keeps only what compute did not claim; proto is fully
        # shadowed by the higher-priority read span.
        assert att["exclusive_s"]["read"] == pytest.approx(1.0)
        assert att["exclusive_s"]["proto"] == pytest.approx(0.0)
        assert att["idle_s"] == pytest.approx(1.0)
        total = sum(att["exclusive_s"].values()) + att["idle_s"]
        assert total == pytest.approx(att["wall_s"])

    def test_plan_outranks_proto(self):
        # A planner span encloses its shadow protocol walk: the time must
        # read as planning, not protocol.
        att = attribution(
            [_ev("plan", 0.0, 1.0), _ev("proto", 0.2, 0.8)], wall_s=1.0
        )
        assert att["exclusive_s"]["plan"] == pytest.approx(1.0)
        assert att["exclusive_s"]["proto"] == pytest.approx(0.0)
        assert STAGES.index("plan") < STAGES.index("proto")

    def test_instants_unknown_cats_and_empty(self):
        att = attribution(
            [("evict", "read", 0.5, -1.0, 0, None),  # instant: no duration
             _ev("mystery", 0.0, 1.0)],
            wall_s=2.0,
        )
        assert "read" not in att["busy_s"]
        assert att["busy_s"]["other"] == pytest.approx(1.0)
        empty = attribution([], wall_s=1.5)
        assert empty["idle_s"] == 1.5 and empty["spans"] == 0

    def test_format_report_renders(self):
        att = attribution(
            [_ev("compute", 0.0, 2.0), _ev("read", 1.0, 3.0)], wall_s=4.0
        )
        text = format_report(att, measured_wall_s=4.0)
        assert "compute" in text and "read" in text and "idle" in text
        assert "epoch wall time: 4.000s" in text

    def test_model_columns_from_step_io(self):
        tm = PipelineTimeModel(disk_bw=100e6, file_overhead=1e-3,
                               chunk_overhead=2e-3, net_bw=1e9,
                               net_latency=1e-4)
        grid = [[StepIO(chunk_loads=2, disk_bytes=10_000_000,
                        net_messages=5, net_bytes=1_000_000)],
                [StepIO(chunk_loads=1, disk_bytes=5_000_000)]]
        cols = model_columns(grid, tm, compute_per_step=0.5)
        assert cols["read"] == pytest.approx(
            3 * 2e-3 + 15_000_000 / 100e6
        )
        assert cols["net"] == pytest.approx(5 * 1e-4 + 1_000_000 / 1e9)
        assert cols["compute"] == pytest.approx(0.5)
        assert cols["epoch"] == pytest.approx(
            tm.epoch_time(grid, 0.5)
        )
        # The model columns merge into the rendered report.
        att = attribution([_ev("compute", 0.0, 1.0)], wall_s=1.0)
        text = format_report(att, model=cols, measured_wall_s=1.0)
        assert "model_s" in text and "pipelined epoch-time bound" in text

    def test_real_trace_attribution_sums_to_wall(self):
        """The acceptance identity on a real traced epoch: the exclusive
        breakdown plus idle covers the measured wall to within 10%."""
        with tracing(capacity=1 << 18) as t:
            t0 = time.perf_counter()
            record_uninterrupted(HARNESS_KW, BATCH, engine="step")
            wall = time.perf_counter() - t0
        att = attribution(t.events(), wall_s=wall)
        assert att["spans"] > 0
        covered = sum(att["exclusive_s"].values()) + att["idle_s"]
        assert covered == pytest.approx(wall, rel=0.10)


# ---------------------------------------------------------- live server RPC
@pytest.mark.transport
class TestServerObservability:
    SPEC = SessionSpec(seed=5, num_nodes=2, batch_per_node=8, seq_len=32)

    @pytest.fixture
    def served(self, tmp_path):
        ds = SyntheticTokenDataset(96, vocab_size=97, mean_len=48, seed=3)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(store.root)
        svc = DataService(store)
        server = DataServiceServer(svc, tmp_path / "svc.sock",
                                   poll_interval=0.001)
        server.start()
        yield server, tmp_path / "svc.sock"
        server.stop()
        store.close()

    def test_metrics_rpc_matches_final_service_stats(self, served):
        server, sock = served
        client = RedoxClient(sock, self.SPEC, job_id="job0")
        for _ in client.epoch(0):
            pass
        out = client.metrics()
        snap, text = out["metrics"], out["text"]
        svc = server.service
        final = svc.residency.per_job_stats["job0"]
        assert final.physical_reads > 0
        for field, v in final.to_dict().items():
            assert snap[f'service_{field}{{job="job0"}}'] == v
        # Aggregate + residency gauges ride along, and the text exposition
        # carries the same samples.
        agg = svc.aggregate_stats()
        assert snap["service_physical_bytes"] == agg.physical_bytes
        assert snap["residency_open_sessions"] == 1
        assert f'service_physical_reads{{job="job0"}} '\
               f'{final.physical_reads}' in text
        client.close()

    def test_metrics_rpc_scrape_is_idempotent(self, served):
        """Scraping twice must not duplicate the per-job providers."""
        server, sock = served
        client = RedoxClient(sock, self.SPEC, job_id="job0")
        for _ in client.epoch(0):
            pass
        first = client.metrics()["metrics"]
        second = client.metrics()["metrics"]
        assert first == second
        client.close()

    def test_trace_dump_rpc(self, served, tmp_path):
        server, sock = served
        client = RedoxClient(sock, self.SPEC, job_id="job0")
        # Tracing off: the RPC reports that instead of failing.
        obj, events = client.trace_dump()
        assert obj is None and events == 0
        trace.enable(1 << 16)
        try:
            for _ in client.epoch(0):
                pass
            doc, events = client.trace_dump()
            assert events > 0 and len(doc["traceEvents"]) > 0
            cats = {e.get("cat") for e in doc["traceEvents"]}
            assert "service" in cats and "ring" in cats
            out = tmp_path / "server_trace.json"
            path, events2 = client.trace_dump(out)
            assert Path(path) == out and events2 >= events
            assert json.loads(out.read_text())["traceEvents"]
        finally:
            trace.disable()
        client.close()
