"""Unit tests for the loop-aware HLO cost model (launch/hlo_cost.py)."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import hlo_costs


def compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


class TestHloCosts:
    def test_plain_matmul(self):
        f = lambda a, b: a @ b
        s = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        t = jax.ShapeDtypeStruct((64, 16), jnp.float32)
        c = hlo_costs(compile_text(f, s, t))
        assert c["flops"] == 2 * 32 * 64 * 16

    def test_scan_multiplies_trip_count(self):
        def f(xs, w):
            def body(c, x):
                return c @ w + x, None
            c, _ = jax.lax.scan(body, xs[0], xs)
            return c

        xs = jax.ShapeDtypeStruct((7, 16, 16), jnp.float32)
        w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        c = hlo_costs(compile_text(f, xs, w))
        assert c["flops"] == 7 * 2 * 16**3

    def test_nested_scans_multiply(self):
        def f(xs, w):
            def outer(c, x):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c + x, None, length=3)
                return ci, None
            c, _ = jax.lax.scan(outer, xs[0], xs)
            return c

        xs = jax.ShapeDtypeStruct((5, 8, 8), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        c = hlo_costs(compile_text(f, xs, w))
        assert c["flops"] == 5 * 3 * 2 * 8**3

    def test_batched_dot_contraction(self):
        f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
        s = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        t = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        c = hlo_costs(compile_text(f, s, t))
        assert c["flops"] == 4 * 2 * 8 * 16 * 8

    def test_bytes_dots_nonzero_and_bounded(self):
        f = lambda a, b: (a @ b).sum()
        s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = hlo_costs(compile_text(f, s, s))
        lo = 2 * 64 * 64 * 4  # two operands
        hi = 16 * 64 * 64 * 4
        assert lo <= c["bytes_dots"] <= hi

    def test_no_dots_no_flops(self):
        f = lambda a: jnp.tanh(a) + 1
        s = jax.ShapeDtypeStruct((128,), jnp.float32)
        c = hlo_costs(compile_text(f, s))
        assert c["flops"] == 0
