"""Subprocess trainer for the transport tests: consume epochs over a
RedoxClient and append one JSON line per batch to ``--out``.

Lines are flushed per batch, so a SIGKILL mid-epoch leaves a valid prefix
on disk — the churn tests read it to see how far the victim got, and the
equivalence tests compare the full record (returned ids + token/mask
checksums) against an in-process solo run.
"""

import argparse
import json
import time

import numpy as np

from repro.core.spec import SessionSpec
from repro.service.transport import RedoxClient


def batch_line(epoch: int, batch) -> str:
    return json.dumps({
        "epoch": epoch,
        "step": int(batch["step"]),
        "returned": np.asarray(batch["returned"]).tolist(),
        "tok_sum": int(np.asarray(batch["tokens"], dtype=np.int64).sum()),
        "tgt_sum": int(np.asarray(batch["targets"], dtype=np.int64).sum()),
        "mask_sum": float(np.asarray(batch["loss_mask"], dtype=np.float64).sum()),
    })


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--socket", required=True)
    p.add_argument("--job-id", required=True)
    p.add_argument("--spec", required=True, help="SessionSpec as JSON")
    p.add_argument("--epochs", type=int, default=1)
    p.add_argument("--out", required=True)
    p.add_argument("--step-sleep", type=float, default=0.0,
                   help="per-batch consumer delay (makes a slow trainer)")
    a = p.parse_args()
    spec = SessionSpec.from_json(json.loads(a.spec))
    client = RedoxClient(a.socket, spec, job_id=a.job_id,
                         heartbeat_interval=0.5, connect_timeout=30.0)
    with open(a.out, "w") as f:
        for epoch in range(a.epochs):
            for batch in client.epoch(epoch):
                f.write(batch_line(epoch, batch) + "\n")
                f.flush()
                if a.step_sleep:
                    time.sleep(a.step_sleep)
    client.close()


if __name__ == "__main__":
    main()
