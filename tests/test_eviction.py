"""Belady/MIN eviction, admission control, and the autotuner.

The tentpole property: under a byte cap, the shared residency with the
merged claim schedule installed never does worse than LRU — and on the
co-scheduled multi-job workload it does strictly better — while every
job's returned stream stays byte-identical to the uncapped run (eviction
is a performance policy, never a correctness one).
"""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.core import ChunkStore, SessionSpec
from repro.core.stats import StepIO
from repro.data import SyntheticTokenDataset
from repro.service import (
    AdmissionControl,
    AdmissionRejected,
    DataService,
    SharedResidency,
)
from repro import autotune

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.eviction

NUM_DOCS = 192


@pytest.fixture(scope="module")
def store_root(tmp_path_factory):
    root = tmp_path_factory.mktemp("eviction") / "chunks"
    ds = SyntheticTokenDataset(NUM_DOCS, 512, mean_len=48, seed=5)
    ds.build_store(root, chunk_size=4, num_slots=16, seed=1).close()
    return root


def run_jobs(root, cap, eviction, jobs=3, epochs=1):
    """Pump ``jobs`` co-scheduled sessions; return (streams, aggregate,
    report)."""
    store = ChunkStore.open(root)
    svc = DataService(store, cache_limit_bytes=cap, eviction=eviction)
    for j in range(jobs):
        svc.open_session(
            f"job{j}", SessionSpec(seed=j, batch_per_node=8, seq_len=64)
        )
    streams = {f"job{j}": [] for j in range(jobs)}
    for epoch in range(epochs):
        for job_id, batch in svc.co_epoch(epoch):
            streams[job_id].append(batch["tokens"].tobytes())
    agg = svc.aggregate_stats()
    rep = svc.stats_report()
    svc.close()
    store.close()
    return streams, agg, rep


# ------------------------------------------------------------- differential
class TestBeladyVsLRU:
    def test_belady_never_worse_and_streams_exact(self, store_root):
        """Cap sweep: Belady physical reads <= LRU at EVERY point, and both
        capped runs return byte-identical streams to the uncapped run."""
        base_streams, base_agg, _ = run_jobs(store_root, None, "belady")
        ws = int(np.asarray(ChunkStore.open(store_root).plan.chunk_bytes).sum())
        for frac in (0.6, 0.5, 0.35, 0.25):
            cap = int(ws * frac)
            lru_streams, lru_agg, _ = run_jobs(store_root, cap, "lru")
            bel_streams, bel_agg, _ = run_jobs(store_root, cap, "belady")
            assert lru_streams == base_streams, f"LRU stream diverged at {frac}"
            assert bel_streams == base_streams, f"Belady stream diverged at {frac}"
            assert bel_agg.physical_reads <= lru_agg.physical_reads, (
                f"Belady did MORE reads than LRU at cap {frac:.0%}: "
                f"{bel_agg.physical_reads} > {lru_agg.physical_reads}"
            )
            assert bel_agg.physical_reads >= base_agg.physical_reads

    def test_belady_strictly_dominates_under_tight_cap(self, store_root):
        """The acceptance criterion: at a cap <= 50% of the working set the
        clairvoyant policy issues strictly fewer physical reads."""
        ws = int(np.asarray(ChunkStore.open(store_root).plan.chunk_bytes).sum())
        cap = ws // 2
        _, lru_agg, _ = run_jobs(store_root, cap, "lru")
        _, bel_agg, _ = run_jobs(store_root, cap, "belady")
        assert lru_agg.evictions > 0, "cap never bit; sweep is vacuous"
        assert bel_agg.physical_reads < lru_agg.physical_reads
        assert bel_agg.physical_bytes < lru_agg.physical_bytes

    def test_unknown_policy_rejected(self, store_root):
        store = ChunkStore.open(store_root)
        try:
            with pytest.raises(ValueError, match="eviction policy"):
                DataService(store, eviction="clock")
        finally:
            store.close()


# ------------------------------------------------------- per-job attribution
class TestStatsAttribution:
    def test_evictions_attributed_not_duplicated(self, store_root):
        """Per-job evictions/bypasses sum to the service totals — the old
        stats_report copied the global counters into the aggregate so that
        summing per-job rows overcounted K-fold."""
        ws = int(np.asarray(ChunkStore.open(store_root).plan.chunk_bytes).sum())
        _, agg, rep = run_jobs(store_root, ws // 3, "belady")
        per_job_ev = sum(r["evictions"] for r in rep["per_job"].values())
        per_job_by = sum(r["cache_bypass"] for r in rep["per_job"].values())
        assert rep["service"]["evictions"] > 0
        assert per_job_ev == rep["service"]["evictions"]
        assert per_job_by == rep["service"]["cache_bypass"]
        assert agg.evictions == rep["service"]["evictions"]
        # peak residency is cache-global: lives in the service record and
        # the aggregate, never fabricated per job
        assert all(r["peak_cache_bytes"] == 0 for r in rep["per_job"].values())
        assert agg.peak_cache_bytes == rep["service"]["peak_cache_bytes"] > 0

    def test_oversized_chunk_counts_as_bypass(self, store_root):
        """A chunk bigger than the whole cap is served but never cached —
        and the refusal is counted, not silent."""
        store = ChunkStore.open(store_root)
        res = SharedResidency(store, cache_limit_bytes=1)
        res.install_claims("j", 0, {0: 2})
        res.read_chunk("j", 0, epoch=0)
        st = res.job_stats("j")
        assert res.cache_bypass == 1 and st.cache_bypass == 1
        assert res.cache_bytes == 0 and res.evictions == 0
        # the second claim re-reads (nothing was cached) — still exact
        res.read_chunk("j", 0, epoch=0)
        assert st.physical_reads == 2
        store.close()


# --------------------------------------------------------- property testing
class _ArrayStore:
    """Minimal store stub: equal-size chunks, counted reads."""

    class _Plan:
        def __init__(self, n):
            self.chunk_bytes = np.full(n, 10, np.int64)

    def __init__(self, n):
        self.plan = self._Plan(n)
        self.reads = 0

    def read_chunk(self, chunk):
        self.reads += 1
        return [(chunk, b"x" * 10)]


def _drive(schedule, num_chunks, cap_chunks, eviction):
    """Feed a raw claim schedule through a SharedResidency as one job."""
    store = _ArrayStore(num_chunks)
    res = SharedResidency(
        store, cache_limit_bytes=cap_chunks * 10, eviction=eviction
    )
    res.install_claims("j", 0, Counter(schedule))
    res.install_schedule(0, list(schedule))
    res.eviction_log = []
    for k in schedule:
        res.read_chunk("j", int(k), epoch=0)
    return store, res


class TestEvictionProperty:
    def test_victim_has_farthest_next_use(self):
        """Deterministic re-check of every logged eviction against the
        ground-truth schedule: no evicted chunk had a nearer next use than
        a resident alternative."""
        rng = np.random.default_rng(7)
        for trial in range(20):
            n = int(rng.integers(4, 12))
            schedule = rng.integers(0, n, size=int(rng.integers(20, 80)))
            cap = int(rng.integers(2, max(n - 1, 3)))
            store, res = _drive(schedule.tolist(), n, cap, "belady")
            for ev in res.eviction_log:
                vic = ev["victim_next"]
                for k, nxt in ev["residents"].items():
                    if k == ev["victim"]:
                        continue
                    if vic is None:
                        continue  # victim had no future use: always safe
                    assert nxt is not None and nxt <= vic, (
                        f"trial {trial}: evicted {ev['victim']} (next {vic}) "
                        f"over resident {k} (next {nxt})"
                    )

    def test_belady_min_offline_bound(self):
        """Belady with the exact schedule never does more physical reads
        than LRU on the same schedule (MIN optimality, sampled)."""
        rng = np.random.default_rng(11)
        for _ in range(15):
            n = int(rng.integers(4, 10))
            schedule = rng.integers(0, n, size=int(rng.integers(30, 90))).tolist()
            cap = int(rng.integers(2, max(n - 1, 3)))
            lru_store, _ = _drive(schedule, n, cap, "lru")
            bel_store, _ = _drive(schedule, n, cap, "belady")
            assert bel_store.reads <= lru_store.reads

if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        data=st.data(),
        n=st.integers(min_value=3, max_value=10),
        cap=st.integers(min_value=2, max_value=8),
    )
    def test_property_no_nearer_eviction(data, n, cap):
        """Eviction never picks a chunk whose next use is nearer than a
        resident alternative's (checked against the offline ground truth)."""
        schedule = data.draw(
            st.lists(
                st.integers(min_value=0, max_value=n - 1),
                min_size=10, max_size=60,
            )
        )
        _, res = _drive(schedule, n, min(cap, n - 1), "belady")
        # replay offline: claims drained at each eviction give the true
        # remaining schedule; check the victim against it
        for ev in res.eviction_log:
            remaining = schedule[ev["claims_drained"]:]
            nxt = {k: None for k in ev["residents"]}
            for i, k in enumerate(remaining):
                if k in nxt and nxt[k] is None:
                    nxt[k] = i
            vic = nxt[ev["victim"]]
            if vic is None:
                continue
            for k, dist in nxt.items():
                if k != ev["victim"]:
                    assert dist is not None and dist <= vic


# ------------------------------------------------------------ schedule drain
class TestNextUseIndex:
    def test_positions_drain_with_claims(self):
        store = _ArrayStore(4)
        res = SharedResidency(store, cache_limit_bytes=None)
        res.install_claims("j", 0, {0: 2, 1: 1})
        res.install_schedule(0, [0, 1, 0])
        assert res.next_use(0) == 0 and res.next_use(1) == 1
        res.read_chunk("j", 0, epoch=0)
        assert res.next_use(0) == 2  # second occurrence now the head
        res.read_chunk("j", 1, epoch=0)
        assert res.next_use(1) is None
        res.read_chunk("j", 0, epoch=0)
        assert res.next_use(0) is None
        assert not res.has_claims()

    def test_reinstall_is_keep_first_until_retired(self):
        store = _ArrayStore(4)
        res = SharedResidency(store)
        res.install_claims("j", 0, {0: 1})
        res.install_schedule(0, [0])
        res.install_schedule(0, [0, 0, 0])  # keep-first: ignored
        assert len(res._next_use[0]) == 1
        res.read_chunk("j", 0, epoch=0)
        res.drop_claims("j", 0)  # pool retired -> epoch retired, index pruned
        assert res.next_use(0) is None
        res.install_claims("j", 0, {0: 1})
        res.install_schedule(0, [0])  # re-run reinstalls cleanly
        assert res.next_use(0) == 0

    def test_epoch_positions_are_epoch_major(self):
        store = _ArrayStore(4)
        res = SharedResidency(store)
        res.install_claims("j", 0, {0: 1})
        res.install_claims("j", 1, {0: 1})
        res.install_schedule(0, [0])
        res.install_schedule(1, [0])
        d = res._next_use[0]
        assert list(d) == sorted(d)
        assert d[1] - d[0] >= (1 << 40) - 1


# ------------------------------------------------------------- admission
class TestAdmission:
    def test_reject_and_release(self, store_root):
        store = ChunkStore.open(store_root)
        probe = DataService(store)
        s = probe.open_session(
            "p", SessionSpec(seed=0, batch_per_node=8, seq_len=64)
        )
        steps = s.steps_per_epoch(0)
        probe.close()
        compute = 0.01
        rate1 = float(np.asarray(store.plan.chunk_bytes).sum()) / (steps * compute)
        svc = DataService(store, admission=AdmissionControl(
            bandwidth_bytes_per_s=rate1 * 1.5, compute_per_step_s=compute,
        ))
        svc.open_session("j0", SessionSpec(seed=0, batch_per_node=8, seq_len=64))
        rep = svc.admission_report()
        assert rep["admitted_bytes_per_s"] == pytest.approx(rate1, rel=1e-6)
        with pytest.raises(AdmissionRejected, match="storage"):
            svc.open_session(
                "j1", SessionSpec(seed=1, batch_per_node=8, seq_len=64)
            )
        # closing the admitted job frees its committed rate
        svc.close_session("j0")
        svc.open_session("j1", SessionSpec(seed=1, batch_per_node=8, seq_len=64))
        svc.close()
        store.close()

    def test_queue_mode_times_out_typed(self, store_root):
        store = ChunkStore.open(store_root)
        svc = DataService(store, admission=AdmissionControl(
            bandwidth_bytes_per_s=1.0, compute_per_step_s=0.01,
            mode="queue", queue_timeout_s=0.2,
        ))
        with pytest.raises(AdmissionRejected, match="queued"):
            svc.open_session(
                "j0", SessionSpec(seed=0, batch_per_node=8, seq_len=64)
            )
        svc.close()
        store.close()

    def test_invalid_mode(self):
        with pytest.raises(ValueError, match="admission mode"):
            AdmissionControl(
                bandwidth_bytes_per_s=1.0, compute_per_step_s=0.01, mode="drop"
            )


# -------------------------------------------------------------- autotuner
class TestAutotune:
    def test_calibration_round_trip(self, store_root, tmp_path):
        calib = autotune.calibrate(store_root, sample_chunks=8, repeats=1)
        path = calib.save(tmp_path / "calib.json")
        back = autotune.Calibration.load(path)
        assert back.to_dict() == calib.to_dict()
        assert set(calib.backends) == {"vfs", "mmap", "parallel"}
        for p in calib.backends.values():
            assert p.bandwidth_bytes_per_s > 0
            assert p.chunk_overhead_s >= 0

    def test_required_cache_bytes_exact(self):
        nb = np.array([10, 20, 30, 40])
        # A's interval spans B's -> peak is A+B
        assert autotune.required_cache_bytes([0, 1, 0, 2], nb) == 30
        # disjoint intervals -> peak is the largest single chunk
        assert autotune.required_cache_bytes([0, 1, 2], nb) == 30
        # everything overlapping -> full working set
        assert autotune.required_cache_bytes([0, 1, 2, 2, 1, 0], nb) == 60
        assert autotune.required_cache_bytes([], nb) == 0

    def test_required_cache_is_sufficient_for_belady(self):
        """The computed cap really is eviction-free under Belady: drive the
        schedule at exactly that cap and observe zero evictions and one
        physical read per distinct chunk."""
        rng = np.random.default_rng(3)
        for _ in range(10):
            n = int(rng.integers(3, 9))
            schedule = rng.integers(0, n, size=int(rng.integers(15, 50))).tolist()
            need = autotune.required_cache_bytes(
                schedule, np.full(n, 10, np.int64)
            )
            store = _ArrayStore(n)
            res = SharedResidency(store, cache_limit_bytes=need)
            res.install_claims("j", 0, Counter(schedule))
            res.install_schedule(0, list(schedule))
            for k in schedule:
                res.read_chunk("j", int(k), epoch=0)
            assert res.evictions == 0 and res.cache_bypass == 0
            assert store.reads == len(set(schedule))

    def test_select_config_is_grid_argmin(self, store_root):
        """The returned choice predicts no worse than every grid point —
        i.e. select_config IS the grid search over the fitted model."""
        calib = autotune.calibrate(store_root, sample_chunks=8, repeats=1)
        demand = autotune.uniform_step_io(1_000_000, 48, 24)
        grid = (1, 2, 4, 8)
        choice = autotune.select_config(
            calib, demand, compute_per_step_s=1e-4, readahead_grid=grid
        )
        from repro.core.storage import BACKENDS
        for name in calib.backends:
            model = autotune.fit_time_model(calib, name)
            strict = model.epoch_time_strict([demand], 1e-4)
            pipelined = model.epoch_time([demand], 1e-4)
            is_async = getattr(BACKENDS[name], "wants_prefetch", False)
            burst = max(s.chunk_loads for s in demand) or 1
            for depth in (grid if is_async else (0,)):
                f = min(1.0, depth / burst) if is_async else 0.0
                predicted = strict - f * (strict - pipelined)
                assert choice.predicted_epoch_s <= predicted + 1e-12

    def test_tune_store_end_to_end(self, store_root):
        calib, choice = autotune.tune_store(
            store_root, compute_per_step_s=1e-4,
            memory_limit_bytes=1_000_000,
        )
        assert choice.backend in calib.backends
        assert choice.cache_limit_bytes == 1_000_000
        assert choice.predicted_epoch_s > 0
        assert choice.model.disk_bw == (
            calib.backends[choice.backend].bandwidth_bytes_per_s
        )

    @pytest.mark.slow
    def test_autotune_within_15pct_of_grid_search(self, store_root):
        """Acceptance criterion, measured: the autotuned config's epoch time
        is within 15% of the best grid-searched config on the small preset.
        Wall-clock measurement -> slow (advisory) tier."""
        import time as _time

        def measure(backend, readahead):
            from repro.core.storage import make_backend
            kw = {"readahead": readahead} if readahead else {}
            store = ChunkStore.open(
                store_root, backend=make_backend(backend, **kw)
            )
            svc = DataService(store)
            svc.open_session(
                "j", SessionSpec(seed=0, batch_per_node=8, seq_len=64)
            )
            t0 = _time.perf_counter()
            for _ in svc.co_epoch(0):
                pass
            wall = _time.perf_counter() - t0
            svc.close()
            store.close()
            return wall

        candidates = [("vfs", 0), ("mmap", 0), ("parallel", 4), ("parallel", 8)]
        measured = {
            cfg: min(measure(*cfg) for _ in range(3)) for cfg in candidates
        }
        best = min(measured.values())
        _, choice = autotune.tune_store(
            store_root,
            compute_per_step_s=0.0,
            readahead_grid=(4, 8),
        )
        chosen = (
            choice.backend, choice.readahead if choice.backend == "parallel" else 0
        )
        if chosen not in measured:
            measured[chosen] = min(measure(*chosen) for _ in range(3))
        assert measured[chosen] <= best * 1.15 + 0.05, (
            f"autotuned {chosen} measured {measured[chosen]:.3f}s vs "
            f"grid best {best:.3f}s ({measured})"
        )


# ----------------------------------------------------- live-mode degradation
class TestLiveModeFallback:
    def test_no_schedule_degrades_to_lru(self):
        """With no planned next uses at all, the Belady victim rule is
        exactly least-recently-claimed — live-only services keep today's
        behaviour."""
        store = _ArrayStore(6)
        live = set(range(6))
        res = SharedResidency(store, cache_limit_bytes=30, eviction="belady")
        res.set_liveness(lambda k: k in live)
        res.eviction_log = []
        for k in [0, 1, 2, 3, 4]:  # cap of 3 chunks: evictions from k=3 on
            res.read_chunk("livejob", k)
        assert [ev["victim"] for ev in res.eviction_log] == [0, 1]
        assert all(ev["victim_next"] is None for ev in res.eviction_log)
