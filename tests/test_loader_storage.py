"""ChunkStore round-trips and the RedoxLoader → JAX bridge."""

import numpy as np
import pytest

from repro.core import ChunkStore, Cluster, EpochSampler, RedoxLoader
from repro.data import SyntheticTokenDataset, decode_record


def build_dataset(tmp_path, num_docs=192, chunk_size=4, slots=16, nodes=1):
    ds = SyntheticTokenDataset(num_docs, vocab_size=97, mean_len=48, seed=3)
    store = ds.build_store(tmp_path / "chunks", chunk_size, num_slots=slots, seed=1)
    cluster = Cluster(store.plan, nodes, store=store, seed=2)
    sampler = EpochSampler(num_docs, nodes, seed=4)
    return ds, store, cluster, sampler


class TestChunkStore:
    def test_chunk_roundtrip(self, tmp_path):
        ds, store, _, _ = build_dataset(tmp_path)
        for k in (0, 1, store.plan.num_chunks - 1):
            for fid, blob in store.read_chunk(k):
                np.testing.assert_array_equal(
                    decode_record(blob), ds.record_tokens(fid)
                )

    def test_file_roundtrip(self, tmp_path):
        ds, store, _, _ = build_dataset(tmp_path)
        for fid in (0, 7, 101, 191):
            np.testing.assert_array_equal(
                decode_record(store.read_file(fid)), ds.record_tokens(fid)
            )

    def test_reopen(self, tmp_path):
        ds, store, _, _ = build_dataset(tmp_path)
        back = ChunkStore.open(store.root)
        assert back.plan.num_files == store.plan.num_files
        assert back.read_file(5) == store.read_file(5)

    def test_read_file_reuses_handles(self, tmp_path):
        """Regression: ranged reads must not re-open the chunk file (or
        re-parse the index) per call — handles are cached in the backend."""
        ds, store, _, _ = build_dataset(tmp_path)
        fids = list(range(0, store.plan.num_files, 3))
        for fid in fids:
            store.read_file(fid)
        opens = store.backend_stats.file_opens
        # At most one open per distinct chunk file, never one per record.
        touched = len({int(store.plan.chunk_of[f]) for f in fids})
        assert opens <= touched
        for fid in fids:  # second pass: every handle already cached
            store.read_file(fid)
        assert store.backend_stats.file_opens == opens
        assert store.backend_stats.ranged_reads == 2 * len(fids)


class TestRedoxLoader:
    def test_batches_cover_epoch(self, tmp_path):
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(cluster, sampler, batch_per_node=16, seq_len=64)
        seen_tokens = 0
        batches = list(loader.epoch(0))
        assert len(batches) == loader.steps_per_epoch()
        for b in batches:
            assert b["tokens"].shape == (16, 64)
            assert b["targets"].shape == (16, 64)
            assert b["loss_mask"].shape == (16, 64)
            assert b["loss_mask"].sum() > 0
            seen_tokens += int(b["loss_mask"].sum())
        assert seen_tokens > 0

    def test_batch_contents_are_real_records(self, tmp_path):
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(cluster, sampler, batch_per_node=8, seq_len=32)
        batch = next(iter(loader.epoch(0)))
        # Every row must be a prefix of SOME document (redirection allows any).
        all_docs = {}
        for d in range(ds.num_docs):
            toks = ds.record_tokens(d)
            all_docs[d] = toks
        for i in range(8):
            row = batch["tokens"][i]
            m = batch["loss_mask"][i].astype(bool)
            # row = [doc[0], ..., doc[n-1]] shifted view; reconstruct
            full = np.concatenate([row[:1], batch["targets"][i]])[: m.sum() + 1]
            matched = any(
                len(t) >= len(full) and np.array_equal(t[: len(full)], full)
                for t in all_docs.values()
            )
            assert matched, f"batch row {i} is not a prefix of any document"

    def test_multi_node_loader(self, tmp_path):
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=3)
        loader = RedoxLoader(cluster, sampler, batch_per_node=8, seq_len=32)
        batches = list(loader.epoch(0))
        for b in batches:
            assert b["tokens"].shape == (24, 32)  # 3 nodes x 8

    def test_async_loader_propagates_worker_errors(self, tmp_path):
        """Regression: a failed storage read inside the worker thread must
        surface to the consumer, not end the epoch cleanly/short."""
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(cluster, sampler, batch_per_node=16, seq_len=32)
        calls = {"n": 0}
        real = store.read_chunk

        def flaky(chunk):
            calls["n"] += 1
            if calls["n"] > 3:
                raise OSError("injected storage failure")
            return real(chunk)

        store.read_chunk = flaky
        with pytest.raises(OSError, match="injected storage failure"):
            for _ in loader.epoch_async(0):
                pass

    def test_async_loader_abandoned_consumer_joins_worker(self, tmp_path):
        """Regression: breaking out of epoch_async mid-epoch must not leave
        the worker thread blocked forever on a full queue."""
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(
            cluster, sampler, batch_per_node=8, seq_len=32, queue_depth=1
        )
        gen = loader.epoch_async(0)
        next(gen)  # queue is full and the worker is blocked on put()
        gen.close()  # GeneratorExit -> shutdown signal -> join
        assert loader._worker is not None
        loader._worker.join(timeout=5.0)
        assert not loader._worker.is_alive(), "worker thread leaked"

    def test_device_loader_abandoned_consumer_releases_buffers(self, tmp_path):
        """Same contract for the device path (DESIGN.md §12): abandoning
        epoch_device must join the protocol worker AND the staging thread,
        and release every staged-but-unconsumed device buffer."""
        from repro.core.device import DeviceStager

        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(
            cluster, sampler, batch_per_node=8, seq_len=32, queue_depth=1
        )
        stager = DeviceStager(depth=1)
        gen = loader.epoch_device(0, stager)
        next(gen)
        gen.close()
        assert loader._worker is not None
        loader._worker.join(timeout=5.0)
        assert not loader._worker.is_alive(), "worker thread leaked"
        assert stager._thread is not None
        stager._thread.join(timeout=5.0)
        assert not stager._thread.is_alive(), "staging thread leaked"
        assert stager.live_buffers == 0, "device buffers stranded"
        stager.close()  # idempotent after stream teardown

    def test_async_loader_exception_in_consumer_joins_worker(self, tmp_path):
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(
            cluster, sampler, batch_per_node=8, seq_len=32, queue_depth=1
        )
        with pytest.raises(RuntimeError, match="consumer bailed"):
            for _ in loader.epoch_async(0):
                raise RuntimeError("consumer bailed")
        loader._worker.join(timeout=5.0)
        assert not loader._worker.is_alive(), "worker thread leaked"

    def test_async_loader_same_order(self, tmp_path):
        ds, store, cluster, sampler = build_dataset(tmp_path, nodes=1)
        loader = RedoxLoader(cluster, sampler, batch_per_node=16, seq_len=32)
        sync = [b["tokens"].copy() for b in loader.epoch(0)]
        ds2, store2, cluster2, sampler2 = build_dataset(tmp_path / "b", nodes=1)
        loader2 = RedoxLoader(cluster2, sampler2, batch_per_node=16, seq_len=32)
        asy = [b["tokens"].copy() for b in loader2.epoch_async(0)]
        assert len(sync) == len(asy)
        for a, b in zip(sync, asy):
            np.testing.assert_array_equal(a, b)
