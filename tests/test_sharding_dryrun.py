"""Sharding rules unit tests + a miniature dry-run in a subprocess.

The subprocess sets XLA_FLAGS for 8 emulated devices (the assignment
forbids setting it globally — smoke tests must see 1 device), builds a
(2,4) mesh, and lowers+compiles reduced configs of three families.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import RunConfig
from repro.parallel.axes import ShardingRules
from repro.parallel.sharding import activation_rules, param_rules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class TestShardingRules:
    def test_divisibility_rail(self):
        mesh = FakeMesh({"data": 2, "model": 4})
        rules = ShardingRules.__new__(ShardingRules)
        rules.mesh = mesh
        rules.rules = {"heads": "model", "batch": ("data",)}
        spec = rules.spec_for(("batch", "heads"), (6, 8))
        assert spec == __import__("jax").sharding.PartitionSpec(("data",), "model")
        # 6 % 4 != 0 on heads -> replicated
        spec2 = rules.spec_for(("batch", "heads"), (8, 6))
        assert spec2[1] is None

    def test_axis_used_once(self):
        mesh = FakeMesh({"model": 4})
        rules = ShardingRules.__new__(ShardingRules)
        rules.mesh = mesh
        rules.rules = {"a": "model", "b": "model"}
        spec = rules.spec_for(("a", "b"), (8, 8))
        assert spec[0] == "model" and spec[1] is None

    def test_param_rules_policies(self):
        mesh = FakeMesh({"data": 2, "model": 4})
        tp = param_rules(mesh, RunConfig())
        assert tp["mlp"] == "model" and tp["embed"] is None
        fsdp = param_rules(mesh, RunConfig(fsdp=True))
        assert fsdp["embed"] == ("data",)
        dp = param_rules(mesh, RunConfig(parallelism="dp_only"))
        assert all(v is None for v in dp.values())

    def test_activation_rules_seq_parallel(self):
        mesh = FakeMesh({"data": 2, "model": 4})
        assert activation_rules(mesh, RunConfig())["seq_act"] is None
        assert activation_rules(mesh, RunConfig(seq_parallel=True))["seq_act"] == "model"


SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses, jax
    from repro.launch import dryrun_lib
    from repro.configs import ARCHS, reduced, get_shape

    small = dataclasses.replace(get_shape("train_4k"), seq_len=256, global_batch=8)
    dryrun_lib.get_config = lambda name: reduced(ARCHS[name])
    dryrun_lib.get_shape = lambda name: small
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch in ("tinyllama-1.1b", "deepseek-moe-16b", "zamba2-1.2b"):
        r = dryrun_lib.run_cell(arch, "train_4k", mesh)
        out[arch] = dict(status=r.status, flops=r.flops_per_device,
                         coll=r.collectives["total_bytes"] if r.collectives else 0,
                         err=r.error[:200])
    print("RESULT " + json.dumps(out))
    """
)


MOE_EQ_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, numpy as np, jax, jax.numpy as jnp
    from repro.configs import ARCHS, reduced, RunConfig
    from repro.models.common import RngStream, split_params
    from repro.models.moe import init_moe, moe_block, moe_block_a2a
    from repro.parallel.axes import ShardingRules, sharding_ctx
    from repro.parallel import sharding as shd

    cfg = dataclasses.replace(reduced(ARCHS["deepseek-moe-16b"]), capacity_factor=16.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    values, _ = split_params(init_moe(RngStream(0), cfg, jnp.float32))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64, cfg.d_model)), jnp.float32)
    rules = ShardingRules(mesh, shd.activation_rules(mesh, RunConfig()))
    with mesh, sharding_ctx(rules):
        ref, aux_r = jax.jit(lambda v, x: moe_block(v, x, cfg))(values, x)
        a2a, aux_a = jax.jit(lambda v, x: moe_block_a2a(v, x, cfg))(values, x)
    err = float(jnp.max(jnp.abs(ref - a2a))) / float(jnp.max(jnp.abs(ref)))
    assert err < 1e-4, err
    assert abs(float(aux_r) - float(aux_a)) < 1e-5
    print("RESULT ok", err)
    """
)


@pytest.mark.slow
def test_moe_a2a_equivalent_to_gspmd_on_8_devices():
    """shard_map all-to-all MoE == pjit MoE at generous capacity (§Perf)."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", MOE_EQ_SUBPROC], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "RESULT ok" in proc.stdout, proc.stderr[-2000:]


@pytest.mark.slow
def test_mini_dryrun_compiles_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, f"no result line; stderr tail: {proc.stderr[-2000:]}"
    out = json.loads(line[0][len("RESULT "):])
    for arch, r in out.items():
        assert r["status"] == "ok", (arch, r["err"])
        assert r["flops"] > 0 and r["coll"] > 0
