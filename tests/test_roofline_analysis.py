"""Roofline analysis module tests (consumes synthetic dry-run rows)."""

from repro.launch.roofline import HW, analyze, model_flops, to_markdown


def fake_row(arch="tinyllama-1.1b", shape="train_4k", flops=1e13, byts=1e11, coll=1e9):
    return dict(
        arch=arch, shape=shape, mesh="data16xmodel16", status="ok",
        step_kind="train_step", flops_per_device=flops, bytes_per_device=byts,
        collectives={"total_bytes": coll},
        memory={"temp_tpu_adjusted": 5e9, "argument_size_in_bytes": 1e9},
    )


class TestRoofline:
    def test_terms_and_dominance(self):
        a = analyze([fake_row()])[0]
        assert abs(a["compute_s"] - 1e13 / HW["peak_flops"]) < 1e-9
        assert abs(a["memory_s"] - 1e11 / HW["hbm_bw"]) < 1e-9
        assert abs(a["collective_s"] - 1e9 / HW["ici_bw"]) < 1e-9
        assert a["dominant"] == "memory"
        assert a["fits_hbm"] is True

    def test_model_flops_train_vs_decode(self):
        t = model_flops("tinyllama-1.1b", "train_4k")
        d = model_flops("tinyllama-1.1b", "decode_32k")
        # train: 6*N*B*S ; decode: 2*N*B
        assert t / d == (6 * 4096 * 256) / (2 * 128)

    def test_moe_uses_active_params(self):
        from repro.configs import get_config

        kimi = get_config("kimi-k2-1t-a32b")
        assert kimi.active_param_count() < 0.05 * kimi.param_count()
        f = model_flops("kimi-k2-1t-a32b", "train_4k")
        assert f == 6.0 * kimi.active_param_count() * 4096 * 256

    def test_skip_rows_passthrough(self):
        row = dict(arch="hubert-xlarge", shape="decode_32k", mesh="m", status="skip: x")
        a = analyze([row])[0]
        assert a["status"] == "skip: x"

    def test_markdown_renders(self):
        md = to_markdown(analyze([fake_row()]))
        assert "| arch |" in md and "tinyllama" in md
