"""Out-of-process transport tests (DESIGN.md §11).

Everything here runs over the REAL transport — Unix sockets and mmap'd
rings under pytest's tmpdir, no network — and pins the tentpole claim: a
trainer consuming through a :class:`RedoxClient` (separate thread or
separate OS process, SIGKILL'd or not) sees the byte-identical GlobalBatch
stream an in-process :class:`RedoxLoader` produces, and a dead client's
claims are unwound without disturbing survivors.
"""

import dataclasses
import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import ChunkStore, SessionSpec
from repro.core.loader import RedoxLoader
from repro.data.synthetic import SyntheticTokenDataset
from repro.service import DataService
from repro.service.transport import (
    BatchRing,
    DataServiceServer,
    RedoxClient,
    ServiceSuspended,
    SessionClosed,
    TransportError,
)
from repro.service.transport.ring import (
    FRAME_BATCH,
    FRAME_EOE,
    STATE_CLOSED,
    RingClosed,
)

pytestmark = pytest.mark.transport

NUM_DOCS = 96
SPEC = SessionSpec(seed=5, num_nodes=2, batch_per_node=8, seq_len=32)
CHILD = Path(__file__).parent / "transport_child.py"


def build_store(tmp_path, name="chunks"):
    ds = SyntheticTokenDataset(NUM_DOCS, vocab_size=97, mean_len=48, seed=3)
    store = ds.build_store(tmp_path / name, 4, num_slots=16, seed=1)
    return ChunkStore.open(store.root)


def solo_batches(tmp_path, spec, epochs=1):
    """The in-process reference stream: one loader, same spec, same store
    bytes, epochs consumed in order."""
    store = ChunkStore.open(tmp_path / "chunks")
    loader = RedoxLoader.from_spec(spec, store)
    out = []
    for e in range(epochs):
        out.extend((e, b) for b in loader.epoch(e))
    store.close()
    return out


def batch_key(epoch, b):
    """Everything deterministic about a batch (measured read_wait_s skipped)."""
    return (
        epoch,
        int(b["step"]),
        b["tokens"].tobytes(),
        b["targets"].tobytes(),
        b["loss_mask"].tobytes(),
        np.asarray(b["returned"]).tobytes(),
        tuple(sorted(
            (n, tuple(
                v for f, v in sorted(dataclasses.asdict(io).items())
                if f != "read_wait_s"
            ))
            for n, io in b["io_by_node"].items()
        )),
    )


@pytest.fixture
def served(tmp_path):
    """A running DataServiceServer over a fresh store; yields (server, path)."""
    store = build_store(tmp_path)
    svc = DataService(store)
    server = DataServiceServer(
        svc, tmp_path / "svc.sock", poll_interval=0.001, heartbeat_timeout=30.0
    )
    server.start()
    yield server, tmp_path / "svc.sock"
    server.stop()
    store.close()


# ---------------------------------------------------------------- ring unit
class TestBatchRing:
    def test_roundtrip_and_wraparound(self, tmp_path):
        ring = BatchRing.create(tmp_path / "r", 4096)
        peer = BatchRing.attach(tmp_path / "r")
        # Frames larger than half the capacity force wrap-around quickly.
        payload = bytes(range(256)) * 6  # 1536 bytes
        for i in range(10):
            assert ring.try_write(FRAME_BATCH, [payload, bytes([i])])
            kind, got = peer.read(timeout=1.0)
            assert kind == FRAME_BATCH
            assert got == payload + bytes([i])
        assert peer.try_read() is None
        peer.close()
        ring.close()

    def test_backpressure_and_budget(self, tmp_path):
        ring = BatchRing.create(tmp_path / "r", 4096)
        big = b"x" * 2000
        assert ring.try_write(FRAME_BATCH, [big])
        assert ring.try_write(FRAME_BATCH, [big])
        assert not ring.try_write(FRAME_BATCH, [big])  # full: producer skips
        assert not ring.writable(2048)
        with pytest.raises(BufferError):
            ring.write(FRAME_BATCH, [big])
        # Consumer drains one frame -> one budget frees up.
        peer = BatchRing.attach(tmp_path / "r")
        peer.try_read()
        assert ring.writable(2048)
        peer.close()
        ring.close()

    def test_closed_ring_drains_then_raises(self, tmp_path):
        ring = BatchRing.create(tmp_path / "r", 4096)
        ring.write(FRAME_EOE, [b"{}"])
        ring.mark_state(STATE_CLOSED)
        peer = BatchRing.attach(tmp_path / "r")
        assert peer.read(timeout=1.0) == (FRAME_EOE, b"{}")  # pending first
        with pytest.raises(RingClosed):
            peer.read(timeout=1.0)
        peer.close()
        ring.close()

    def test_attach_rejects_non_ring(self, tmp_path):
        (tmp_path / "bogus").write_bytes(b"\x00" * 128)
        with pytest.raises(ValueError, match="not a Redox batch ring"):
            BatchRing.attach(tmp_path / "bogus")


# ------------------------------------------------- in-thread client identity
class TestClientEquivalence:
    @pytest.mark.parametrize("engine", ["replay", "step", "per_access"])
    def test_thread_client_byte_identical(self, tmp_path, served, engine):
        server, sock = served
        spec = SPEC.replace(engine=engine)
        ref = solo_batches(tmp_path, spec, epochs=2)
        client = RedoxClient(sock, spec, job_id=f"job-{engine}")
        got = [(e, b) for e in range(2) for b in client.epoch(e)]
        client.close()
        assert [batch_key(e, b) for e, b in got] == \
               [batch_key(e, b) for e, b in ref]

    def test_two_clients_share_bytes(self, tmp_path, served):
        """Two same-pattern jobs over the socket still dedup physical reads
        through the shared residency (the PR-3 property, now cross-process)."""
        server, sock = served
        a = RedoxClient(sock, SPEC, job_id="jobA")
        b = RedoxClient(sock, SPEC, job_id="jobB")
        ref = solo_batches(tmp_path, SPEC)

        outs = {}

        def run(cli, key):
            outs[key] = [(0, batch) for batch in cli.epoch(0)]

        ta = threading.Thread(target=run, args=(a, "a"))
        tb = threading.Thread(target=run, args=(b, "b"))
        ta.start(); tb.start(); ta.join(); tb.join()
        for key in ("a", "b"):
            assert [batch_key(e, x) for e, x in outs[key]] == \
                   [batch_key(e, x) for e, x in ref]
        agg = a.stats()["aggregate"]
        assert agg["shared_hits"] > 0
        a.close()
        b.close()

    def test_steps_per_epoch_and_unknown_op(self, tmp_path, served):
        server, sock = served
        client = RedoxClient(sock, SPEC, job_id="job0")
        store = ChunkStore.open(tmp_path / "chunks")
        assert client.steps_per_epoch(0) == \
            RedoxLoader.from_spec(SPEC, store).steps_per_epoch(0)
        store.close()
        with pytest.raises(ValueError, match="unknown transport op"):
            client._rpc({"op": "nonsense"})
        client.close()

    def test_duplicate_job_id_rejected(self, served):
        server, sock = served
        client = RedoxClient(sock, SPEC, job_id="job0")
        with pytest.raises(ValueError, match="already has a connected client"):
            RedoxClient(sock, SPEC, job_id="job0")
        client.close()

    def test_spec_roundtrips_the_wire(self, served):
        server, sock = served
        spec = SPEC.replace(engine="step", queue_depth=3)
        client = RedoxClient(sock, spec, job_id="job0")
        # The server echoes the installed session's spec, with the derived
        # sampler seed materialised.
        assert client.spec == spec.replace(
            sampler_seed=spec.effective_sampler_seed
        )
        client.close()


# ------------------------------------------------------- subprocess identity
def spawn_child(sock, job_id, spec, out, *, epochs=1, step_sleep=0.0):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    return subprocess.Popen(
        [
            sys.executable, str(CHILD),
            "--socket", str(sock), "--job-id", job_id,
            "--spec", json.dumps(spec.to_json()),
            "--epochs", str(epochs), "--out", str(out),
            "--step-sleep", str(step_sleep),
        ],
        env=env,
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
    )


def child_lines(out: Path):
    if not out.exists():
        return []
    return [json.loads(line) for line in out.read_text().splitlines()]


def solo_lines(tmp_path, spec, epochs=1):
    """The reference stream in transport_child's line format."""
    from transport_child import batch_line

    return [
        json.loads(batch_line(e, b))
        for e, b in solo_batches(tmp_path, spec, epochs=epochs)
    ]


class TestSubprocessTrainer:
    @pytest.mark.parametrize("engine", ["replay", "step", "per_access"])
    def test_separate_process_byte_identical(self, tmp_path, served, engine):
        """The acceptance criterion: a trainer in its own OS process via
        RedoxClient == in-process JobSession, for all three engines."""
        server, sock = served
        spec = SPEC.replace(engine=engine)
        out = tmp_path / "child.jsonl"
        proc = spawn_child(sock, f"job-{engine}", spec, out, epochs=2)
        _, err = proc.communicate(timeout=120)
        assert proc.returncode == 0, err.decode()
        assert child_lines(out) == solo_lines(tmp_path, spec, epochs=2)

    def test_sigkill_one_of_three_mid_epoch(self, tmp_path, served):
        """SIGKILL one client mid-epoch: survivors byte-identical to solo,
        the victim's leaked claims unwound."""
        server, sock = served
        svc = server.service
        outs = {j: tmp_path / f"{j}.jsonl" for j in ("a", "b", "victim")}
        procs = {
            "a": spawn_child(sock, "a", SPEC, outs["a"], step_sleep=0.02),
            "b": spawn_child(sock, "b", SPEC, outs["b"], step_sleep=0.02),
            "victim": spawn_child(
                sock, "victim", SPEC, outs["victim"], step_sleep=0.05
            ),
        }
        # Kill the victim once it has demonstrably consumed a mid-epoch batch.
        deadline = time.monotonic() + 60
        while len(child_lines(outs["victim"])) < 2:
            assert time.monotonic() < deadline, "victim never started"
            time.sleep(0.02)
        procs["victim"].kill()
        procs["victim"].wait()
        for j in ("a", "b"):
            _, err = procs[j].communicate(timeout=120)
            assert procs[j].returncode == 0, err.decode()
        ref = solo_lines(tmp_path, SPEC)
        for j in ("a", "b"):
            assert child_lines(outs[j]) == ref, f"survivor {j} diverged"
        # The victim got a correct prefix before dying.
        got = child_lines(outs["victim"])
        assert got == ref[: len(got)]
        # EOF-reap closed the victim's session and unwound its claims.
        deadline = time.monotonic() + 30
        while svc.residency.has_claims():
            assert time.monotonic() < deadline, "victim claims never unwound"
            time.sleep(0.02)
        assert all(s.job_id != "victim" for s in svc.sessions)


# -------------------------------------------------------------------- churn
class TestChurn:
    N_QUICK = 8

    def _churn(self, tmp_path, sock, n_jobs, *, join_delay=0.15):
        """n_jobs thread clients: half start at once, half join mid-epoch
        while the first half consumes slowly (the pump admits them into the
        already-running round)."""
        specs = {
            f"j{i}": SPEC.replace(seed=i, engine="replay" if i % 2 else "step")
            for i in range(n_jobs)
        }
        outs, errs = {}, []

        def run(job, delay, sleep):
            try:
                time.sleep(delay)
                cli = RedoxClient(sock, specs[job], job_id=job)
                got = []
                for b in cli.epoch(0):
                    got.append(batch_key(0, b))
                    time.sleep(sleep)
                outs[job] = got
                cli.close()
            except BaseException as e:  # surfaced below
                errs.append((job, e))

        threads = []
        for i, job in enumerate(specs):
            early = i < n_jobs // 2
            t = threading.Thread(
                target=run,
                args=(job, 0.0 if early else join_delay, 0.01 if early else 0.0),
            )
            t.start()
            threads.append(t)
        for t in threads:
            t.join(timeout=180)
        assert not errs, errs
        for job, spec in specs.items():
            ref = [batch_key(0, b) for _, b in solo_batches(tmp_path, spec)]
            assert outs[job] == ref, f"{job} diverged from its solo run"

    def test_mid_epoch_joins_quick(self, tmp_path, served):
        server, sock = served
        self._churn(tmp_path, sock, self.N_QUICK)
        assert not server.service.residency.has_claims()

    @pytest.mark.slow
    def test_many_sessions_with_kills(self, tmp_path, served):
        """Tens of sessions over one socket, subprocess kills included."""
        server, sock = served
        # Two waves of thread clients...
        self._churn(tmp_path, sock, 12)
        # ...then a subprocess wave with a mid-epoch SIGKILL.
        outs = {j: tmp_path / f"{j}.jsonl" for j in ("p0", "p1", "pv")}
        procs = {
            j: spawn_child(
                sock, j, SPEC, outs[j],
                step_sleep=0.05 if j == "pv" else 0.02,
            )
            for j in outs
        }
        while len(child_lines(outs["pv"])) < 2:
            time.sleep(0.02)
        procs["pv"].kill()
        procs["pv"].wait()
        ref = solo_lines(tmp_path, SPEC)
        for j in ("p0", "p1"):
            _, err = procs[j].communicate(timeout=120)
            assert procs[j].returncode == 0, err.decode()
            assert child_lines(outs[j]) == ref
        deadline = time.monotonic() + 30
        while server.service.residency.has_claims():
            assert time.monotonic() < deadline
            time.sleep(0.02)


# ------------------------------------------------------------ dead clients
class TestLiveness:
    def test_heartbeat_timeout_reaps_frozen_client(self, tmp_path):
        """A client that stops heartbeating AND stops draining its ring is
        declared dead and reaped; its session closes, claims unwind."""
        store = build_store(tmp_path)
        svc = DataService(store)
        server = DataServiceServer(
            svc, tmp_path / "s.sock", poll_interval=0.001, heartbeat_timeout=0.4
        )
        server.start()
        try:
            client = RedoxClient(
                tmp_path / "s.sock", SPEC, job_id="frozen",
                heartbeat_interval=0,  # heartbeats disabled: plays dead
            )
            stream = client.epoch(0)
            next(stream)  # begin the epoch, consume one batch, then freeze
            deadline = time.monotonic() + 30
            while any(s.job_id == "frozen" for s in svc.sessions):
                assert time.monotonic() < deadline, "frozen client never reaped"
                time.sleep(0.02)
            # The client-side stream observes the closed ring (after
            # draining whatever was already in flight).
            with pytest.raises(SessionClosed):
                for _ in stream:
                    pass
            assert not svc.residency.has_claims()
        finally:
            server.stop()
            store.close()

    def test_ring_drain_counts_as_liveness(self, tmp_path):
        """A trainer blocked in long steps (no RPCs) but still consuming
        batches must NOT be reaped: head movement keeps it alive."""
        store = build_store(tmp_path)
        svc = DataService(store)
        server = DataServiceServer(
            svc, tmp_path / "s.sock", poll_interval=0.001, heartbeat_timeout=0.5
        )
        server.start()
        try:
            client = RedoxClient(
                tmp_path / "s.sock", SPEC, job_id="slow",
                heartbeat_interval=0,  # only ring drain keeps it alive
            )
            got = []
            for b in client.epoch(0):
                got.append(batch_key(0, b))
                time.sleep(0.2)  # longer than nothing, shorter than timeout
            ref = [batch_key(0, b) for _, b in solo_batches(tmp_path, SPEC)]
            assert got == ref
            client.close()
        finally:
            server.stop()
            store.close()


# -------------------------------------------------------- suspend over wire
class TestSuspendResume:
    def test_suspend_resume_over_socket(self, tmp_path):
        """Mid-epoch service suspend over the wire: the client drains every
        batch produced before the suspend point, reconnects to a resumed
        server, and the combined stream is byte-identical to solo."""
        store = build_store(tmp_path)
        svc = DataService(store)
        server = DataServiceServer(svc, tmp_path / "s.sock", poll_interval=0.001)
        server.start()
        client = RedoxClient(tmp_path / "s.sock", SPEC, job_id="jobA")

        got = []
        stream = client.epoch(0)
        for _ in range(2):  # consume a couple batches, then checkpoint
            got.append(batch_key(0, next(stream)))
        assert client.suspend(tmp_path / "ck") == tmp_path / "ck"
        with pytest.raises(ServiceSuspended):
            for b in stream:  # drains in-flight frames first
                got.append(batch_key(0, b))
        resume_at = len(got)
        with pytest.raises(ServiceSuspended):
            client.epoch(1).send(None)  # suspended server refuses new epochs
        client.close()
        server.stop()
        store.close()

        # Fresh process: re-open the store, resume the service, reconnect.
        store2 = ChunkStore.open(tmp_path / "chunks")
        svc2 = DataService.resume(tmp_path / "ck", store2)
        server2 = DataServiceServer(svc2, tmp_path / "s2.sock", poll_interval=0.001)
        server2.start()
        client2 = RedoxClient(tmp_path / "s2.sock", job_id="jobA")  # attach
        assert client2.resume_point == (0, resume_at)
        got += [batch_key(0, b) for b in client2.epoch(0)]
        got += [batch_key(1, b) for b in client2.epoch(1)]
        client2.close()
        server2.stop()
        store2.close()

        ref = [batch_key(e, b) for e, b in solo_batches(tmp_path, SPEC, epochs=2)]
        assert got == ref

    def test_client_resume_from_flag(self, tmp_path):
        """A client may also hand the suspend dir to open_session itself
        (fresh server that did NOT pre-resume): the server resolves this
        job's subdir through the service manifest."""
        store = build_store(tmp_path)
        svc = DataService(store)
        server = DataServiceServer(svc, tmp_path / "s.sock", poll_interval=0.001)
        server.start()
        client = RedoxClient(tmp_path / "s.sock", SPEC, job_id="jobA")
        got = []
        stream = client.epoch(0)
        got.append(batch_key(0, next(stream)))
        client.suspend(tmp_path / "ck")
        with pytest.raises(ServiceSuspended):
            for b in stream:
                got.append(batch_key(0, b))
        client.close()
        server.stop()
        store.close()

        store2 = ChunkStore.open(tmp_path / "chunks")
        svc2 = DataService(store2)  # blank service, no pre-resume
        server2 = DataServiceServer(svc2, tmp_path / "s2.sock", poll_interval=0.001)
        server2.start()
        client2 = RedoxClient(
            tmp_path / "s2.sock", job_id="jobA", resume_from=tmp_path / "ck"
        )
        assert client2.resume_point == (0, len(got))
        got += [batch_key(0, b) for b in client2.epoch(0)]
        client2.close()
        server2.stop()
        store2.close()
        ref = [batch_key(0, b) for _, b in solo_batches(tmp_path, SPEC)]
        assert got == ref


# ------------------------------------------------------------ error surface
class TestErrors:
    def test_no_server_listening(self, tmp_path):
        with pytest.raises(TransportError, match="no data server listening"):
            RedoxClient(tmp_path / "nothing.sock", SPEC, connect_timeout=0.3)

    def test_server_stop_closes_clients(self, tmp_path, served):
        server, sock = served
        client = RedoxClient(sock, SPEC, job_id="job0")
        server.stop()
        with pytest.raises((SessionClosed, TransportError)):
            for _ in client.epoch(0):
                pass


# ------------------------------------------------------------- launch CLIs
class TestLaunchCLI:
    """The consolidated launcher flags (satellite: launch/cli.py): every
    shared data-plane/elastic flag is spelled identically — same type,
    choices, nargs, metavar, help — by train.py and data_service.py."""

    SHARED = [
        "--batch", "--seq-len", "--num-docs", "--vocab-size", "--seed",
        "--policy", "--engine", "--backend", "--codec", "--bands",
        "--fidelity", "--resume-data", "--suspend-after",
    ]
    #: The storage subset (launch.cli.add_storage_args) that
    #: examples/train_lm.py must also spell identically.
    STORAGE = ["--backend", "--codec", "--bands", "--fidelity"]
    # Builder parameters: these defaults intentionally differ per launcher
    # (historical CLI defaults); everything else must match exactly.
    PER_LAUNCHER_DEFAULTS = {"--batch", "--seq-len", "--num-docs"}

    @staticmethod
    def _actions(parser):
        return {o: a for a in parser._actions for o in a.option_strings}

    def test_shared_flags_spelled_identically(self):
        from repro.launch.data_service import build_parser as svc_parser
        from repro.launch.train import build_parser as train_parser

        ta, sa = self._actions(train_parser()), self._actions(svc_parser())
        for opt in self.SHARED:
            assert opt in ta, f"train.py lost {opt}"
            assert opt in sa, f"data_service.py lost {opt}"
            t, s = ta[opt], sa[opt]
            same = ("type", "choices", "nargs", "const", "metavar", "help")
            for attr in same:
                assert getattr(t, attr) == getattr(s, attr), (opt, attr)
            if opt not in self.PER_LAUNCHER_DEFAULTS:
                assert t.default == s.default, opt

    def test_storage_flags_shared_with_example(self):
        """examples/train_lm.py composes add_storage_args too — same
        spelling for every byte-representation knob."""
        import importlib.util

        from repro.launch.train import build_parser as train_parser

        path = Path(__file__).parent.parent / "examples" / "train_lm.py"
        ex = importlib.util.spec_from_file_location("train_lm_example", path)
        mod = importlib.util.module_from_spec(ex)
        ex.loader.exec_module(mod)
        ta, ea = self._actions(train_parser()), self._actions(mod.build_parser())
        for opt in self.STORAGE:
            assert opt in ea, f"train_lm.py lost {opt}"
            for attr in ("type", "choices", "nargs", "const", "metavar",
                         "help", "default"):
                assert getattr(ta[opt], attr) == getattr(ea[opt], attr), (
                    opt, attr
                )

    def test_engine_choices_track_session_spec(self):
        from repro.core.spec import _ENGINES
        from repro.launch.train import build_parser

        act = self._actions(build_parser())["--engine"]
        assert tuple(act.choices) == _ENGINES

    def test_bare_resume_data_resolves_per_launcher(self):
        import argparse

        from repro.launch.cli import RESUME_AUTO, resolve_resume_dir

        ap = argparse.ArgumentParser()
        assert resolve_resume_dir(ap, None, Path("d")) is None
        assert resolve_resume_dir(ap, "given", Path("d")) == Path("given")
        assert resolve_resume_dir(ap, RESUME_AUTO, Path("d")) == Path("d")
        # Launchers with no default location reject the bare flag.
        with pytest.raises(SystemExit):
            resolve_resume_dir(ap, RESUME_AUTO, None)

    def test_train_parses_bare_resume_data(self):
        from repro.launch.cli import RESUME_AUTO
        from repro.launch.train import build_parser

        args = build_parser().parse_args(["--arch", "xlstm-350m", "--resume-data"])
        assert args.resume_data == RESUME_AUTO
        args = build_parser().parse_args(
            ["--arch", "xlstm-350m", "--resume-data", "ck"]
        )
        assert args.resume_data == "ck"


@pytest.mark.slow
class TestServeEndToEnd:
    """Two-terminal quickstart as subprocesses: ``data_service --serve`` in
    one OS process, ``train --data-server`` in another."""

    def test_train_against_served_data_plane(self, tmp_path):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
        sock = tmp_path / "svc.sock"
        srv = subprocess.Popen(
            [
                sys.executable, "-m", "repro.launch.data_service",
                "--serve", str(sock), "--num-docs", "256",
                "--vocab-size", "512", "--seq-len", "64", "--co-refill",
            ],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        )
        try:
            out = subprocess.run(
                [
                    sys.executable, "-m", "repro.launch.train",
                    "--arch", "xlstm-350m", "--steps", "6",
                    "--seq-len", "64", "--batch", "8",
                    "--data-server", str(sock),
                    "--workdir", str(tmp_path / "w"),
                ],
                env=env, capture_output=True, text=True, timeout=300,
            )
            assert out.returncode == 0, out.stdout + out.stderr
            assert "done: 6 steps" in out.stdout
            assert "data plane: " in out.stdout
        finally:
            assert srv.poll() is None, srv.stdout.read()  # server survived
            srv.send_signal(signal.SIGINT)
            srv.wait(timeout=30)
