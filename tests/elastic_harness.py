"""Differential harness for elastic data-plane equivalence (DESIGN.md §10).

One epoch *scenario* — (cluster config, policy, batch, optional mid-epoch
``fail_node``/``join_node`` schedules) — can be executed many ways:

* the uninterrupted live walk (``engine="step"`` or ``"per_access"``);
* replay of a clairvoyant :class:`EpochPlan`;
* a walk chopped by suspend/restore at every k-th **step barrier**, each hop
  persisting a :class:`ClusterSnapshot` to npz+manifest files and rebuilding
  a brand-new cluster from them (simulating a fresh process);
* the replay engine chopped the same way (``EpochPlanner.state_at`` +
  ``plan_from`` suffix re-planning per hop);
* the reference walk chopped at every k-th **access** — suspension at an
  arbitrary access ``t``, mid-step, mid-node.

All of them must produce the *identical* :class:`EpochStream`: returned-id
streams, chunk-load and ship event sequences, per-step StepIO grids, and
end-of-epoch NodeStats — plus exactly-once consumption. ``test_elastic.py``
drives the grid; ``test_planner.py``/``test_service.py`` reuse the
comparison helpers, making this the template for equivalence tests.
"""

import dataclasses
import json

import numpy as np

from repro.core import ChunkingPlan, Cluster, EpochPlanner, EpochSampler
from repro.core.elastic import ClusterSnapshot
from repro.core.planner import PlanRecorder
from repro.core.stats import StepIO

IO_FIELDS = ("chunk_loads", "disk_bytes", "file_reads", "net_messages", "net_bytes")


def io_key(io: StepIO) -> tuple:
    """The exact (non-measured) counters of a StepIO."""
    return tuple(getattr(io, f) for f in IO_FIELDS)


def make(n=960, c=8, slots=64, nodes=3, seed=0, sizes=None, **kw):
    """A small id-space cluster + sampler (same knobs as test_planner)."""
    if sizes is None:
        sizes = np.full(n, 100, dtype=np.int64)
    plan = ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)
    cluster = Cluster(plan, nodes, seed=seed, **kw)
    sampler = EpochSampler(n, nodes, seed=seed + 99)
    return cluster, sampler


# --------------------------------------------------------------- stream record
@dataclasses.dataclass
class EpochStream:
    """Everything observable about one epoch execution."""

    returned: list          # per node: np.int64[...] full consumption order
    io_grid: list           # per step: {node: io_key tuple} (absent == zeros)
    loads: list             # (step, owner, chunk, fill_rate, files tuple)
    ships: list             # (step, src, dst, file, loc)
    node_stats: list        # NodeStats per node

    def all_returned(self) -> np.ndarray:
        parts = [r for r in self.returned if r.size]
        return np.concatenate(parts) if parts else np.empty(0, np.int64)


def _normalize_io(io_by_node, num_nodes) -> dict:
    out = {}
    for r in range(num_nodes):
        key = io_key(io_by_node.get(r, StepIO()))
        if any(key):
            out[r] = key
    return out


def _events_from_recorder(rec: PlanRecorder, step_offset: int = 0):
    loads = [
        (s + step_offset, o, k, fr, tuple(f.tolist()))
        for s, o, k, fr, f in zip(
            rec.load_step, rec.load_owner, rec.load_chunk,
            rec.load_fill_rate, rec.load_files,
        )
    ]
    ships = [
        (s + step_offset, src, dst, f, loc)
        for s, src, dst, f, loc in zip(
            rec.ship_step, rec.ship_src, rec.ship_dst, rec.ship_file, rec.ship_loc,
        )
    ]
    return loads, ships


def _events_from_plan(plan):
    loads = [
        (int(s) + plan.start_step, int(o), int(k), float(fr),
         tuple(plan.load_files(i).tolist()))
        for i, (s, o, k, fr) in enumerate(zip(
            plan.load_step, plan.load_owner, plan.load_chunk, plan.load_fill_rate,
        ))
    ]
    ships = [
        (int(s) + plan.start_step, int(src), int(dst), int(f), int(loc))
        for s, src, dst, f, loc in zip(
            plan.ship_step, plan.ship_src, plan.ship_dst,
            plan.ship_file, plan.ship_loc,
        )
    ]
    return loads, ships


class _Accum:
    """Accumulates an EpochStream across suspension segments."""

    def __init__(self):
        self.returned: "dict[int, list]" = {}
        self.io_grid: list = []
        self.loads: list = []
        self.ships: list = []
        self.node_stats = None

    def add_step(self, returned_per_node, io_by_node, num_nodes):
        for r in range(num_nodes):
            ret = returned_per_node[r] if r < len(returned_per_node) else None
            if ret is not None and len(ret):
                self.returned.setdefault(r, []).extend(int(x) for x in ret)
        self.io_grid.append(_normalize_io(io_by_node, num_nodes))

    def finish(self, cluster) -> EpochStream:
        self.node_stats = [n.stats.copy() for n in cluster.nodes]
        num_nodes = cluster.num_nodes
        return EpochStream(
            returned=[
                np.asarray(self.returned.get(r, []), dtype=np.int64)
                for r in range(num_nodes)
            ],
            io_grid=self.io_grid,
            loads=self.loads,
            ships=self.ships,
            node_stats=self.node_stats,
        )


# ------------------------------------------------------------------ recorders
def record_uninterrupted(
    make_kwargs, batch, *, engine="step", epoch=0, failures=None, joins=None
) -> EpochStream:
    """One live, unchopped epoch walk."""
    cluster, sampler = make(**make_kwargs)
    rec = PlanRecorder()
    acc = _Accum()
    for _, returned, _, io_by_node in cluster.epoch_stream(
        sampler, epoch, batch,
        engine=engine, recorder=rec, failures=failures, joins=joins,
    ):
        acc.add_step(returned, io_by_node, cluster.num_nodes)
    acc.loads, acc.ships = _events_from_recorder(rec)
    return acc.finish(cluster)


def record_replay(
    make_kwargs, batch, *, epoch=0, failures=None, joins=None
) -> EpochStream:
    """Plan the scenario clairvoyantly, then replay the plan."""
    cluster, sampler = make(**make_kwargs)
    plan = EpochPlanner(cluster).plan(
        sampler, epoch, batch, failures=failures, joins=joins
    )
    acc = _Accum()
    for _, returned, _, io_by_node in cluster.replay_stream(
        plan, epoch=epoch, batch_per_node=batch
    ):
        acc.add_step(returned, io_by_node, cluster.num_nodes)
    acc.loads, acc.ships = _events_from_plan(plan)
    return acc.finish(cluster)


def _hop(cluster, tmp_path, tag) -> Cluster:
    """Suspend-to-disk, then rebuild a fresh cluster from the files only."""
    d = tmp_path / f"hop_{tag}"
    cluster.snapshot().save(d)
    snap = ClusterSnapshot.load(d)
    return Cluster.restore(snap, plan=cluster.plan)


def record_suspended(
    make_kwargs, batch, *, every, engine="step", epoch=0,
    failures=None, joins=None, tmp_path,
) -> EpochStream:
    """The same scenario, suspending/restoring at every ``every``-th step."""
    cluster, sampler = make(**make_kwargs)
    acc = _Accum()
    start, hops = 0, 0
    while True:
        rec = PlanRecorder()
        stream = cluster.epoch_stream(
            sampler if start == 0 else None, epoch, batch,
            engine=engine, recorder=rec, failures=failures, joins=joins,
            start_step=start, resume=start > 0,
        )
        steps = 0
        exhausted = True
        for _, returned, _, io_by_node in stream:
            acc.add_step(returned, io_by_node, cluster.num_nodes)
            steps += 1
            if steps == every:
                exhausted = False
                break
        loads, ships = _events_from_recorder(rec, step_offset=start)
        acc.loads.extend(loads)
        acc.ships.extend(ships)
        if exhausted:
            return acc.finish(cluster)
        stream.close()
        start += steps
        cluster = _hop(cluster, tmp_path, hops)
        hops += 1


def record_suspended_replay(
    make_kwargs, batch, *, every, epoch=0, failures=None, joins=None, tmp_path,
) -> EpochStream:
    """Replay chopped at every ``every``-th step: each hop derives the
    snapshot by shadow simulation (``state_at``) — replay protocol state is
    implicit — then re-plans and replays only the epoch suffix."""
    cluster, sampler = make(**make_kwargs)
    planner = EpochPlanner(cluster)
    plan = planner.plan(sampler, epoch, batch, failures=failures, joins=joins)
    acc = _Accum()
    start, hops = 0, 0
    while True:
        acc_loads, acc_ships = _events_from_plan(plan)
        acc.loads.extend(acc_loads)
        acc.ships.extend(acc_ships)
        stream = cluster.replay_stream(plan, epoch=epoch, batch_per_node=batch)
        steps = 0
        exhausted = True
        for _, returned, _, io_by_node in stream:
            acc.add_step(returned, io_by_node, cluster.num_nodes)
            steps += 1
            if steps == every:
                exhausted = False
                break
        if exhausted:
            return acc.finish(cluster)
        stream.close()
        start += steps
        # the executed prefix's events stay; drop the unexecuted suffix ones
        acc.loads = [e for e in acc.loads if e[0] < start]
        acc.ships = [e for e in acc.ships if e[0] < start]
        snap = EpochPlanner(make(**make_kwargs)[0]).state_at(
            sampler, epoch, batch, start, failures=failures, joins=joins
        )
        d = tmp_path / f"rhop_{hops}"
        snap.save(d)
        snap = ClusterSnapshot.load(d)
        cluster = Cluster.restore(snap, plan=cluster.plan)
        plan = EpochPlanner(cluster).plan_from(
            snap, failures=failures, joins=joins
        )
        hops += 1


def record_suspended_per_access(
    make_kwargs, batch, *, every, epoch=0, failures=None, joins=None, tmp_path,
) -> EpochStream:
    """The reference walk suspended at every ``every``-th **access** —
    including mid-step, mid-node. Driver loop state (the trainer's own
    cursor) rides along as JSON; protocol state goes through the snapshot."""
    cluster, sampler = make(**make_kwargs)
    cluster.begin_epoch(sampler, epoch)
    cluster._grid = (batch, "ceil")
    acc = _Accum()
    # Driver state, serialized across hops like a trainer checkpoint:
    state = {"step": 0, "his": None, "count": 0, "io": {}, "partial": {}}
    hops = 0
    while True:
        rec = PlanRecorder()
        cluster.set_recorder(rec)
        suspended = _drive_per_access(cluster, acc, rec, state, batch,
                                      every, failures, joins)
        cluster.set_recorder(None)
        loads, ships = _events_from_recorder(rec)
        acc.loads.extend(loads)
        acc.ships.extend(ships)
        if not suspended:
            cluster._check_epoch_complete()
            return acc.finish(cluster)
        d = tmp_path / f"ahop_{hops}"
        cluster.snapshot(step=state["step"]).save(d)
        (d / "driver_state.json").write_text(json.dumps(state))
        snap = ClusterSnapshot.load(d)
        state = json.loads((d / "driver_state.json").read_text())
        cluster = Cluster.restore(snap, plan=cluster.plan)
        hops += 1


def _drive_per_access(cluster, acc, rec, state, batch, every, failures, joins):
    """Continue the manual reference walk; True when suspending mid-epoch."""
    while True:
        step = state["step"]
        if state["his"] is None:
            # Step barrier: elastic events fire here, exactly once.
            if failures and step in failures:
                cluster.fail_node(
                    failures[step], int(cluster.positions[failures[step]])
                )
            if joins and step in joins:
                for _ in range(joins[step]):
                    cluster.join_node()
            if cluster._live_exhausted():
                return False
            state["his"] = [
                int(min(cluster.positions[r] + batch, cluster.sequences[r].size))
                for r in range(cluster.num_nodes)
            ]
            state["io"] = {}
            state["partial"] = {}
        rec.step = step  # absolute step for load/ship attribution
        io_by_node = {
            int(r): StepIO(**dict(zip(IO_FIELDS, v)))
            for r, v in state["io"].items()
        }
        for r in range(cluster.num_nodes):
            if cluster.failed[r]:
                continue
            hi = state["his"][r] if r < len(state["his"]) else 0
            while int(cluster.positions[r]) < hi:
                pos = int(cluster.positions[r])
                f, _ = cluster.access(
                    r, pos, int(cluster.sequences[r][pos]), io_by_node
                )
                state["partial"].setdefault(str(r), []).append(int(f))
                state["count"] += 1
                if every and state["count"] % every == 0:
                    state["io"] = {
                        str(k): list(io_key(v)) for k, v in io_by_node.items()
                    }
                    return True
        returned = [
            np.asarray(state["partial"].get(str(r), []), dtype=np.int64)
            for r in range(cluster.num_nodes)
        ]
        acc.add_step(returned, io_by_node, cluster.num_nodes)
        state.update({"step": step + 1, "his": None, "io": {}, "partial": {}})


# ------------------------------------------------------------- golden streams
#: Tiny fixed scenario behind tests/golden/streams.json: small enough to
#: commit, big enough to exercise misses, redirection, and remote prefetch.
GOLDEN_CONFIG = dict(n=96, c=4, slots=16, nodes=2, seed=7)
GOLDEN_BATCH = 8


def golden_streams() -> dict:
    """Per-(policy, engine) returned-id streams of the golden scenario.

    Committed under ``tests/golden/streams.json`` (regenerate with
    ``python tests/golden/regen.py``) so a refactor that silently changes
    the shuffle — in any one engine — fails against the recorded stream
    instead of only against the other engines.
    """
    from repro.core import EpochPlanner as _Planner

    out = {"config": dict(GOLDEN_CONFIG, batch=GOLDEN_BATCH), "streams": {}}
    for policy in ("max_fill", "random"):
        per_engine = {}
        for engine in ("step", "per_access"):
            cluster, sampler = make(policy=policy, **GOLDEN_CONFIG)
            res = cluster.run_epoch(sampler, 0, GOLDEN_BATCH, engine=engine)
            per_engine[engine] = [r.tolist() for r in res.returned]
        cluster, sampler = make(policy=policy, **GOLDEN_CONFIG)
        plan = _Planner(cluster).plan(sampler, 0, GOLDEN_BATCH)
        res = cluster.run_epoch(sampler, 0, GOLDEN_BATCH, plan=plan)
        per_engine["replay"] = [r.tolist() for r in res.returned]
        out["streams"][policy] = per_engine
    return out


# ----------------------------------------------------------------- assertions
def assert_node_stats_equal(a, b, *, skip=("read_wait_s", "peak_inflight_reads")):
    """NodeStats lists equal on every exact counter (measured ones skipped)."""
    assert len(a) == len(b)
    for na, nb in zip(a, b):
        for f in dataclasses.fields(type(na)):
            if f.name in skip:
                continue
            assert getattr(na, f.name) == getattr(nb, f.name), f.name


def assert_streams_equal(a: EpochStream, b: EpochStream, *, num_files=None):
    """Full differential equality of two EpochStreams (+ exactly-once)."""
    assert len(a.returned) == len(b.returned), "node counts differ"
    for r, (ra, rb) in enumerate(zip(a.returned, b.returned)):
        np.testing.assert_array_equal(ra, rb, err_msg=f"returned stream, node {r}")
    assert len(a.io_grid) == len(b.io_grid), "step counts differ"
    for s, (ia, ib) in enumerate(zip(a.io_grid, b.io_grid)):
        assert ia == ib, f"StepIO grid diverges at step {s}: {ia} != {ib}"
    assert a.loads == b.loads, "chunk-load event sequences differ"
    assert a.ships == b.ships, "ship event sequences differ"
    assert_node_stats_equal(a.node_stats, b.node_stats)
    if num_files is not None:
        assert sorted(a.all_returned().tolist()) == list(range(num_files)), (
            "exactly-once violated"
        )


def assert_same_epoch(res_a, res_b, rec_a=None, rec_b=None):
    """EpochResult/PlanRecorder equality (the test_planner.py contract)."""
    for a, b in zip(res_a.returned, res_b.returned):
        np.testing.assert_array_equal(a, b)
    assert res_a.per_node_step_io == res_b.per_node_step_io
    assert res_a.node_stats == res_b.node_stats
    if rec_a is not None and rec_b is not None:
        assert rec_a.load_chunk == rec_b.load_chunk
        assert rec_a.load_owner == rec_b.load_owner
        assert rec_a.load_step == rec_b.load_step
        assert rec_a.load_fill_rate == rec_b.load_fill_rate
        for fa, fb in zip(rec_a.load_files, rec_b.load_files):
            np.testing.assert_array_equal(fa, fb)
        assert rec_a.ship_file == rec_b.ship_file
        assert rec_a.ship_loc == rec_b.ship_loc
        assert rec_a.ship_src == rec_b.ship_src
        assert rec_a.ship_dst == rec_b.ship_dst
