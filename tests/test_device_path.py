"""DeviceStager: packing, identity vs the host epoch, stats, teardown.

The contract under test (DESIGN.md §12): the device path is a pure
transport — ``epoch_device`` / ``stream`` must yield byte-identical
tokens/targets/loss_mask to the host epoch, annotate (not corrupt) the
per-step IO accounting, and never strand device buffers, whatever the
consumer does.
"""

import numpy as np
import pytest

from repro.core import Cluster, EpochSampler, RedoxLoader, SessionSpec
from repro.core.device import DeviceStager, HostPack, pack_records
from repro.data import SyntheticTokenDataset

pytestmark = pytest.mark.device


def build_loader(tmp_path, *, nodes=1, batch_per_node=8, seq_len=32, **kw):
    ds = SyntheticTokenDataset(96, vocab_size=97, mean_len=48, seed=3)
    store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
    cluster = Cluster(store.plan, nodes, store=store, seed=2)
    sampler = EpochSampler(96, nodes, seed=4)
    return store, RedoxLoader(
        cluster, sampler, batch_per_node=batch_per_node, seq_len=seq_len, **kw
    )


def grids(b):
    return tuple(np.asarray(b[k]) for k in ("tokens", "targets", "loss_mask"))


class TestPackRecords:
    def test_dedup_and_padding(self):
        recs = [np.arange(5, dtype=np.int32), np.arange(9, dtype=np.int32),
                np.arange(5, dtype=np.int32)]
        returned = np.asarray([40, 7, 40])  # rows 0 and 2 share a file
        slots, lens, idx = pack_records(recs, returned, seq_len=16, row_pad=8)
        assert slots.shape == (2, 24)  # 2 unique files, 17 -> pad to 24
        assert slots.dtype == np.int32 and idx.dtype == np.int32
        # np.unique sorts by file id: slot 0 = file 7, slot 1 = file 40
        np.testing.assert_array_equal(idx, [1, 0, 1])
        assert lens[0] == 9 and lens[1] == 5
        np.testing.assert_array_equal(slots[1, :5], np.arange(5))
        assert (slots[1, 5:] == 0).all()

    def test_length_clip_to_seq_plus_one(self):
        recs = [np.arange(100, dtype=np.int32)]
        slots, lens, idx = pack_records(recs, None, seq_len=16, row_pad=8)
        assert lens[0] == 17 and slots.shape[1] == 24

    def test_no_returned_means_one_slot_per_row(self):
        recs = [np.arange(4, dtype=np.int32)] * 3
        slots, lens, idx = pack_records(recs, None, seq_len=8)
        assert slots.shape[0] == 3
        np.testing.assert_array_equal(idx, [0, 1, 2])


class TestEpochDevice:
    def test_matches_host_epoch_bytes(self, tmp_path):
        store, loader = build_loader(tmp_path)
        host = [grids(b) + (int(b["step"]),) for b in loader.epoch(0)]
        stager = DeviceStager()
        dev = [grids(b) + (int(b["step"]),) for b in loader.epoch_device(0, stager)]
        assert len(host) == len(dev) > 0
        for h, d in zip(host, dev):
            for a, b in zip(h, d):
                np.testing.assert_array_equal(a, b)
        assert stager.stats.kernel_steps == len(dev)  # Pallas path taken
        assert stager.stats.bytes_to_device > 0
        assert stager.live_buffers == 0

    def test_grid_stream_matches_host_epoch(self, tmp_path):
        """The RedoxClient-style path: pre-assembled batches, no kernel."""
        store, loader = build_loader(tmp_path)
        host = [grids(b) for b in loader.epoch(0)]
        stager = DeviceStager()
        dev = [grids(b) for b in stager.stream(loader.epoch_async(0))]
        for h, d in zip(host, dev):
            for a, b in zip(h, d):
                np.testing.assert_array_equal(a, b)
        assert stager.stats.kernel_steps == 0
        assert stager.stats.steps == len(host)

    def test_io_accounting_annotated_not_corrupted(self, tmp_path):
        store, loader = build_loader(tmp_path)
        stager = DeviceStager()
        staged = list(loader.epoch_device(0, stager))
        for b in staged:
            assert b["stage_s"] >= 0.0 and b["stage_wait_s"] >= 0.0
            io = b["io_by_node"]
            assert sum(s.stage_s for s in io.values()) == pytest.approx(
                b["stage_s"]
            )
        assert 0.0 <= stager.stats.overlap_fraction <= 1.0
        # Replay-engine plans share StepIO objects with future epochs: the
        # host-side stream must come back with stage fields untouched.
        for b in loader.epoch(1):
            for s in b["io_by_node"].values():
                assert s.stage_s == 0.0 and s.stage_wait_s == 0.0

    def test_use_kernel_false_rejects_packs(self):
        stager = DeviceStager(use_kernel=False)
        with pytest.raises(ValueError, match="cannot stage HostPacks"):
            stager.stage(HostPack(slot_tokens=np.zeros((1, 8), np.int32)))

    def test_stream_is_one_at_a_time(self, tmp_path):
        store, loader = build_loader(tmp_path)
        stager = DeviceStager()
        gen = stager.stream(loader.epoch_async(0))
        next(gen)
        with pytest.raises(RuntimeError, match="one-at-a-time"):
            next(stager.stream(iter([])))
        with pytest.raises(RuntimeError, match="stream is active"):
            stager.close()
        gen.close()
        stager.close()  # fine once torn down


class TestTeardown:
    def test_abandoned_consumer_releases_device_buffers(self, tmp_path):
        store, loader = build_loader(tmp_path, queue_depth=1)
        stager = DeviceStager(depth=1)
        gen = loader.epoch_device(0, stager)
        next(gen)
        # Let the staging thread get ahead: a staged-but-unconsumed batch
        # must exist so abandonment has something to release.
        deadline = 50
        while stager.live_buffers == 0 and deadline:
            import time

            time.sleep(0.02)
            deadline -= 1
        assert stager.live_buffers > 0
        gen.close()
        assert loader._worker is not None
        loader._worker.join(timeout=5.0)
        assert not loader._worker.is_alive(), "protocol worker leaked"
        assert stager._thread is not None
        stager._thread.join(timeout=5.0)
        assert not stager._thread.is_alive(), "staging thread leaked"
        assert stager.live_buffers == 0, "device buffers stranded"
        assert stager.stats.buffers_released >= 1

    def test_worker_error_propagates_through_stager(self, tmp_path):
        store, loader = build_loader(tmp_path)
        calls = {"n": 0}
        real = store.read_chunk

        def flaky(chunk):
            calls["n"] += 1
            if calls["n"] > 3:
                raise OSError("injected storage failure")
            return real(chunk)

        store.read_chunk = flaky
        stager = DeviceStager()
        with pytest.raises(OSError, match="injected storage failure"):
            for _ in loader.epoch_device(0, stager):
                pass
        assert stager.live_buffers == 0


class TestClientEpochDevice:
    def test_ring_stream_staged_byte_identical(self, tmp_path):
        """RedoxClient.epoch_device == the in-process host epoch, through
        the socket + shared-memory ring + DeviceStager."""
        from repro.service.service import DataService
        from repro.service.transport import DataServiceServer, RedoxClient

        ds = SyntheticTokenDataset(96, vocab_size=97, mean_len=48, seed=3)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        spec = SessionSpec(seed=5, num_nodes=2, batch_per_node=4, seq_len=32)
        host = [
            grids(b) for b in RedoxLoader.from_spec(spec, store).epoch(0)
        ]
        svc = DataService(store)
        server = DataServiceServer(svc, tmp_path / "svc.sock", poll_interval=0.001)
        server.start()
        try:
            client = RedoxClient(tmp_path / "svc.sock", spec, job_id="dev0")
            dev = [grids(b) for b in client.epoch_device(0)]
            client.close()
        finally:
            server.stop()
        assert len(dev) == len(host) > 0
        for h, d in zip(host, dev):
            for a, b in zip(h, d):
                np.testing.assert_array_equal(a, b)
