"""Elastic data plane: differential suspend/resume + scale-up tests.

The contract (DESIGN.md §10): a mid-epoch snapshot/restore — through npz +
manifest files, into a fresh cluster — at *any* boundary (every k-th step,
every k-th access, before/after elastic events) never changes anything
observable: returned streams, load/ship events, StepIO grids, NodeStats,
exactly-once. ``elastic_harness`` holds the execution modes; this file
drives the grid and the join/fail unit semantics.
"""

import tempfile
from pathlib import Path

import numpy as np
import pytest

from elastic_harness import (
    assert_streams_equal,
    make,
    record_replay,
    record_suspended,
    record_suspended_per_access,
    record_suspended_replay,
    record_uninterrupted,
)
from repro.core import Cluster, RedoxLoader
from repro.core.elastic import ClusterSnapshot

pytestmark = pytest.mark.elastic

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property test becomes a no-op; the grid below remains
    HAVE_HYPOTHESIS = False


SCENARIOS = {
    "plain": dict(failures=None, joins=None),
    # one join_node and one fail_node mid-suffix (acceptance criteria)
    "join_then_fail": dict(failures={5: 1}, joins={3: 1}),
}


class TestDifferentialSuspendResume:
    """Uninterrupted vs chopped-at-every-k, for all engines and policies."""

    @pytest.mark.parametrize("policy", ["max_fill", "random"])
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS))
    @pytest.mark.parametrize("every", [1, 3])
    def test_step_level_suspension_all_engines(
        self, tmp_path, policy, scenario, every
    ):
        kw = dict(nodes=3, policy=policy)
        ev = SCENARIOS[scenario]
        ref = record_uninterrupted(kw, 16, engine="step", **ev)
        modes = {
            "per_access": record_uninterrupted(kw, 16, engine="per_access", **ev),
            "replay": record_replay(kw, 16, **ev),
            "susp-step": record_suspended(
                kw, 16, every=every, engine="step",
                tmp_path=tmp_path / "s", **ev,
            ),
            "susp-per_access": record_suspended(
                kw, 16, every=every, engine="per_access",
                tmp_path=tmp_path / "p", **ev,
            ),
            "susp-replay": record_suspended_replay(
                kw, 16, every=every, tmp_path=tmp_path / "r", **ev,
            ),
        }
        for name, stream in modes.items():
            assert_streams_equal(ref, stream, num_files=960)

    @pytest.mark.parametrize("scenario", sorted(SCENARIOS))
    def test_access_level_suspension(self, tmp_path, scenario):
        """Suspend at every 37th *access* — mid-step, mid-node."""
        kw = dict(nodes=3)
        ev = SCENARIOS[scenario]
        ref = record_uninterrupted(kw, 16, engine="per_access", **ev)
        got = record_suspended_per_access(
            kw, 16, every=37, tmp_path=tmp_path, **ev
        )
        assert_streams_equal(ref, got, num_files=960)

    def test_variable_sizes_tight_remote_memory(self, tmp_path):
        rng = np.random.default_rng(5)
        sizes = rng.integers(40, 400, 960).astype(np.int64)
        kw = dict(nodes=3, sizes=sizes, remote_memory_limit_bytes=2_000)
        ev = SCENARIOS["join_then_fail"]
        ref = record_uninterrupted(kw, 16, engine="step", **ev)
        got = record_suspended(
            kw, 16, every=2, engine="step", tmp_path=tmp_path, **ev
        )
        assert_streams_equal(ref, got, num_files=960)

    def test_single_node_cluster(self, tmp_path):
        kw = dict(nodes=1)
        ref = record_uninterrupted(kw, 16, engine="step", joins={2: 1})
        got = record_suspended(
            kw, 16, every=2, engine="step", tmp_path=tmp_path, joins={2: 1}
        )
        assert_streams_equal(ref, got, num_files=960)


class TestJoinNode:
    def test_join_rebalances_ownership_and_tails(self):
        cluster, sampler = make(nodes=3)
        cluster.begin_epoch(sampler, 0)
        for _ in cluster.epoch_stream(sampler, 0, 16):
            break  # run one step so positions are non-trivial
        before_positions = cluster.positions.copy()
        before_total = sum(s.size for s in cluster.sequences)
        new = cluster.join_node()
        assert new == 3 and cluster.num_nodes == 4
        # position stability: existing cursors untouched, new starts at 0
        np.testing.assert_array_equal(cluster.positions[:3], before_positions)
        assert cluster.positions[3] == 0
        # the new node owns a fair share of the groups
        counts = [int((cluster.owner_of_group == r).sum()) for r in range(4)]
        assert counts[3] == cluster.plan.num_groups // 4
        # no access lost or duplicated by the tail handoff
        assert sum(s.size for s in cluster.sequences) == before_total
        # prefixes stayed intact
        full = sampler.node_sequences(0)
        for r in range(3):
            np.testing.assert_array_equal(
                cluster.sequences[r], full[r][: cluster.sequences[r].size]
            )

    def test_join_exactly_once_and_drained(self):
        for policy in ("max_fill", "random"):
            cluster, sampler = make(nodes=2, policy=policy)
            res = cluster.run_epoch(sampler, 0, 16, joins={2: 1})
            assert sorted(np.concatenate(res.returned).tolist()) == list(range(960))
            for node in cluster.nodes:
                assert node.memory.is_empty()
            for rm in cluster.remote_mem:
                assert len(rm) == 0

    def test_join_after_fail_reuses_protocol(self):
        cluster, sampler = make(nodes=3)
        res = cluster.run_epoch(sampler, 0, 16, failures={2: 0}, joins={4: 1})
        assert sorted(np.concatenate(res.returned).tolist()) == list(range(960))


class TestSnapshotFiles:
    def test_round_trip_preserves_everything(self, tmp_path):
        cluster, sampler = make(nodes=3)
        gen = cluster.epoch_stream(sampler, 0, 16)
        for i, _ in enumerate(gen):
            if i == 3:
                break
        gen.close()
        snap = cluster.snapshot()
        snap.save(tmp_path)
        loaded = ClusterSnapshot.load(tmp_path)
        assert loaded.epoch == 0 and loaded.step == 4
        assert loaded.grid == {"batch_per_node": 16, "stepping": "ceil"}
        np.testing.assert_array_equal(loaded.positions, cluster.positions)
        restored = Cluster.restore(loaded, plan=cluster.plan)
        for a, b in zip(cluster.nodes, restored.nodes):
            np.testing.assert_array_equal(a.memory.resident, b.memory.resident)
            np.testing.assert_array_equal(a.consumed, b.consumed)
            assert a.rng.bit_generator.state == b.rng.bit_generator.state
            assert a.stats == b.stats
            assert a.memory.used_bytes == b.memory.used_bytes
            assert a.memory.peak_bytes == b.memory.peak_bytes

    def test_torn_snapshot_rejected(self, tmp_path):
        """A crash between the npz and manifest overwrites must not resume
        from mixed state: load() verifies the shared per-save token."""
        import json

        cluster, sampler = make(nodes=2)
        cluster.begin_epoch(sampler, 0)
        cluster.snapshot().save(tmp_path)
        mf = json.loads((tmp_path / "data_manifest.json").read_text())
        mf["token"] = "0" * 32  # manifest from a different save() call
        (tmp_path / "data_manifest.json").write_text(json.dumps(mf))
        with pytest.raises(ValueError, match="torn snapshot"):
            ClusterSnapshot.load(tmp_path)

    def test_restore_rejects_mismatched_plan(self, tmp_path):
        cluster, sampler = make(nodes=2)
        cluster.begin_epoch(sampler, 0)
        cluster.snapshot().save(tmp_path)
        snap = ClusterSnapshot.load(tmp_path)
        other, _ = make(nodes=2, n=480, slots=32)
        with pytest.raises(ValueError, match="different ChunkingPlan"):
            Cluster.restore(snap, plan=other.plan)

    def test_snapshot_requires_epoch(self):
        cluster, _ = make(nodes=2)
        with pytest.raises(AssertionError, match="outside an epoch"):
            cluster.snapshot()


class TestLoaderSuspendResume:
    @pytest.mark.parametrize("engine", ["replay", "step", "per_access"])
    def test_resumed_batches_identical(self, tmp_path, engine):
        from repro.core import ChunkStore, EpochSampler
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(192, vocab_size=97, mean_len=48, seed=3)
        ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)

        def fresh():
            return ChunkStore.open(tmp_path / "chunks")

        sampler = EpochSampler(192, 2, seed=4)
        store = fresh()
        loader = RedoxLoader(
            Cluster(store.plan, 2, store=store, seed=2), sampler,
            batch_per_node=8, seq_len=32, engine=engine,
        )
        ref = [
            (b["step"], b["tokens"].copy(), b["returned"].copy())
            for b in loader.epoch(0)
        ]
        store.close()

        store = fresh()
        loader = RedoxLoader(
            Cluster(store.plan, 2, store=store, seed=2), sampler,
            batch_per_node=8, seq_len=32, engine=engine,
        )
        got = []
        for b in loader.epoch(0):
            got.append((b["step"], b["tokens"].copy(), b["returned"].copy()))
            if b["step"] == 2:
                break
        ck = tmp_path / "data_ck"
        loader.suspend(ck)
        store.close()

        store = fresh()  # "fresh process": only the store + the files
        loader2 = RedoxLoader.resume(ck, store)
        assert loader2.resume_point == (0, 3)
        got += [
            (b["step"], b["tokens"].copy(), b["returned"].copy())
            for b in loader2.epoch(0)
        ]
        store.close()

        assert [s for s, _, _ in ref] == [s for s, _, _ in got]
        for (_, ta, ra), (_, tb, rb) in zip(ref, got):
            np.testing.assert_array_equal(ta, tb)
            np.testing.assert_array_equal(ra, rb)

    def test_resumed_loader_rejects_other_epochs(self, tmp_path):
        """Asking a mid-epoch-resumed loader for a different epoch must be
        a clear error, not a drain-assertion crash or a dropped suffix."""
        from repro.core import ChunkStore, EpochSampler
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(96, vocab_size=97, mean_len=32, seed=3)
        ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(tmp_path / "chunks")
        loader = RedoxLoader(
            Cluster(store.plan, 1, store=store, seed=2),
            EpochSampler(96, 1, seed=4),
            batch_per_node=8, seq_len=32, engine="step",
        )
        for b in loader.epoch(0):
            break
        loader.suspend(tmp_path / "ck")
        store.close()
        store = ChunkStore.open(tmp_path / "chunks")
        loader2 = RedoxLoader.resume(tmp_path / "ck", store)
        with pytest.raises(RuntimeError, match="resumed mid-epoch 0"):
            next(loader2.epoch(1))
        store.close()

    def test_live_async_suspend_refused(self, tmp_path):
        from repro.core import ChunkStore, EpochSampler
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(96, vocab_size=97, mean_len=32, seed=3)
        ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        store = ChunkStore.open(tmp_path / "chunks")
        loader = RedoxLoader(
            Cluster(store.plan, 1, store=store, seed=2),
            EpochSampler(96, 1, seed=4),
            batch_per_node=8, seq_len=32, engine="step",
        )
        gen = loader.epoch_async(0)
        next(gen)
        gen.close()
        with pytest.raises(RuntimeError, match="epoch_async"):
            loader.suspend(tmp_path / "ck")
        store.close()


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        nodes=st.integers(1, 4),
        every=st.integers(5, 97),
        policy=st.sampled_from(["max_fill", "random"]),
        seed=st.integers(0, 1000),
        event=st.sampled_from(["none", "fail", "join", "both"]),
    )
    def test_suspend_at_random_access_property(nodes, every, policy, seed, event):
        """Suspend at a random access cadence, restore, continue — the
        stream equals the uninterrupted run, across node counts and a
        mid-suffix fail_node/join_node (satellite: elastic property)."""
        kw = dict(n=240, c=4, slots=16, nodes=nodes, seed=seed, policy=policy)
        failures = {3: nodes - 1} if event in ("fail", "both") and nodes > 1 else None
        joins = {2: 1} if event in ("join", "both") else None
        ref = record_uninterrupted(
            kw, 8, engine="per_access", failures=failures, joins=joins
        )
        with tempfile.TemporaryDirectory() as d:
            got = record_suspended_per_access(
                kw, 8, every=every, tmp_path=Path(d),
                failures=failures, joins=joins,
            )
        assert_streams_equal(ref, got, num_files=240)
