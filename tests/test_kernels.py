"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.chunk_gather.ops import chunk_gather
from repro.kernels.chunk_gather.ref import chunk_gather_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_attention_gqa
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,d", [(4, 256, 64), (2, 128, 32), (1, 512, 128), (3, 192, 64)])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, bh, s, d, causal, dtype):
        q, k, v = (jnp.asarray(RNG.normal(size=(bh, s, d)), dtype) for _ in range(3))
        bq = min(64, s)
        out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bq)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=_tol(dtype), rtol=_tol(dtype),
        )

    @pytest.mark.parametrize("window", [32, 96, 1024])
    def test_sliding_window(self, window):
        bh, s, d = 2, 256, 64
        q, k, v = (jnp.asarray(RNG.normal(size=(bh, s, d)), jnp.float32) for _ in range(3))
        out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    def test_block_shape_independence(self):
        bh, s, d = 2, 256, 64
        q, k, v = (jnp.asarray(RNG.normal(size=(bh, s, d)), jnp.float32) for _ in range(3))
        outs = [
            flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5, rtol=1e-5)

    def test_gqa_wrapper(self):
        b, s, h, kvh, d = 2, 128, 8, 2, 32
        q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        out = flash_attention_gqa(q, k, v, block_q=64, block_k=64)
        assert out.shape == (b, s, h, d)
        assert np.isfinite(np.asarray(out, np.float32)).all()


class TestDecodeAttention:
    @pytest.mark.parametrize("b,h,kvh,s,d", [(2, 8, 2, 512, 64), (1, 4, 4, 256, 32), (3, 16, 4, 1024, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, kvh, s, d, dtype):
        q = jnp.asarray(RNG.normal(size=(b, h, d)), dtype)
        ck = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), dtype)
        cv = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), dtype)
        mask = jnp.asarray(RNG.random((b, s)) < 0.75)
        out = decode_attention(q, ck, cv, mask, block_k=128)
        g = h // kvh
        qg = q.reshape(b * kvh, g, d)
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
        m = jnp.repeat(mask[:, None, :], kvh, 1).reshape(b * kvh, s)
        ref = decode_attention_ref(qg, fold(ck), fold(cv), m).reshape(b, h, d)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=_tol(dtype), rtol=_tol(dtype),
        )

    def test_ring_buffer_mask(self):
        """Rotating-window cache = arbitrary validity pattern; exactness."""
        b, h, kvh, s, d = 1, 4, 2, 256, 64
        q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
        ck = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        cv = jnp.asarray(RNG.normal(size=(b, s, kvh, d)), jnp.float32)
        # only slots [64:128) valid, as after ring wrap-around
        mask = jnp.zeros((b, s), bool).at[:, 64:128].set(True)
        out = decode_attention(q, ck, cv, mask, block_k=64)
        qg = q.reshape(b * kvh, h // kvh, d)
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
        m = jnp.repeat(mask[:, None, :], kvh, 1).reshape(b * kvh, s)
        ref = decode_attention_ref(qg, fold(ck), fold(cv), m).reshape(b, h, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


class TestChunkGather:
    @pytest.mark.parametrize("slots,L,B", [(64, 128, 16), (32, 256, 8), (16, 64, 32), (128, 512, 4)])
    def test_exact(self, slots, L, B):
        ct = jnp.asarray(RNG.integers(1, 1000, (slots, L)), jnp.int32)
        lens = jnp.asarray(RNG.integers(1, L + 1, (slots,)), jnp.int32)
        idx = jnp.asarray(RNG.integers(0, slots, (B,)), jnp.int32)
        t, m = chunk_gather(ct, lens, idx)
        tr, mr = chunk_gather_ref(ct, lens, idx)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(tr))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))

    def test_duplicate_indices(self):
        """Redirection may serve the same slot to multiple rows in a step."""
        ct = jnp.asarray(RNG.integers(1, 100, (8, 32)), jnp.int32)
        lens = jnp.full((8,), 32, jnp.int32)
        idx = jnp.asarray([3, 3, 3, 0], jnp.int32)
        t, _ = chunk_gather(ct, lens, idx)
        np.testing.assert_array_equal(np.asarray(t[0]), np.asarray(t[1]))
        np.testing.assert_array_equal(np.asarray(t[0]), np.asarray(ct[3]))


class TestSSDScan:
    @pytest.mark.parametrize("bh,s,p,n,chunk", [(4, 256, 64, 16, 64), (2, 128, 32, 32, 32), (1, 512, 64, 64, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_ref(self, bh, s, p, n, chunk, dtype):
        x = jnp.asarray(RNG.normal(size=(bh, s, p)), dtype)
        dt = jnp.asarray(RNG.random((bh, s)) * 0.5 + 0.01, jnp.float32)
        a = jnp.asarray(-RNG.random((bh, 1)) * 2 - 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(bh, s, n)), dtype)
        c = jnp.asarray(RNG.normal(size=(bh, s, n)), dtype)
        out = ssd_scan(x, dt, a, b, c, chunk=chunk)
        ref = ssd_scan_ref(x, dt, a, b, c)
        scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
        assert err / scale < (5e-2 if dtype == jnp.bfloat16 else 2e-4), err / scale

    def test_chunk_size_independence(self):
        bh, s, p, n = 2, 256, 32, 16
        x = jnp.asarray(RNG.normal(size=(bh, s, p)), jnp.float32)
        dt = jnp.asarray(RNG.random((bh, s)) * 0.3 + 0.01, jnp.float32)
        a = jnp.asarray(-RNG.random((bh, 1)) - 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(bh, s, n)), jnp.float32)
        c = jnp.asarray(RNG.normal(size=(bh, s, n)), jnp.float32)
        outs = [np.asarray(ssd_scan(x, dt, a, b, c, chunk=cs)) for cs in (32, 64, 128, 256)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-4)
