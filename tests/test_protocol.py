"""Unit tests for the single-node Redox protocol (paper §3.2/§3.3)."""

import numpy as np
import pytest

from repro.core import ChunkingPlan, EpochSampler, LocalNode


def make_plan(n=96, c=4, slots=8, seed=0, sizes=None):
    sizes = np.full(n, 100, dtype=np.int64) if sizes is None else sizes
    return ChunkingPlan.create(sizes, c, num_slots=slots, seed=seed)


class TestChunkingPlan:
    def test_basic_shape(self):
        plan = make_plan(n=96, c=4, slots=8)
        assert plan.num_chunks == 24
        assert plan.num_groups == 2
        assert plan.group_width == 12
        assert plan.num_slots == 8

    def test_every_file_mapped_once(self):
        plan = make_plan(n=97, c=4, slots=8)  # partial last chunk
        flat = plan.chunk_files.reshape(-1)
        members = flat[flat >= 0]
        assert sorted(members.tolist()) == list(range(97))

    def test_inverse_maps_consistent(self):
        plan = make_plan(n=97, c=4, slots=8)
        for f in range(97):
            k, s = int(plan.chunk_of[f]), int(plan.slot_of[f])
            assert plan.chunk_files[k, s] == f

    def test_group_ranges_cover_chunks(self):
        plan = make_plan(n=200, c=8, slots=24)
        seen = []
        for g in range(plan.num_groups):
            a, b = plan.group_chunk_range(g)
            seen.extend(range(a, b))
        assert seen == list(range(plan.num_chunks))

    def test_save_load_roundtrip(self, tmp_path):
        plan = make_plan(n=50, c=4, slots=8)
        plan.save(tmp_path / "plan.npz")
        back = ChunkingPlan.load(tmp_path / "plan.npz")
        np.testing.assert_array_equal(plan.chunk_files, back.chunk_files)
        assert back.chunk_size == plan.chunk_size

    def test_memory_bytes_sizing(self):
        sizes = np.full(1000, 200, dtype=np.int64)
        plan = ChunkingPlan.create(sizes, 10, memory_bytes=20_000)
        # M = C / mean = 100 slots -> 10 abstract chunks
        assert plan.num_slots == 100
        assert plan.num_groups == 10


class TestLocalProtocol:
    def test_exactly_once_per_epoch(self):
        plan = make_plan(n=96, c=4, slots=8)
        node = LocalNode(plan, seed=1)
        sampler = EpochSampler(96, 1, seed=5)
        for epoch in range(3):
            node.begin_epoch()
            seq = sampler.global_sequence(epoch)
            returned = [node.request(int(f)).file_id for f in seq]
            assert sorted(returned) == list(range(96)), "exactly-once violated"
            assert node.epoch_complete()

    def test_redirection_preserves_slot(self):
        plan = make_plan(n=96, c=4, slots=8)
        node = LocalNode(plan, seed=2)
        node.begin_epoch()
        seq = EpochSampler(96, 1, seed=9).global_sequence(0)
        for f in seq:
            res = node.request(int(f))
            # the returned file must be mapped to the same abstract location
            assert plan.location_of_file(res.file_id) == plan.location_of_file(
                res.requested
            )

    def test_miss_then_hits_within_chunk(self):
        # After a cold miss fills a whole chunk, sibling slots should hit.
        plan = make_plan(n=32, c=4, slots=4, seed=3)  # one group of 8 chunks
        node = LocalNode(plan, seed=3)
        node.begin_epoch()
        first = node.request(0)
        assert not first.hit and first.chunk_loaded is not None
        # The other three slots of the abstract chunk are now resident.
        hits = 0
        for f in range(1, 32):
            if plan.slot_of[f] != plan.slot_of[0]:
                res = node.request(int(f))
                hits += res.hit
                break
        assert hits == 1

    def test_never_evict_invariant(self):
        # AbstractMemory.fill asserts on overwrite; a full epoch exercising
        # many refills must not trip it.
        plan = make_plan(n=240, c=6, slots=12, seed=4)
        node = LocalNode(plan, seed=4)
        node.begin_epoch()
        seq = EpochSampler(240, 1, seed=11).global_sequence(0)
        for f in seq:
            node.request(int(f))
        assert node.epoch_complete()

    def test_fill_rate_policy_beats_random_on_waste(self):
        sizes = np.full(4096, 1000, dtype=np.int64)
        plan = ChunkingPlan.create(sizes, 16, num_slots=256, seed=7)
        sampler = EpochSampler(4096, 1, seed=13)
        waste = {}
        for policy in ("max_fill", "random"):
            node = LocalNode(plan, policy=policy, seed=21)
            node.begin_epoch()
            for f in sampler.global_sequence(0):
                node.request(int(f))
            waste[policy] = node.stats.wasted_bytes
        # Paper §3.3/Table 5: fill-rate-maximising selection wastes less.
        assert waste["max_fill"] < waste["random"]

    def test_first_fill_rate_is_one(self):
        plan = make_plan(n=64, c=4, slots=8, seed=8)
        node = LocalNode(plan, seed=8)
        node.begin_epoch()
        res = node.request(0)
        assert res.fill_rate == 1.0  # empty abstract chunk, fresh chunk

    def test_byte_accounting_zero_at_epoch_end(self):
        sizes = np.random.default_rng(0).integers(50, 500, 128).astype(np.int64)
        plan = ChunkingPlan.create(sizes, 4, num_slots=16, seed=9)
        node = LocalNode(plan, seed=9)
        node.begin_epoch()
        for f in EpochSampler(128, 1, seed=17).global_sequence(0):
            node.request(int(f))
        assert node.memory.used_bytes == 0
        assert node.stats.peak_local_bytes > 0

    def test_disk_bytes_equals_filled_plus_wasted(self):
        plan = make_plan(n=96, c=4, slots=8, seed=10)
        node = LocalNode(plan, seed=10)
        node.begin_epoch()
        for f in EpochSampler(96, 1, seed=19).global_sequence(0):
            node.request(int(f))
        s = node.stats
        assert s.disk_bytes == s.filled_bytes + s.wasted_bytes
        # every file's bytes land in memory exactly once
        assert s.filled_bytes == plan.file_sizes.sum()

    def test_epoch_reset_requires_drained_memory(self):
        plan = make_plan(n=32, c=4, slots=4)
        node = LocalNode(plan, seed=0)
        node.begin_epoch()
        node.request(0)  # loads a chunk, leaves residents behind
        with pytest.raises(AssertionError):
            node.begin_epoch()
