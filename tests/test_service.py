"""Multi-job data service: equivalence, shared residency, fault tolerance.

The service contract under test:

* a single-session :class:`DataService` run is byte-identical (returned
  ids, batches, load/ship events, StepIO counters) to a plain
  ``RedoxLoader`` run with the same seed/policy — for the ``per_access``,
  ``step``, and ``replay`` engines;
* K co-scheduled jobs read strictly fewer bytes than K independent
  loaders (shared residency actually deduplicates);
* killing one job mid-epoch leaves every other session's stream
  byte-identical to its solo run, and the shared cache drains.
"""

import numpy as np
import pytest

# Comparison helpers come from the elastic differential harness (the
# template for all equivalence tests — see tests/elastic_harness.py).
from elastic_harness import assert_node_stats_equal, io_key
from repro.core import ChunkStore, Cluster, EpochSampler, ParallelBackend, RedoxLoader
from repro.core.planner import PlanRecorder
from repro.data import SyntheticTokenDataset
from repro.ft.failures import FailureInjector, StragglerMonitor
from repro.service import DataService

pytestmark = pytest.mark.service

NUM_DOCS = 192  # divisible by batch 16: no ragged tail, batches cover the epoch


def build_store(tmp_path, name="chunks", backend="vfs"):
    ds = SyntheticTokenDataset(NUM_DOCS, vocab_size=97, mean_len=48, seed=3)
    store = ds.build_store(tmp_path / name, 4, num_slots=16, seed=1)
    return ChunkStore.open(store.root, backend=backend)


def plain_run(store, *, seed, sampler_seed, engine, nodes=1, batch=16):
    cluster = Cluster(store.plan, nodes, store=store, seed=seed)
    sampler = EpochSampler(NUM_DOCS, nodes, seed=sampler_seed)
    loader = RedoxLoader(cluster, sampler, batch_per_node=batch, seq_len=32,
                         engine=engine)
    recorder = PlanRecorder() if engine != "replay" else None
    batches = list(loader.epoch(0)) if recorder is None else None
    if recorder is not None:
        # live engines: capture load/ship events through the epoch recorder
        stream = cluster.epoch_stream(
            sampler, 0, batch, stepping="floor_tail", engine=engine,
            collect_payloads=True, recorder=recorder,
        )
        batches = []
        for step, returned, payloads, io_by_node in stream:
            batches.append(loader._assemble(payloads, step, io_by_node, returned))
    return cluster, loader, batches, recorder


def assert_io_equal(a, b):
    """StepIO dicts equal on every exact counter (read_wait_s is measured)."""
    assert a.keys() == b.keys()
    for r in a:
        assert io_key(a[r]) == io_key(b[r]), r


class TestSingleSessionEquivalence:
    @pytest.mark.parametrize("engine", ["replay", "step", "per_access"])
    def test_byte_identical_to_plain_loader(self, tmp_path, engine):
        store_a = build_store(tmp_path, "a")
        _, plain_loader, plain_batches, _ = plain_run(
            store_a, seed=2, sampler_seed=4, engine=engine
        )

        store_b = build_store(tmp_path, "b")
        svc = DataService(store_b)
        session = svc.open_session(
            "solo", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32,
            engine=engine,
        )
        svc.plan_epoch(0)
        svc_batches = list(session.epoch(0))

        assert len(plain_batches) == len(svc_batches)
        for pb, sb in zip(plain_batches, svc_batches):
            np.testing.assert_array_equal(pb["tokens"], sb["tokens"])
            np.testing.assert_array_equal(pb["loss_mask"], sb["loss_mask"])
            np.testing.assert_array_equal(pb["returned"], sb["returned"])
            assert_io_equal(pb["io_by_node"], sb["io_by_node"])
        if engine == "replay":
            pa, pb = plain_loader.last_plan, session.last_plan
            np.testing.assert_array_equal(pa.load_chunk, pb.load_chunk)
            np.testing.assert_array_equal(pa.load_fill_rate, pb.load_fill_rate)
            np.testing.assert_array_equal(pa.load_files_flat, pb.load_files_flat)
            np.testing.assert_array_equal(pa.ship_file, pb.ship_file)
            np.testing.assert_array_equal(pa.io_grid, pb.io_grid)

    def test_solo_co_refill_is_a_no_op(self, tmp_path):
        """The co-refill preference only ever narrows toward chunks some
        OTHER session needs, so a solo session with co_refill=True stays
        byte-identical to its solo run (no self-history bias)."""
        store_a = build_store(tmp_path, "a")
        _, _, plain_batches, _ = plain_run(
            store_a, seed=2, sampler_seed=4, engine="step"
        )
        store_b = build_store(tmp_path, "b")
        svc = DataService(store_b, co_refill=True)
        session = svc.open_session(
            "solo", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32,
            engine="step",
        )
        svc_batches = list(session.epoch(0))
        for pb, sb in zip(plain_batches, svc_batches):
            np.testing.assert_array_equal(pb["returned"], sb["returned"])
        assert session.stats.co_refill_hits == 0

    @pytest.mark.parametrize("engine", ["step", "per_access"])
    def test_live_event_stream_identical(self, tmp_path, engine):
        """Load/ship events of a multi-node live session match the plain
        cluster walk exactly (the recorder-level view of 'byte-identical')."""
        store_a = build_store(tmp_path, "a")
        c_a, _, _, rec_a = plain_run(
            store_a, seed=2, sampler_seed=4, engine=engine, nodes=2, batch=8
        )
        store_b = build_store(tmp_path, "b")
        svc = DataService(store_b)
        session = svc.open_session(
            "solo", seed=2, sampler_seed=4, num_nodes=2, batch_per_node=8,
            seq_len=32, engine=engine,
        )
        rec_b = PlanRecorder()
        stream = session.cluster.epoch_stream(
            session.sampler, 0, 8, stepping="floor_tail", engine=engine,
            collect_payloads=True, recorder=rec_b,
        )
        for _ in stream:
            pass
        assert rec_a.load_chunk == rec_b.load_chunk
        assert rec_a.load_step == rec_b.load_step
        assert rec_a.ship_file == rec_b.ship_file
        assert rec_a.ship_loc == rec_b.ship_loc
        assert_node_stats_equal(
            [n.stats for n in c_a.nodes], [n.stats for n in session.cluster.nodes]
        )


class TestSharedResidency:
    def test_sequential_sessions_share_bytes(self, tmp_path):
        """Independently consumed sessions share bytes with no explicit
        plan_epoch call (the service plans on first touch): job B's whole
        epoch is served from job A's physical reads."""
        store = build_store(tmp_path)
        svc = DataService(store)
        a = svc.open_session("a", seed=2, batch_per_node=16, seq_len=32)
        b = svc.open_session("b", seed=9, batch_per_node=16, seq_len=32)
        for _ in a.epoch(0):
            pass
        for _ in b.epoch(0):
            pass
        svc.residency.end_epoch()
        assert a.stats.physical_reads > 0
        assert b.stats.physical_reads == 0  # fully served from the cache
        assert b.stats.shared_hits > 0
        assert svc.residency.cache_bytes == 0  # refcounts drained

    @pytest.mark.parametrize("bail_at", [0, 5])
    def test_abandoned_pump_rerun_is_clean(self, tmp_path, bail_at):
        """Breaking out of co_epoch mid-epoch must not leave claims behind —
        neither partially drained pools (bail mid-round) nor plan-time pools
        of sessions whose generator never even started (bail at the first
        batch) — and the re-run still deduplicates down to one physical
        read per chunk."""
        store = build_store(tmp_path)
        svc = DataService(store)
        for j in range(2):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32)
        for i, _ in enumerate(svc.co_epoch(0)):
            if i == bail_at:
                break  # consumer bails mid-epoch
        assert not svc.residency.has_claims()
        assert svc.residency.cache_bytes == 0  # nothing left pinned
        before = store.backend_stats.chunk_reads
        for _ in svc.co_epoch(0):
            pass
        reads = store.backend_stats.chunk_reads - before
        assert reads == store.plan.num_chunks  # one physical read per chunk
        assert svc.residency.cache_bytes == 0

    @pytest.mark.parametrize("co_refill", [False, True])
    def test_pump_dedupes_and_stays_exactly_once(self, tmp_path, co_refill):
        single = build_store(tmp_path, "single")
        _, _, batches, _ = plain_run(single, seed=107, sampler_seed=108, engine="replay")
        single_bytes = single.backend_stats.bytes_read
        assert single_bytes > 0

        store = build_store(tmp_path, "svc")
        svc = DataService(store, co_refill=co_refill)
        jobs = 3
        for j in range(jobs):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32)
        returned = {f"j{j}": [] for j in range(jobs)}
        for job_id, batch in svc.co_epoch(0):
            returned[job_id].append(batch["returned"])
        for job_id, chunks in returned.items():
            ids = np.concatenate(chunks)
            assert sorted(ids.tolist()) == list(range(NUM_DOCS)), job_id
        agg = svc.aggregate_stats()
        assert agg.dup_loads_avoided > 0
        # the acceptance bound: K co-scheduled jobs strictly below K x solo
        assert store.backend_stats.bytes_read < jobs * single_bytes
        if co_refill:
            assert agg.co_refill_hits > 0
        assert svc.residency.cache_bytes == 0

    def test_merged_schedule_drives_backend_readahead(self, tmp_path):
        """plan_epoch's merged physical schedule makes every parallel-backend
        read a scheduled hit — clairvoyance survives multi-tenancy."""
        store = build_store(tmp_path, backend=ParallelBackend(workers=2))
        svc = DataService(store)
        for j in range(3):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32)
        for _ in svc.co_epoch(0):
            pass
        b = store.backend_stats
        assert b.chunk_reads > 0
        assert b.scheduled_hits == b.chunk_reads
        store.close()

    def test_cache_limit_evicts_but_streams_survive(self, tmp_path):
        store = build_store(tmp_path)
        limit = int(store.plan.chunk_bytes.max()) * 3
        svc = DataService(store, cache_limit_bytes=limit)
        for j in range(2):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32)
        returned = {f"j{j}": [] for j in range(2)}
        for job_id, batch in svc.co_epoch(0):
            returned[job_id].append(batch["returned"])
        for job_id, chunks in returned.items():
            ids = np.concatenate(chunks)
            assert sorted(ids.tolist()) == list(range(NUM_DOCS)), job_id
        assert svc.residency.peak_cache_bytes <= limit
        assert svc.residency.evictions > 0

    def test_concurrent_threads_share_and_stay_clean(self, tmp_path):
        """Two sessions consumed from separate threads (epoch_async): claim
        pools are installed/unwound under the service lock, so refcounts
        stay exact — each chunk is read physically once, streams stay
        exactly-once, and nothing is left pinned."""
        import threading

        store = build_store(tmp_path)
        svc = DataService(store)
        sessions = [
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16,
                             seq_len=32)
            for j in range(2)
        ]
        returned = {s.job_id: [] for s in sessions}

        def consume(s):
            for batch in s.epoch_async(0):
                returned[s.job_id].append(batch["returned"])

        threads = [threading.Thread(target=consume, args=(s,)) for s in sessions]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for job_id, chunks in returned.items():
            ids = np.concatenate(chunks)
            assert sorted(ids.tolist()) == list(range(NUM_DOCS)), job_id
        svc.residency.end_epoch()
        assert not svc.residency.has_claims()
        assert svc.residency.cache_bytes == 0
        assert store.backend_stats.chunk_reads == store.plan.num_chunks

    def test_sessions_at_different_epochs_stay_exact(self, tmp_path):
        """Claim pools are keyed per (job, epoch): a job mid-epoch-0 is not
        disturbed when another job plans/runs epoch 1, and cross-epoch
        retention lets the straggler's later epoch ride the fast job's
        reads (chunk bytes are epoch-invariant)."""
        store = build_store(tmp_path)
        svc = DataService(store)
        a = svc.open_session("a", seed=2, batch_per_node=16, seq_len=32)
        b = svc.open_session("b", seed=9, batch_per_node=16, seq_len=32)
        gen_a = a.epoch(0)
        ids_a0 = [next(gen_a)["returned"] for _ in range(4)]  # a is mid-epoch 0
        ids_b1 = [batch["returned"] for batch in b.epoch(1)]  # b runs epoch 1
        ids_a0 += [batch["returned"] for batch in gen_a]      # a finishes 0
        before = a.stats.physical_reads
        ids_a1 = [batch["returned"] for batch in a.epoch(1)]  # a catches up
        for ids in (ids_a0, ids_b1, ids_a1):
            assert sorted(np.concatenate(ids).tolist()) == list(range(NUM_DOCS))
        # a's epoch 1 was fully served from bytes pinned by its own planned
        # claims since b's epoch-1 plan ran — zero new physical reads
        assert a.stats.physical_reads == before
        svc.residency.end_epoch()
        assert not svc.residency.has_claims()
        assert svc.residency.cache_bytes == 0

    def test_plan_ahead_epochs_keep_cross_epoch_sharing(self, tmp_path):
        """Epochs planned ahead of consumption keep their claim pools:
        starting epoch 0 must not unwind the (job, epoch 1) refs, so epoch
        1 is served entirely from bytes epoch 0 already read."""
        store = build_store(tmp_path)
        svc = DataService(store)
        s = svc.open_session("a", seed=2, batch_per_node=16, seq_len=32)
        svc.plan_epoch(0)
        svc.plan_epoch(1)
        for _ in s.epoch(0):
            pass
        before = s.stats.physical_reads
        for _ in s.epoch(1):
            pass
        assert s.stats.physical_reads == before  # epoch 1 fully shared
        svc.residency.end_epoch()
        assert not svc.residency.has_claims()
        assert svc.residency.cache_bytes == 0

    def test_duplicate_job_id_rejected_until_closed(self, tmp_path):
        store = build_store(tmp_path)
        svc = DataService(store)
        svc.open_session("a", batch_per_node=16, seq_len=32)
        with pytest.raises(ValueError, match="already has an open session"):
            svc.open_session("a", batch_per_node=16, seq_len=32)
        # a restarted job reopens under the same id with fresh state
        svc.close_session("a")
        again = svc.open_session("a", seed=5, batch_per_node=16, seq_len=32)
        n = sum(1 for _ in again.epoch(0))
        assert n == again.steps_per_epoch()


class TestServiceFaultTolerance:
    def test_kill_job_mid_epoch_survivors_byte_identical(self, tmp_path):
        """FailureInjector kills one job mid-epoch through the live pump;
        the survivors' streams must equal their solo runs, and the victim's
        outstanding claims must not pin the shared cache."""
        solo = {}
        for j in range(3):
            store = build_store(tmp_path, f"solo{j}")
            _, _, batches, _ = plain_run(
                store, seed=100 + 7 * j, sampler_seed=100 + 7 * j + 1, engine="step"
            )
            solo[f"j{j}"] = [b["returned"] for b in batches]

        store = build_store(tmp_path, "svc")
        svc = DataService(store)
        for j in range(3):
            svc.open_session(
                f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32,
                engine="step",
            )
        injector = FailureInjector({4: 1})  # job j1 dies at its step-4 batch
        monitor = StragglerMonitor(num_workers=3, threshold=2.0)
        got = {f"j{j}": [] for j in range(3)}
        for job_id, batch in svc.co_epoch(0):
            got[job_id].append(batch["returned"])
            monitor.record(int(job_id[1:]), 0.050 if job_id == "j2" else 0.001)
            dead = injector.maybe_fail(batch["step"])
            if dead is not None and job_id == f"j{dead}":
                svc.close_session(job_id)
        assert len(got["j1"]) == 5  # steps 0..4, then killed
        for job_id in ("j0", "j2"):
            assert len(got[job_id]) == len(solo[job_id])
            for a, b in zip(solo[job_id], got[job_id]):
                np.testing.assert_array_equal(a, b)
        # the per-job step timings fed through the pump flag the slow job
        assert monitor.stragglers() == [2]
        # dead session's claims were unwound: nothing left pinned
        assert svc.residency.cache_bytes == 0
        assert len(svc.sessions) == 2

    def test_kill_planned_job_unwinds_claims(self, tmp_path):
        """Replay engine: the victim's *planned* claim refcounts are dropped,
        so retained chunks do not leak after the epoch."""
        store = build_store(tmp_path)
        svc = DataService(store)
        for j in range(2):
            svc.open_session(f"j{j}", seed=100 + 7 * j, batch_per_node=16, seq_len=32)
        seen = 0
        for job_id, batch in svc.co_epoch(0):
            seen += 1
            if job_id == "j1" and batch["step"] == 2:
                svc.close_session("j1")
        assert seen > 0
        assert svc.residency.cache_bytes == 0


@pytest.mark.elastic
class TestServiceSuspendResume:
    """The whole service — all sessions + residency claims — suspends to
    files and resumes in a fresh process with byte-identical pump output
    (elastic harness contract applied at the service layer)."""

    @pytest.mark.parametrize("engines", [
        ("replay", "replay"), ("step", "replay"), ("per_access", "step"),
    ])
    def test_resumed_pump_byte_identical(self, tmp_path, engines):
        def open_svc(name):
            store = build_store(tmp_path, name)
            svc = DataService(store)
            for j, eng in enumerate(engines):
                svc.open_session(
                    f"job{j}", seed=2 + 10 * j, batch_per_node=16,
                    seq_len=32, engine=eng,
                )
            return store, svc

        store, svc = open_svc("a")
        ref = [(j, b["step"], b["returned"].copy()) for j, b in svc.co_epoch(0)]
        svc.close()
        store.close()

        store, svc = open_svc("b")
        got = []
        pump = svc.co_epoch(0)
        for j, b in pump:
            got.append((j, b["step"], b["returned"].copy()))
            if len(got) == 5:  # mid-round: job0 is one step ahead of job1
                break
        pump.close()
        ck = tmp_path / "svc_ck"
        svc.suspend(ck)
        svc.close()
        store.close()

        store = ChunkStore.open(tmp_path / "b")  # fresh process: files only
        svc2 = DataService.resume(ck, store)
        got += [(j, b["step"], b["returned"].copy()) for j, b in svc2.co_epoch(0)]
        # resumed claims were exactly the remaining reads: drained to zero
        assert not svc2.residency.has_claims()
        assert svc2.residency.cache_bytes == 0
        svc2.close()
        store.close()

        assert [(j, s) for j, s, _ in ref] == [(j, s) for j, s, _ in got]
        for (_, _, ra), (_, _, rb) in zip(ref, got):
            np.testing.assert_array_equal(ra, rb)

    def test_suspend_before_every_session_pumped(self, tmp_path):
        """Regression: suspending after the pump served only the first
        session must checkpoint the never-advanced ones too (at their
        step-0 / resume cursor), not crash on a missing progress cursor."""
        store = build_store(tmp_path)
        svc = DataService(store)
        for j, eng in enumerate(("replay", "step")):
            svc.open_session(
                f"job{j}", seed=2 + 10 * j, batch_per_node=16, seq_len=32,
                engine=eng,
            )
        ref_store = build_store(tmp_path, "ref")
        ref_svc = DataService(ref_store)
        for j, eng in enumerate(("replay", "step")):
            ref_svc.open_session(
                f"job{j}", seed=2 + 10 * j, batch_per_node=16, seq_len=32,
                engine=eng,
            )
        ref = [(j, b["step"], b["returned"].copy()) for j, b in ref_svc.co_epoch(0)]
        ref_svc.close()
        ref_store.close()

        pump = svc.co_epoch(0)
        got = [next(pump)]  # only job0 ever pumped
        got = [(j, b["step"], b["returned"].copy()) for j, b in got]
        pump.close()
        svc.suspend(tmp_path / "ck")
        svc.close()
        store.close()

        store = ChunkStore.open(tmp_path / "chunks")
        svc2 = DataService.resume(tmp_path / "ck", store)
        got += [(j, b["step"], b["returned"].copy()) for j, b in svc2.co_epoch(0)]
        svc2.close()
        store.close()
        assert [(j, s) for j, s, _ in ref] == [(j, s) for j, s, _ in got]
        for (_, _, ra), (_, _, rb) in zip(ref, got):
            np.testing.assert_array_equal(ra, rb)

    def test_co_refill_replay_suspend_refused(self, tmp_path):
        """A co_refill service with replay sessions must refuse to suspend
        (derived snapshots would diverge from the jointly-planned prefix)."""
        store = build_store(tmp_path)
        svc = DataService(store, co_refill=True)
        for j in range(2):
            svc.open_session(f"job{j}", seed=2 + j, batch_per_node=16, seq_len=32)
        pump = svc.co_epoch(0)
        next(pump)
        pump.close()
        with pytest.raises(NotImplementedError, match="co_refill"):
            svc.suspend(tmp_path / "ck")
        svc.close()
        store.close()

    def test_resumed_sessions_share_remaining_bytes(self, tmp_path):
        """Two resumed replay jobs with the same access pattern still dedup
        their *remaining* reads through the shared residency."""
        store = build_store(tmp_path)
        svc = DataService(store)
        for j in range(2):
            svc.open_session(
                f"job{j}", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32
            )
        pump = svc.co_epoch(0)
        for i, _ in enumerate(pump):
            if i == 3:
                break
        pump.close()
        ck = tmp_path / "ck"
        svc.suspend(ck)
        svc.close()
        store.close()

        store = ChunkStore.open(tmp_path / "chunks")
        svc2 = DataService.resume(ck, store)
        for _ in svc2.co_epoch(0):
            pass
        agg = svc2.aggregate_stats()
        assert agg.shared_hits > 0  # identical-pattern jobs kept sharing
        svc2.close()
        store.close()


class TestSessionSpecAPI:
    """SessionSpec is THE session-describing object; the legacy kwarg
    spelling (and the use_planner alias) are deprecation shims that must
    build byte-identical sessions."""

    SPEC = None  # set in _specs

    def _returned(self, tmp_path, name, open_with):
        store = build_store(tmp_path, name)
        svc = DataService(store)
        session = open_with(svc)
        out = [b["returned"].copy() for b in session.epoch(0)]
        svc.close()
        store.close()
        return out

    def test_spec_equals_kwargs_equals_use_planner(self, tmp_path):
        from repro.core import SessionSpec

        spec = SessionSpec(seed=2, sampler_seed=4, batch_per_node=16, seq_len=32)
        via_spec = self._returned(
            tmp_path, "a", lambda svc: svc.open_session("j", spec)
        )
        via_kwargs = self._returned(
            tmp_path, "b",
            lambda svc: svc.open_session(
                "j", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32,
                engine="replay",
            ),
        )
        via_alias = self._returned(
            tmp_path, "c",
            lambda svc: svc.open_session(
                "j", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32,
                use_planner=True,
            ),
        )
        assert len(via_spec) == len(via_kwargs) == len(via_alias)
        for a, b, c in zip(via_spec, via_kwargs, via_alias):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)

    def test_use_planner_false_is_step_engine(self, tmp_path):
        from repro.core import SessionSpec

        via_alias = self._returned(
            tmp_path, "a",
            lambda svc: svc.open_session(
                "j", seed=2, sampler_seed=4, batch_per_node=16, seq_len=32,
                use_planner=False,
            ),
        )
        via_spec = self._returned(
            tmp_path, "b",
            lambda svc: svc.open_session(
                "j",
                SessionSpec(seed=2, sampler_seed=4, batch_per_node=16,
                            seq_len=32, engine="step"),
            ),
        )
        for a, b in zip(via_alias, via_spec):
            np.testing.assert_array_equal(a, b)

    def test_loader_from_spec_matches_manual_stack(self, tmp_path):
        """RedoxLoader.from_spec == hand-built Cluster/EpochSampler/loader,
        and loader.spec round-trips what from_spec installed."""
        from repro.core import SessionSpec

        spec = SessionSpec(seed=2, sampler_seed=4, batch_per_node=16, seq_len=32)
        store_a = build_store(tmp_path, "a")
        _, _, plain_batches, _ = plain_run(
            store_a, seed=2, sampler_seed=4, engine="replay"
        )
        store_b = build_store(tmp_path, "b")
        loader = RedoxLoader.from_spec(spec, store_b)
        assert loader.spec == spec
        for pb, sb in zip(plain_batches, loader.epoch(0)):
            np.testing.assert_array_equal(pb["returned"], sb["returned"])
            np.testing.assert_array_equal(pb["tokens"], sb["tokens"])
        store_a.close()
        store_b.close()

    def test_spec_json_roundtrip(self):
        from repro.core import SessionSpec

        spec = SessionSpec(policy="random", seed=9, engine="per_access",
                           queue_depth=5)
        assert SessionSpec.from_json(spec.to_json()) == spec
        import json as _json
        assert SessionSpec.from_json(
            _json.loads(_json.dumps(spec.to_json()))
        ) == spec  # survives an actual wire hop

    def test_spec_rejects_unknown_and_invalid(self):
        from repro.core import SessionSpec

        with pytest.raises(ValueError, match="unknown SessionSpec fields"):
            SessionSpec.from_json({"bacth_per_node": 8})  # typo'd knob
        with pytest.raises(ValueError, match="unknown engine"):
            SessionSpec(engine="warp")
        with pytest.raises(ValueError, match="must be positive"):
            SessionSpec(num_nodes=0)
        with pytest.raises(ValueError, match="not both"):
            SessionSpec.from_kwargs(use_planner=True, engine="step")

    def test_open_session_rejects_spec_plus_kwargs(self, tmp_path):
        from repro.core import SessionSpec

        store = build_store(tmp_path)
        svc = DataService(store)
        with pytest.raises(TypeError, match="not.*both|not both"):
            svc.open_session("j", SessionSpec(), seed=3)
        svc.close()
        store.close()


class TestSessionLifecycle:
    """close/close_session idempotency and the unknown-job error surface."""

    def test_close_session_is_idempotent(self, tmp_path):
        store = build_store(tmp_path)
        svc = DataService(store)
        svc.open_session("j", seed=2, batch_per_node=16, seq_len=32)
        svc.close_session("j")
        svc.close_session("j")          # second close: no-op
        svc.close_session("never-was")  # unknown id: no-op too
        svc.close()
        svc.close()                     # service close is idempotent as well
        store.close()

    def test_close_then_reopen_same_job_id(self, tmp_path):
        store = build_store(tmp_path)
        svc = DataService(store)
        s1 = svc.open_session("j", seed=2, batch_per_node=16, seq_len=32)
        first = [b["returned"].copy() for b in s1.epoch(0)]
        svc.close_session("j")
        s2 = svc.open_session("j", seed=2, batch_per_node=16, seq_len=32)
        second = [b["returned"].copy() for b in s2.epoch(0)]
        for a, b in zip(first, second):
            np.testing.assert_array_equal(a, b)  # fresh protocol state
        svc.close()
        store.close()

    def test_unknown_job_lookup_has_clear_error(self, tmp_path):
        store = build_store(tmp_path)
        svc = DataService(store)
        svc.open_session("present", seed=2, batch_per_node=16, seq_len=32)
        # NB: str(KeyError) is the repr of its message, so quotes inside the
        # message arrive escaped — match on quote-free fragments.
        with pytest.raises(KeyError, match="no open session for job"):
            svc.session("absent")
        with pytest.raises(KeyError, match="present"):
            svc.session("absent")  # message lists what IS open
        svc.close()
        with pytest.raises(KeyError, match="open sessions: none"):
            svc.session("present")
        store.close()

    def test_double_open_same_id_rejected(self, tmp_path):
        store = build_store(tmp_path)
        svc = DataService(store)
        svc.open_session("j", seed=2, batch_per_node=16, seq_len=32)
        with pytest.raises(ValueError, match="already has an open session"):
            svc.open_session("j", seed=3, batch_per_node=16, seq_len=32)
        svc.close()
        store.close()
