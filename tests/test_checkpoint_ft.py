"""Checkpoint/restore + fault-tolerance integration tests."""

import numpy as np
import jax

from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import ARCHS, RunConfig, reduced
from repro.ft.failures import FailureInjector, Heartbeat, StragglerMonitor
from repro.launch.specs import dummy_train_inputs
from repro.models import build_model
from repro.optim.optimizers import make_optimizer
from repro.train.train_step import build_train_step, init_train_state


def make_setup(name="tinyllama-1.1b"):
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    run = RunConfig(optimizer="adamw", learning_rate=1e-3)
    opt = make_optimizer(run)
    state = init_train_state(model, opt, 0)
    step_fn = jax.jit(build_train_step(model, run, opt))
    return cfg, model, step_fn, state


class TestCheckpoint:
    def test_restart_resume_is_bit_exact(self, tmp_path):
        """Train 6 steps; checkpoint at 3; restart; steps 4-6 match exactly."""
        cfg, model, step_fn, state = make_setup()
        batches = [dummy_train_inputs(cfg, 4, 64, seed=i) for i in range(6)]
        losses_a = []
        for i, b in enumerate(batches):
            state, m = step_fn(state, b)
            losses_a.append(float(m["loss"]))
            if i == 2:
                save_checkpoint(tmp_path, 3, state)

        # "crash" and restart from the checkpoint
        cfg, model, step_fn, fresh = make_setup()
        state_b = restore_checkpoint(tmp_path, 3, fresh)
        losses_b = []
        for b in batches[3:]:
            state_b, m = step_fn(state_b, b)
            losses_b.append(float(m["loss"]))
        np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-6)

    def test_latest_step(self, tmp_path):
        cfg, model, step_fn, state = make_setup()
        assert latest_step(tmp_path) is None
        save_checkpoint(tmp_path, 5, state)
        save_checkpoint(tmp_path, 9, state)
        assert latest_step(tmp_path) == 9

    def test_async_checkpointer(self, tmp_path):
        cfg, model, step_fn, state = make_setup()
        ck = AsyncCheckpointer(tmp_path, keep=2)
        for s in (1, 2, 3):
            ck.save(s, state)
        ck.wait()
        assert latest_step(tmp_path) == 3
        steps = sorted(p.name for p in tmp_path.iterdir())
        assert len(steps) == 2  # gc keeps last 2

    def test_async_wait_after_failed_save_cleans_up(self, tmp_path):
        """A save abandoned by a worker-thread failure leaves no
        .tmp_step_* behind and wait() both joins the thread and surfaces
        the error exactly once."""
        import pytest

        cfg, model, step_fn, state = make_setup()
        save_checkpoint(tmp_path, 7, state)  # occupy step 7: next save fails
        ck = AsyncCheckpointer(tmp_path, keep=2)
        ck.save(7, state)
        with pytest.raises(FileExistsError):
            ck.wait()
        assert ck._thread is None  # joined, not leaked
        leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".tmp_step_")]
        assert leftovers == []
        ck.wait()  # error was consumed; a second wait is a clean no-op
        ck.save(8, state)  # the checkpointer is still usable
        ck.wait()
        assert latest_step(tmp_path) == 8

    def test_elastic_reshard_on_restore(self, tmp_path):
        """Save unsharded; restore with explicit device placement (the
        mechanism behind mesh-shape changes on restart)."""
        cfg, model, step_fn, state = make_setup()
        save_checkpoint(tmp_path, 1, state)
        dev = jax.devices()[0]
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), state
        )
        back = restore_checkpoint(tmp_path, 1, state, shardings=shardings)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFaultTolerance:
    def test_heartbeat_detects_dead(self):
        hb = Heartbeat(3, timeout_s=0.05)
        import time

        hb.ping(0)
        hb.ping(1)
        hb.mark_dead(2)
        assert hb.dead_workers() == [2]
        time.sleep(0.06)
        assert set(hb.dead_workers()) == {0, 1, 2}

    def test_failure_injection_schedule(self):
        inj = FailureInjector({10: 2, 20: 0})
        assert inj.maybe_fail(10) == 2
        assert inj.maybe_fail(11) is None

    def test_straggler_monitor(self):
        sm = StragglerMonitor(4, threshold=2.0)
        for _ in range(8):
            for w in range(3):
                sm.record(w, 0.1)
            sm.record(3, 0.5)
        assert sm.stragglers() == [3]

    def test_straggler_two_workers_slow_one_flagged(self):
        """Regression: the upper-middle 'median' of 2 workers was the slow
        worker's own mean, so it could never exceed threshold x itself;
        the leave-one-out median compares it against its peer."""
        sm = StragglerMonitor(2, threshold=2.0)
        for _ in range(8):
            sm.record(0, 0.1)
            sm.record(1, 0.5)
        assert sm.stragglers() == [1]

    def test_straggler_all_equal_none_flagged(self):
        sm = StragglerMonitor(4, threshold=2.0)
        for _ in range(8):
            for w in range(4):
                sm.record(w, 0.1)
        assert sm.stragglers() == []

    def test_straggler_empty_window_flagged(self):
        """A silent worker is flagged once its peers report; with no
        reports from anyone there is no baseline and nobody is flagged."""
        sm = StragglerMonitor(3, threshold=2.0)
        assert sm.stragglers() == []  # nobody reported yet
        for _ in range(4):
            sm.record(0, 0.1)
            sm.record(1, 0.1)
        assert sm.stragglers() == [2]  # worker 2 never reported

    def test_heartbeat_mark_dead_vs_ping_interleaving(self):
        """mark_dead and ping may race (coordinator vs a slow worker's last
        gasp): a ping AFTER mark_dead resurrects the worker — exactly the
        elastic rejoin semantics Cluster.join_node gives the data plane —
        while a mark_dead after the ping wins again."""
        hb = Heartbeat(2, timeout_s=5.0)
        hb.mark_dead(0)
        assert hb.dead_workers() == [0]
        hb.ping(0)  # late ping: the worker is actually alive
        assert hb.dead_workers() == []
        hb.ping(1)
        hb.mark_dead(1)  # coordinator overrules: declared dead stays dead
        assert hb.dead_workers() == [1]
        hb.mark_dead(1)  # idempotent
        assert hb.dead_workers() == [1]

    def test_train_through_failure_with_redox_remap(self, tmp_path):
        """End-to-end: training from the Redox loader survives a data-node
        failure mid-epoch (ownership remap) AND a trainer restart from the
        checkpoint; every record is still consumed exactly once."""
        from repro.core import Cluster, EpochSampler
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(240, vocab_size=97, mean_len=48, seed=5)
        store = ds.build_store(tmp_path / "chunks", 4, num_slots=16, seed=1)
        cluster = Cluster(store.plan, 3, store=store, seed=2)
        sampler = EpochSampler(240, 3, seed=4)
        seqs = cluster.begin_epoch(sampler, 0)
        consumed = []
        io = {}
        for r in range(3):
            for pos in range(40):
                f, data = cluster.access(r, pos, int(seqs[r][pos]), io)
                assert data is not None
                consumed.append(f)
        cluster.fail_node(1, processed_upto=40)
        for r in (0, 2):
            seq = cluster.sequences[r]
            for pos in range(40, len(seq)):
                f, data = cluster.access(r, pos, int(seq[pos]), io)
                assert data is not None
                consumed.append(f)
        assert sorted(consumed) == list(range(240))
